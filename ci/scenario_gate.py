#!/usr/bin/env python3
"""Structural gate for the scenario matrix artifact (BENCH_scenarios.json).

Checks that every swept cell is internally consistent — these are
invariants of the serving runtime, not tunable performance numbers, so
any violation is a hard failure:

* schema tag is `compass.scenarios.v1`;
* every cell key is `scenario|topology|policy` (three parts);
* conservation: `served + rejected + failed + shed + expired ==
  arrivals` and `arrivals > 0` — the executor (live or DES) accounted
  for every generated request, including ones that failed terminally
  under chaos or were shed/expired by the overload plane;
* `slo_compliance`, `mean_accuracy`, `slo_goodput` and
  `gold_compliance` lie in [0, 1], and goodput never exceeds
  compliance (it is compliance discounted by the served fraction);
* the resilience counters (`failed`, `retries`, `panics_recovered`,
  `timeouts`, `breaker_trips`, `failovers`) and the overload counters
  (`shed`, `expired`, `brownout_steps`) are present and non-negative,
  the `resilience` tag is `on`/`off`, and the `overload` tag is
  `deadline`/`tail`/`off`;
* latency quantiles are ordered: `p50 <= p95 <= p99`;
* `pool_dark` cells on a multi-pool topology injected their fault
  (`faults != "none"`) and the alive pool absorbed spilled work
  (`spills >= 1`);
* `squeeze` / `slowdown` cells injected their fault;
* the chaos pair: `dark_recover` runs resilience-on (and its
  Static-Accurate cell on a multi-pool topology must fail over at
  least once), `dark_drain` runs the same fault resilience-off with
  zero retries; `flaky` runs resilience-on and on a single-pool
  topology (where the flaky pool is unavoidable) must retry at least
  once;
* the overload pair: `overload_sustained` runs deadline-aware,
  `overload_tail_drop` runs the tail-drop twin, `overload_flash`
  deadline-aware; every non-overload cell runs the plane off with
  zero shed/expired; the sustained Static-Accurate cell (ρ ≈ 1.5)
  must shed or expire at least one request. The deadline-vs-tail
  gold_compliance ratio itself is gated by `bench_gate.py` against
  BENCH_scenarios_baseline.json;
* the drift pair: `drift_replan` runs the online re-plan loop
  (`replan` tag `on`) and its Elastico cells must adopt at least one
  re-derived plan (`replans >= 1` — the loop converged inside the
  drifted window); `drift_static` serves the same arrivals and drift
  with the loop off; both inject their fault. Every replan-off cell
  reports zero adopted plans, so a disabled loop provably never
  touched the policy. The replan-vs-static compliance ratio itself
  is gated by `bench_gate.py` against BENCH_scenarios_baseline.json.

`--min-scenarios N` / `--min-topos N` additionally assert matrix
coverage (distinct scenario / topology counts), so the CI smoke run
can't silently shrink below the acceptance floor.

Usage: scenario_gate.py BENCH_scenarios.json [--min-scenarios N]
       [--min-topos N]
"""

import json
import sys

SCHEMA = "compass.scenarios.v1"


def check_cell(key: str, cell: dict) -> list:
    errors = []
    parts = key.split("|")
    if len(parts) != 3:
        errors.append(f"{key}: cell key is not scenario|topology|policy")
        return errors
    scenario = parts[0]
    policy = parts[2]

    arrivals = cell.get("arrivals", 0)
    served = cell.get("served", 0)
    rejected = cell.get("rejected", 0)
    failed = cell.get("failed", 0)
    shed = cell.get("shed", 0)
    expired = cell.get("expired", 0)
    if arrivals <= 0:
        errors.append(f"{key}: no arrivals generated")
    if served + rejected + failed + shed + expired != arrivals:
        errors.append(
            f"{key}: conservation violated — served {served} + rejected "
            f"{rejected} + failed {failed} + shed {shed} + expired "
            f"{expired} != arrivals {arrivals}")

    for field in ("slo_compliance", "mean_accuracy", "slo_goodput",
                  "gold_compliance"):
        val = cell.get(field, -1.0)
        if not 0.0 <= val <= 1.0:
            errors.append(f"{key}: {field} {val} outside [0, 1]")
    if cell.get("slo_goodput", 0.0) > cell.get("slo_compliance", 0.0) + 1e-9:
        errors.append(f"{key}: slo_goodput exceeds slo_compliance")
    for field in ("failed", "retries", "panics_recovered", "timeouts",
                  "breaker_trips", "failovers", "shed", "expired",
                  "brownout_steps", "replans"):
        if cell.get(field, -1) < 0:
            errors.append(f"{key}: counter {field} missing or negative")
    if cell.get("resilience") not in ("on", "off"):
        errors.append(f"{key}: resilience tag {cell.get('resilience')!r} "
                      "is not on/off")
    if cell.get("overload") not in ("deadline", "tail", "off"):
        errors.append(f"{key}: overload tag {cell.get('overload')!r} "
                      "is not deadline/tail/off")
    p50, p95, p99 = (cell.get(q, 0.0) for q in ("p50_ms", "p95_ms", "p99_ms"))
    if not p50 <= p95 <= p99:
        errors.append(f"{key}: quantiles unordered: {p50} / {p95} / {p99}")

    faults = cell.get("faults", "none")
    multi_pool = cell.get("n_pools", 1) >= 2
    if scenario == "pool_dark" and multi_pool:
        if faults == "none":
            errors.append(f"{key}: pool_dark cell ran without its fault")
        if cell.get("spills", 0) < 1:
            errors.append(f"{key}: pool_dark cell never spilled to the "
                          "alive pool")
    if scenario in ("squeeze", "slowdown") and faults == "none":
        errors.append(f"{key}: {scenario} cell ran without its fault")

    # The chaos pair + the flaky window (resilience-plane cells).
    if scenario == "dark_recover":
        if cell.get("resilience") != "on":
            errors.append(f"{key}: dark_recover must run resilience-on")
        if multi_pool and faults == "none":
            errors.append(f"{key}: dark_recover cell ran without its fault")
        if multi_pool and policy == "Static-Accurate" \
                and cell.get("failovers", 0) < 1:
            errors.append(f"{key}: dark window never failed over to the "
                          "surviving pool")
    if scenario == "dark_drain":
        if cell.get("resilience") != "off":
            errors.append(f"{key}: dark_drain must run resilience-off")
        if cell.get("retries", 0) != 0:
            errors.append(f"{key}: dark_drain retried with resilience off")
    if scenario == "flaky":
        if cell.get("resilience") != "on":
            errors.append(f"{key}: flaky must run resilience-on")
        if faults == "none":
            errors.append(f"{key}: flaky cell ran without its fault")
        if not multi_pool and cell.get("retries", 0) < 1:
            errors.append(f"{key}: flaky window on the only pool never "
                          "retried")

    # The overload pair + the flash cell (overload-plane cells).
    want_overload = {"overload_sustained": "deadline",
                     "overload_tail_drop": "tail",
                     "overload_flash": "deadline"}.get(scenario, "off")
    if cell.get("overload") != want_overload:
        errors.append(f"{key}: overload tag {cell.get('overload')!r}, "
                      f"expected {want_overload!r}")
    if want_overload == "off" and shed + expired > 0:
        errors.append(f"{key}: overload-off cell shed {shed} / expired "
                      f"{expired} requests")
    if scenario == "overload_sustained" and policy == "Static-Accurate" \
            and shed + expired < 1:
        errors.append(f"{key}: sustained 1.5x overload never shed or "
                      "expired a request")

    # The drift pair (re-plan loop cells).
    want_replan = "on" if scenario == "drift_replan" else "off"
    if cell.get("replan") not in ("on", "off"):
        errors.append(f"{key}: replan tag {cell.get('replan')!r} is not "
                      "on/off")
    elif cell.get("replan") != want_replan:
        errors.append(f"{key}: replan tag {cell.get('replan')!r}, "
                      f"expected {want_replan!r}")
    if cell.get("replan") == "off" and cell.get("replans", 0) != 0:
        errors.append(f"{key}: adopted {cell.get('replans')} plan(s) with "
                      "the re-plan loop off")
    if scenario in ("drift_replan", "drift_static") and faults == "none":
        errors.append(f"{key}: {scenario} cell ran without its drift fault")
    if scenario == "drift_replan" and policy == "Elastico" \
            and cell.get("replans", 0) < 1:
        errors.append(f"{key}: re-plan loop never adopted a plan under "
                      "drift (did the estimator converge?)")
    return errors


def main() -> int:
    args = sys.argv[1:]
    min_scenarios = min_topos = 0
    paths = []
    i = 0
    while i < len(args):
        if args[i] == "--min-scenarios":
            min_scenarios, i = int(args[i + 1]), i + 2
        elif args[i] == "--min-topos":
            min_topos, i = int(args[i + 1]), i + 2
        else:
            paths.append(args[i])
            i += 1
    if len(paths) != 1:
        print(__doc__)
        return 2

    with open(paths[0]) as f:
        doc = json.load(f)

    errors = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema {doc.get('schema')!r} != {SCHEMA!r}")
    cells = doc.get("cells", {})
    if not cells:
        errors.append("no cells in artifact")
    for key in sorted(cells):
        errors.extend(check_cell(key, cells[key]))

    scenarios = {k.split("|")[0] for k in cells}
    topos = {k.split("|")[1] for k in cells if len(k.split("|")) == 3}
    if len(scenarios) < min_scenarios:
        errors.append(f"only {len(scenarios)} scenario(s) "
                      f"({sorted(scenarios)}), need >= {min_scenarios}")
    if len(topos) < min_topos:
        errors.append(f"only {len(topos)} topolog(y/ies) ({sorted(topos)}), "
                      f"need >= {min_topos}")

    if errors:
        for e in errors:
            print(f"scenario gate: {e}")
        print(f"scenario gate: FAIL — {len(errors)} violation(s) across "
              f"{len(cells)} cell(s)")
        return 1
    print(f"scenario gate: OK — {len(cells)} cell(s), "
          f"{len(scenarios)} scenario(s) x {len(topos)} topolog(y/ies), "
          "conservation and ranges hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
