#!/usr/bin/env python3
"""Perf regression gate: diff BENCH_hotpath.json against the committed
BENCH_baseline.json.

Two kinds of checks, both read from the baseline file:

* **Absolute keys** — every shared numeric key is diffed; >25% regression
  on the per-key mean (ns/iter) fails. CI runners are noisy, so the
  tolerance is deliberately wide; treat a failure as "look at the diff",
  not as proof of a regression. Bless absolutes by downloading the
  BENCH_hotpath.json artifact from a trusted CI run on main and merging
  its keys into BENCH_baseline.json (machine-specific — only meaningful
  once runs come from comparable runners).
* **Ratio invariants** — the baseline's `"ratios"` object maps a label to
  `{"num": key, "den": key, "min": x}` (and/or `"max"`): the gate
  computes new[num]/new[den] and fails if it leaves the bounds, or if
  either key is missing from the new results. `num`/`den` are resolved
  by exact match first, then by *unique prefix* — bench names embed the
  per-thread op count, which differs between CI's fast mode and a full
  local run, so the committed ratios use op-count-free prefixes (e.g.
  `"mpmc central k=4 push+pop x"`) and match either mode. Ratios are
  machine-portable (both sides run on the same runner in the same job),
  so they arm the gate without a blessed absolute baseline: the
  sharded-queue and batched-dispatch speedups, and the pooled-DES cost
  envelope, are asserted on every run. Bounds are set conservatively —
  well below the speedups a quiet machine shows — to leave headroom for
  shared-runner noise.

Both files may nest objects (e.g. BENCH_scenarios.json's `cells`): they
are flattened to `/`-joined numeric-leaf keys before checking, so a
ratio over the scenario matrix reads
`cells/flash_crowd|uniform-k4|Elastico/slo_compliance`. Non-numeric
leaves (schema tags, fault strings) are dropped by the flatten.

Usage: bench_gate.py BENCH_baseline.json BENCH_hotpath.json
"""

import json
import sys

TOLERANCE = 1.25


def flatten(doc: dict, prefix: str = "") -> dict:
    """Nested dicts -> {"a/b/c": number}; numeric leaves only."""
    out = {}
    for key, val in doc.items():
        path = f"{prefix}/{key}" if prefix else key
        if isinstance(val, dict):
            out.update(flatten(val, path))
        elif isinstance(val, (int, float)) and not isinstance(val, bool):
            out[path] = float(val)
    return out


def check_absolutes(base: dict, new: dict) -> list:
    shared = sorted(set(base) & set(new))
    regressed = []
    for key in shared:
        old_ns, new_ns = float(base[key]), float(new[key])
        ratio = new_ns / old_ns if old_ns > 0 else 1.0
        flag = "REGRESSION" if ratio > TOLERANCE else "ok"
        print(f"{key:<60} {old_ns:>14.1f} -> {new_ns:>14.1f} ns/iter "
              f"({ratio:5.2f}x) {flag}")
        if ratio > TOLERANCE:
            regressed.append(key)
    if not shared:
        print(
            "bench gate: no shared absolute keys — absolute diffing "
            "unarmed.\nTo arm it, bless a baseline: merge a trusted CI "
            "run's BENCH_hotpath.json artifact into BENCH_baseline.json."
        )
    extra = sorted(set(new) - set(base))
    if extra:
        print(f"bench gate: {len(extra)} new key(s) not in baseline "
              f"(informational): {', '.join(extra[:5])}"
              + (" …" if len(extra) > 5 else ""))
    return regressed


def resolve_key(want: str, new: dict):
    """Exact bench key, or the unique key it is a prefix of."""
    if want in new:
        return want
    matches = [k for k in new if k.startswith(want)]
    return matches[0] if len(matches) == 1 else None


def check_ratios(ratios: dict, new: dict) -> list:
    failed = []
    for label, spec in sorted(ratios.items()):
        num_key = resolve_key(spec["num"], new)
        den_key = resolve_key(spec["den"], new)
        if num_key is None or den_key is None:
            missing = [spec[w] for w, k in
                       (("num", num_key), ("den", den_key)) if k is None]
            print(f"ratio {label}: MISSING/ambiguous bench key(s): {missing}")
            failed.append(label)
            continue
        num, den = float(new[num_key]), float(new[den_key])
        if den <= 0:
            print(f"ratio {label}: non-positive denominator {den}")
            failed.append(label)
            continue
        ratio = num / den
        lo = spec.get("min")
        hi = spec.get("max")
        ok = (lo is None or ratio >= float(lo)) and (
            hi is None or ratio <= float(hi))
        bounds = []
        if lo is not None:
            bounds.append(f">= {float(lo):.2f}")
        if hi is not None:
            bounds.append(f"<= {float(hi):.2f}")
        print(f"ratio {label:<52} {ratio:6.2f}x (want {' and '.join(bounds)}) "
              f"{'ok' if ok else 'VIOLATION'}")
        if not ok:
            failed.append(label)
    return failed


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        new = json.load(f)

    ratios = base.pop("ratios", {})
    base = flatten(base)
    new = flatten(new)
    regressed = check_absolutes(base, new)
    ratio_failures = check_ratios(ratios, new)

    if regressed or ratio_failures:
        if regressed:
            print(f"bench gate: FAIL — {len(regressed)} absolute key(s) "
                  f"regressed >{(TOLERANCE - 1):.0%}: {regressed}")
        if ratio_failures:
            print(f"bench gate: FAIL — {len(ratio_failures)} ratio "
                  f"invariant(s) violated: {ratio_failures}")
        return 1
    print(f"bench gate: OK — {len(set(base) & set(new))} absolute key(s) "
          f"within {(TOLERANCE - 1):.0%}, {len(ratios)} ratio invariant(s) "
          "hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
