#!/usr/bin/env python3
"""Perf regression gate: diff BENCH_hotpath.json against the committed
BENCH_baseline.json, failing on >25% regression for any *shared* bench
key (new keys are informational; keys dropped from the bench are
ignored).

Usage: bench_gate.py BENCH_baseline.json BENCH_hotpath.json

The baseline is blessed manually: download the BENCH_hotpath.json
artifact from a trusted CI run on main and commit it as
BENCH_baseline.json. An empty baseline ({}) leaves the gate unarmed —
the step passes and prints how to arm it. CI runners are noisy, so the
tolerance is deliberately wide (1.25x on the per-key mean); treat a
failure as "look at the diff", not as proof of a regression.
"""

import json
import sys

TOLERANCE = 1.25


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        new = json.load(f)

    shared = sorted(set(base) & set(new))
    if not shared:
        print(
            "bench gate: no shared keys (baseline empty or disjoint) — gate "
            "unarmed.\nTo arm it, bless a baseline: copy a trusted CI run's "
            "BENCH_hotpath.json artifact to BENCH_baseline.json and commit."
        )
        return 0

    regressed = []
    for key in shared:
        old_ns, new_ns = float(base[key]), float(new[key])
        ratio = new_ns / old_ns if old_ns > 0 else 1.0
        flag = "REGRESSION" if ratio > TOLERANCE else "ok"
        print(f"{key:<60} {old_ns:>14.1f} -> {new_ns:>14.1f} ns/iter "
              f"({ratio:5.2f}x) {flag}")
        if ratio > TOLERANCE:
            regressed.append(key)

    extra = sorted(set(new) - set(base))
    if extra:
        print(f"bench gate: {len(extra)} new key(s) not in baseline "
              f"(informational): {', '.join(extra[:5])}"
              + (" …" if len(extra) > 5 else ""))

    if regressed:
        print(f"bench gate: FAIL — {len(regressed)} key(s) regressed "
              f">{(TOLERANCE - 1):.0%}: {regressed}")
        return 1
    print(f"bench gate: OK — {len(shared)} shared key(s) within "
          f"{(TOLERANCE - 1):.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
