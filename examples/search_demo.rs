//! COMPASS-V search demo (no artifacts needed): feasible-set discovery on
//! the RAG space vs exhaustive grid search, at three thresholds.
//!
//! Run: `cargo run --release --example search_demo`

use compass::configspace::rag_space;
use compass::oracle::RagOracle;
use compass::search::{grid_search, CompassV, CompassVParams};

fn main() {
    let space = rag_space();
    let n = space.enumerate_valid().len();
    println!("RAG configuration space: {n} valid configurations\n");

    for tau in [0.50, 0.75, 0.85] {
        let mut oracle = RagOracle::new_rag(42);
        let result = CompassV::new(CompassVParams { seed: 42, ..Default::default() })
            .run(&space, tau, &mut oracle);

        let mut gt_oracle = RagOracle::new_rag(42);
        let gt = grid_search(&space, 100, &mut gt_oracle).feasible(tau);
        let gt_ids: std::collections::HashSet<usize> =
            gt.iter().map(|(c, _)| space.flat_id(c)).collect();
        let hits = result
            .feasible
            .iter()
            .filter(|(c, _)| gt_ids.contains(&space.flat_id(c)))
            .count();

        println!(
            "tau={tau}: found {:>3} feasible (gt {:>3}, recall {:>5.1}%) using {:>6} samples ({:.1}% saved vs {})",
            result.feasible.len(),
            gt.len(),
            100.0 * hits as f64 / gt.len().max(1) as f64,
            result.samples_used,
            result.savings_vs_exhaustive(n, 100) * 100.0,
            n * 100,
        );
        // Show the frontier of what was found.
        let mut best: Vec<_> = result.feasible.clone();
        best.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (cfg, acc) in best.iter().take(3) {
            println!("    {:<40} acc~{acc:.3}", space.display(cfg));
        }
        println!();
    }
}
