//! Detection-cascade workflow demo: the detector->gate->verifier pipeline
//! over real CNN artifacts, showing how the confidence threshold moves the
//! verifier invocation rate (and hence latency).
//!
//! Run: `make artifacts && cargo run --release --example detection_cascade`

use compass::configspace::detection_space;
use compass::runtime::artifacts_dir;
use compass::workflows::detection::DetectionWorkflow;
use compass::workflows::Workflow;

fn main() -> anyhow::Result<()> {
    let space = detection_space();
    let mut wf = DetectionWorkflow::load(&artifacts_dir(), 3)?;

    println!("detection cascade: det-m + ver-x, sweeping confidence threshold\n");
    let det = 2; // det-m
    let ver = 3; // ver-x
    let nms = 2; // 0.5
    for conf in 0..7 {
        let cfg = vec![det, ver, conf, nms];
        // Warm the gate statistics, then time a batch.
        for _ in 0..20 {
            wf.run(&space, &cfg)?;
        }
        let t0 = std::time::Instant::now();
        let n = 40;
        let mut successes = 0;
        for _ in 0..n {
            if wf.run(&space, &cfg)?.success == Some(true) {
                successes += 1;
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
        println!(
            "  conf_thr={:<5} mean {:>6.2} ms/req  measured acc {:>4.2}  ({})",
            space.display(&cfg).split(", ").nth(2).unwrap_or(""),
            ms,
            successes as f64 / n as f64,
            space.display(&cfg),
        );
    }
    println!("\nhigher thresholds forward more inputs to the verifier -> more compute, more accuracy");
    Ok(())
}
