//! End-to-end driver (EXPERIMENTS.md §E2E): the full Compass lifecycle on
//! live PJRT serving.
//!
//!  offline:  COMPASS-V search (tau=0.75) -> live profiling -> Pareto
//!            front -> AQM switching thresholds;
//!  online:   real requests through the Rust serving system (central
//!            queue, load monitor, Elastico) under the paper's spike
//!            pattern, compared against the static baselines.
//!
//! Run: `make artifacts && cargo run --release --example rag_serving -- [--duration 30]`

use compass::experiments::common::{base_qps, make_policy, offline_phase, SLO_FACTORS};
use compass::metrics::report::summary_row;
use compass::metrics::RunSummary;
use compass::runtime::artifacts_dir;
use compass::serving::executor::WorkflowEngine;
use compass::serving::{serve, ServeOptions};
use compass::util::results_dir;
use compass::workflows::rag::RagWorkflow;
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let duration = args
        .iter()
        .position(|a| a == "--duration")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(30.0);
    let seed = 7;

    println!("== Compass end-to-end: offline phase (live profiling) ==");
    let (space, full) = offline_phase(0.75, 1e9, seed, true)?;
    let slo = SLO_FACTORS[1] * full.ladder.last().unwrap().mean_ms;
    let (_, plan) = offline_phase(0.75, slo, seed, true)?;
    print!("{}", plan.render());

    let qps = base_qps(&full);
    let spec = WorkloadSpec {
        base_qps: qps,
        duration_s: duration,
        pattern: Pattern::paper_spike(),
        seed,
    };
    let arrivals = generate_arrivals(&spec);
    println!(
        "\n== online phase: spike pattern, {duration}s, base {qps:.2} qps, SLO {slo:.0} ms ==\n({} arrivals; 4x spike in the middle third; live PJRT serving)",
        arrivals.len()
    );

    let mut rows = Vec::new();
    for policy_name in ["Elastico", "Static-Fast", "Static-Accurate"] {
        let policy_plan = if policy_name == "Elastico" { &plan } else { &full };
        let policy = make_policy(policy_plan, policy_name);
        let space2 = space.clone();
        let plan2 = policy_plan.clone();
        let out = serve(
            move || {
                let configs: Vec<_> =
                    plan2.ladder.iter().map(|p| p.config.clone()).collect();
                let wf = RagWorkflow::load_subset(
                    &artifacts_dir(),
                    &space2,
                    &configs,
                    seed,
                )?;
                Ok(WorkflowEngine::new(wf, space2.clone(), plan2.clone()))
            },
            policy,
            &arrivals,
            &ServeOptions::default(),
        )?;
        let summary = RunSummary::compute(
            &out.records,
            &out.switches,
            slo,
            policy_plan.ladder.len(),
        );
        println!("{}", summary_row(policy_name, &summary));
        if let Some(rate) = summary.success_rate {
            println!("    measured answer success rate: {rate:.3}");
        }
        compass::metrics::report::write_records_csv(
            &results_dir().join(format!("e2e_{}.csv", policy_name.to_lowercase())),
            &out.records,
        )?;
        rows.push((policy_name, summary));
    }

    let ela = &rows[0].1;
    let fast = &rows[1].1;
    let acc = &rows[2].1;
    println!("\n== verdict ==");
    println!(
        "Elastico vs Static-Accurate: {:+.1} pts SLO compliance",
        (ela.slo_compliance - acc.slo_compliance) * 100.0
    );
    println!(
        "Elastico vs Static-Fast:     {:+.1} pts mean accuracy",
        (ela.mean_accuracy - fast.mean_accuracy) * 100.0
    );
    println!("raw records -> results/e2e_*.csv");
    Ok(())
}
