//! Quickstart: the whole Compass stack in one minute.
//!
//! 1. loads the AOT artifacts (PJRT CPU),
//! 2. runs COMPASS-V on the RAG configuration space (surrogate oracle),
//! 3. profiles the feasible ladder and derives AQM switching thresholds,
//! 4. pushes a few live requests through each rung.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use compass::experiments::common::offline_phase;
use compass::runtime::artifacts_dir;
use compass::workflows::rag::RagWorkflow;
use compass::workflows::Workflow;

fn main() -> anyhow::Result<()> {
    println!("== Compass quickstart ==\n");

    // Offline phase: search (oracle) + profile (live artifacts) + AQM.
    println!("offline phase: COMPASS-V @ tau=0.75 + live profiling + AQM…");
    let (space, plan) = offline_phase(0.75, 1000.0, 7, true)?;
    print!("{}", plan.render());

    // Online phase: run one request per rung through the live pipeline.
    println!("\nlive requests through each rung:");
    let configs: Vec<_> = plan.ladder.iter().map(|p| p.config.clone()).collect();
    let mut wf = RagWorkflow::load_subset(&artifacts_dir(), &space, &configs, 7)?;
    for rung in &plan.ladder {
        let t0 = std::time::Instant::now();
        let out = wf.run(&space, &rung.config)?;
        println!(
            "  {:<40} {:>7.1} ms  success={:?}",
            rung.label,
            t0.elapsed().as_secs_f64() * 1e3,
            out.success.unwrap_or(false),
        );
    }
    println!("\nquickstart OK — see `compass help` and examples/rag_serving.rs");
    Ok(())
}
