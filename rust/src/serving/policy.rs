//! Configuration-selection policies.
//!
//! A [`ScalingPolicy`] maps observed load (queue depth, time) to a rung of
//! the Pareto ladder. The same trait drives the live server and the
//! discrete-event simulator.
//!
//! Policies are constructed from a [`crate::planner::Plan`], which
//! carries the executor worker count its queue-depth thresholds were
//! derived for (`Plan::workers`, effective service rate k·μ) — a policy
//! built from a k-worker plan is only meaningful against a k-worker
//! pool (`ServeOptions::workers` / `sim::simulate_k`).

/// A runtime configuration-selection policy over a ladder of `n` rungs
/// (index 0 = fastest, `n-1` = most accurate).
pub trait ScalingPolicy: Send {
    /// Observe load and return the desired ladder index.
    ///
    /// `queue_depth` is the controller's depth signal: on a homogeneous
    /// fleet the total-across-shards backlog; on a pooled fleet
    /// ([`crate::serving::pool`]) the backlog of the pool the current
    /// rung routes to, so thresholds derived per pool
    /// ([`crate::planner::derive_plan_pools`]) compare against the
    /// backlog that pool alone must drain — and a threshold crossing
    /// moves load between pools.
    fn decide(&mut self, now_ms: f64, queue_depth: usize) -> usize;

    /// Currently selected ladder index.
    fn current(&self) -> usize;

    /// Display name (reports/plots).
    fn name(&self) -> String;

    /// Lock-elision hint for the serving control plane: an inclusive
    /// depth band `[lo, hi]` within which `decide` is *guaranteed* to
    /// keep the current rung and needs no state update that cannot wait
    /// for the next monitor tick. The server caches the band in atomics
    /// and skips the policy mutex entirely for in-band observations —
    /// the hot-path common case. `None` (the default) means every
    /// observation must reach the policy under its lock.
    ///
    /// Contract: for any `d` with `lo <= d <= hi`, `decide(now, d)`
    /// returns `current()` and performs no transition, opens no
    /// hysteresis window, and resets none — skipping the call is
    /// observationally equivalent up to smoothing-state staleness that
    /// the periodic tick (which always takes the lock) repairs.
    fn no_switch_band(&self) -> Option<(usize, usize)> {
        None
    }

    /// Swap in a re-derived plan (the online re-planner's install hook,
    /// [`crate::serving::replan`]). Returns `true` if the policy adopted
    /// the new thresholds. The default declines: policies that carry no
    /// plan (static baselines) have nothing to re-derive, and a
    /// re-planner pointed at one simply keeps measuring.
    ///
    /// Contract for implementors: the new plan must describe the *same
    /// ladder* (same length, same rung order — only thresholds,
    /// cooldowns and service beliefs may differ), the currently selected
    /// rung must remain selected (re-planning retunes future decisions,
    /// it does not itself switch), and any open hysteresis window must
    /// be reset (its threshold basis just changed under it).
    fn replace_plan(&mut self, _plan: crate::planner::Plan) -> bool {
        false
    }
}

/// A fixed-configuration baseline (Static-Fast/Medium/Accurate, §VI-C).
#[derive(Clone, Debug)]
pub struct StaticPolicy {
    idx: usize,
    label: String,
}

impl StaticPolicy {
    pub fn new(idx: usize, label: impl Into<String>) -> StaticPolicy {
        StaticPolicy { idx, label: label.into() }
    }
}

impl ScalingPolicy for StaticPolicy {
    fn decide(&mut self, _now_ms: f64, _queue_depth: usize) -> usize {
        self.idx
    }

    fn current(&self) -> usize {
        self.idx
    }

    fn name(&self) -> String {
        self.label.clone()
    }

    /// A static policy never moves: every depth is in-band, so the
    /// server's fast path never takes the policy lock.
    fn no_switch_band(&self) -> Option<(usize, usize)> {
        Some((0, usize::MAX))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_never_moves() {
        let mut p = StaticPolicy::new(2, "Static-Accurate");
        for t in 0..100 {
            assert_eq!(p.decide(t as f64 * 10.0, t * 7), 2);
        }
        assert_eq!(p.name(), "Static-Accurate");
    }

    #[test]
    fn static_band_covers_every_depth() {
        let p = StaticPolicy::new(1, "s");
        assert_eq!(p.no_switch_band(), Some((0, usize::MAX)));
    }

    #[test]
    fn static_declines_replanning() {
        let mut p = StaticPolicy::new(1, "s");
        let plan = crate::planner::Plan {
            slo_ms: 100.0,
            slack_buffer_ms: 10.0,
            up_cooldown_ms: 0.0,
            down_cooldown_ms: 1000.0,
            workers: 1,
            batch: 1,
            batch_alpha_ms: 0.0,
            pools: vec![],
            ladder: vec![],
        };
        assert!(!p.replace_plan(plan), "a static baseline has no plan to retune");
        assert_eq!(p.current(), 1);
    }
}
