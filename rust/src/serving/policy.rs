//! Configuration-selection policies.
//!
//! A [`ScalingPolicy`] maps observed load (queue depth, time) to a rung of
//! the Pareto ladder. The same trait drives the live server and the
//! discrete-event simulator.
//!
//! Policies are constructed from a [`crate::planner::Plan`], which
//! carries the executor worker count its queue-depth thresholds were
//! derived for (`Plan::workers`, effective service rate k·μ) — a policy
//! built from a k-worker plan is only meaningful against a k-worker
//! pool (`ServeOptions::workers` / `sim::simulate_k`).

/// A runtime configuration-selection policy over a ladder of `n` rungs
/// (index 0 = fastest, `n-1` = most accurate).
pub trait ScalingPolicy: Send {
    /// Observe load and return the desired ladder index.
    fn decide(&mut self, now_ms: f64, queue_depth: usize) -> usize;

    /// Currently selected ladder index.
    fn current(&self) -> usize;

    /// Display name (reports/plots).
    fn name(&self) -> String;
}

/// A fixed-configuration baseline (Static-Fast/Medium/Accurate, §VI-C).
#[derive(Clone, Debug)]
pub struct StaticPolicy {
    idx: usize,
    label: String,
}

impl StaticPolicy {
    pub fn new(idx: usize, label: impl Into<String>) -> StaticPolicy {
        StaticPolicy { idx, label: label.into() }
    }
}

impl ScalingPolicy for StaticPolicy {
    fn decide(&mut self, _now_ms: f64, _queue_depth: usize) -> usize {
        self.idx
    }

    fn current(&self) -> usize {
        self.idx
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_never_moves() {
        let mut p = StaticPolicy::new(2, "Static-Accurate");
        for t in 0..100 {
            assert_eq!(p.decide(t as f64 * 10.0, t * 7), 2);
        }
        assert_eq!(p.name(), "Static-Accurate");
    }
}
