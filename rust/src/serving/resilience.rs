//! The resilience plane's decision core: per-pool health, bounded
//! retries with exponential backoff and a token-bucket budget, and a
//! per-pool circuit breaker — all as pure, clock-agnostic state
//! machines, following the dispatch-plane pattern
//! ([`crate::serving::topology::Topology`]): *decisions* live here
//! once, and the live server (`serving/server.rs`) and the DES engine
//! (`sim/engine.rs`) drive the same machines with their own clocks
//! (wall vs virtual), so a simulated chaos run replays the live
//! runtime's failure handling deterministically.
//!
//! ## Failure lifecycle
//!
//! 1. **Detect** — a pool is [`PoolHealth::Dark`] while its fault
//!    window is open ([`crate::workload::FaultPlan::is_dark_at_ms`]),
//!    and [`PoolHealth::Degraded`] while its breaker is open (the
//!    error/timeout EWMA crossed the trip threshold).
//! 2. **Failover** — routing consults [`HealthView::routable`]; a dark
//!    or degraded pool's rung band remaps to the nearest surviving pool
//!    via [`Topology::failover_pool`] (the `spill_order` walk, costed
//!    by `speed_factor`), and remaps back the instant health returns.
//! 3. **Retry** — a failed/timed-out/panicked request re-enqueues
//!    through normal routing with a fresh attempt number, gated by the
//!    per-request cap and the run-wide token bucket
//!    ([`HealthView::try_retry`]) and delayed by exponential backoff
//!    ([`ResilienceConfig::backoff_ms`]). Budget-denied or cap-exhausted
//!    requests are counted `failed` — never silently dropped — so the
//!    extended conservation law `served + rejected + failed == arrivals`
//!    holds under any chaos plan.
//! 4. **Recover** — a dark window closing (or a half-open probe
//!    succeeding) flips the pool back to [`PoolHealth::Healthy`] and
//!    routing remaps back with it.
//!
//! ## Disabled-config parity is structural
//!
//! [`ResilienceConfig::default`] is disabled: every query degenerates
//! to the pre-resilience constant (`routable` → always, `try_retry` →
//! never, breakers never trip), and the executors skip the resilience
//! branches entirely, so a disabled run is bit-identical to the
//! pre-resilience runtime — the same precedent as margin-0 spill and
//! the empty [`crate::workload::FaultPlan`], pinned by
//! `tests/resilience.rs`.

use super::topology::Topology;
use crate::workload::FaultPlan;

/// Resilience knobs of one run. `Default` is **disabled** — bit-for-bit
/// the pre-resilience runtime (pinned).
#[derive(Clone, Debug, PartialEq)]
pub struct ResilienceConfig {
    /// Master switch. Off (the default) short-circuits every decision
    /// to its historical constant.
    pub enabled: bool,
    /// Max retry attempts per request (attempt 0 is the first try).
    pub max_retries: u32,
    /// Token-bucket retry budget: tokens the bucket holds at start
    /// (and its cap). Each retry costs one token.
    pub retry_budget: f64,
    /// Bucket refill rate (tokens per second of run time) — bounds the
    /// sustained retry rate so an error storm cannot amplify load
    /// unboundedly.
    pub retry_refill_per_s: f64,
    /// Exponential backoff base (ms): attempt n waits `base · 2^(n-1)`
    /// before re-enqueueing, capped at [`backoff_cap_ms`](Self::backoff_cap_ms).
    pub backoff_base_ms: f64,
    /// Backoff ceiling (ms).
    pub backoff_cap_ms: f64,
    /// Error-rate EWMA level that trips a pool's breaker open.
    pub breaker_threshold: f64,
    /// EWMA smoothing weight per completion (0 < α ≤ 1).
    pub breaker_alpha: f64,
    /// How long (ms) a tripped breaker stays open before a half-open
    /// probe is allowed through.
    pub breaker_open_ms: f64,
    /// Minimum completions a pool must report before its EWMA may trip
    /// the breaker (keeps one unlucky first request from darkening a
    /// cold pool).
    pub breaker_min_samples: u32,
    /// Per-request execution timeout (ms); 0 disables. A completion
    /// slower than this counts as a timeout failure (and feeds the
    /// breaker EWMA like an error).
    pub request_timeout_ms: f64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            enabled: false,
            max_retries: 2,
            retry_budget: 64.0,
            retry_refill_per_s: 16.0,
            backoff_base_ms: 4.0,
            backoff_cap_ms: 200.0,
            breaker_threshold: 0.5,
            breaker_alpha: 0.2,
            breaker_open_ms: 1000.0,
            breaker_min_samples: 8,
            request_timeout_ms: 0.0,
        }
    }
}

impl ResilienceConfig {
    /// The enabled profile with default tuning.
    pub fn enabled() -> ResilienceConfig {
        ResilienceConfig { enabled: true, ..ResilienceConfig::default() }
    }

    /// Backoff before retry attempt `attempt` (the first retry is
    /// attempt 1): `base · 2^(attempt-1)`, capped.
    pub fn backoff_ms(&self, attempt: u32) -> f64 {
        if !self.enabled || attempt == 0 {
            return 0.0;
        }
        let exp = (attempt - 1).min(30);
        (self.backoff_base_ms * (1u64 << exp) as f64).min(self.backoff_cap_ms)
    }

    /// Did an execution that took `service_ms` time out?
    pub fn timed_out(&self, service_ms: f64) -> bool {
        self.enabled && self.request_timeout_ms > 0.0 && service_ms > self.request_timeout_ms
    }

    /// Parse `on` / `off` / a comma-separated `key=value` list
    /// (`on,max_retries=3,breaker_threshold=0.4,timeout_ms=500`).
    pub fn parse(s: &str) -> anyhow::Result<ResilienceConfig> {
        let mut cfg = ResilienceConfig::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part {
                "on" | "enabled" => cfg.enabled = true,
                "off" | "disabled" => cfg.enabled = false,
                _ => {
                    let (key, val) = part.split_once('=').ok_or_else(|| {
                        anyhow::anyhow!("resilience {part:?}: expected on|off|key=value")
                    })?;
                    let num = || -> anyhow::Result<f64> {
                        val.parse()
                            .map_err(|_| anyhow::anyhow!("resilience {part:?}: bad number"))
                    };
                    match key {
                        "max_retries" => cfg.max_retries = num()? as u32,
                        "retry_budget" => cfg.retry_budget = num()?,
                        "retry_refill_per_s" => cfg.retry_refill_per_s = num()?,
                        "backoff_ms" => cfg.backoff_base_ms = num()?,
                        "backoff_cap_ms" => cfg.backoff_cap_ms = num()?,
                        "breaker_threshold" => cfg.breaker_threshold = num()?,
                        "breaker_alpha" => cfg.breaker_alpha = num()?,
                        "breaker_open_ms" => cfg.breaker_open_ms = num()?,
                        "breaker_min_samples" => cfg.breaker_min_samples = num()? as u32,
                        "timeout_ms" => cfg.request_timeout_ms = num()?,
                        other => anyhow::bail!("unknown resilience key {other:?}"),
                    }
                }
            }
        }
        Ok(cfg)
    }
}

/// Per-pool health as routing sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolHealth {
    /// Serving normally — routable.
    Healthy,
    /// Breaker open (error/timeout EWMA tripped): routed around until a
    /// half-open probe succeeds.
    Degraded,
    /// Inside a fault-schedule dark window: not serving at all.
    Dark,
}

/// Circuit-breaker state of one pool.
#[derive(Clone, Copy, Debug, PartialEq)]
enum BreakerState {
    Closed,
    /// Tripped at `since_ms`; no traffic until the open window elapses.
    Open { since_ms: f64 },
    /// One probe in flight; its outcome closes or re-opens.
    HalfOpen,
}

/// Per-pool completion statistics + breaker.
#[derive(Clone, Debug)]
struct PoolStats {
    ewma: f64,
    samples: u32,
    state: BreakerState,
}

/// The health view: per-pool error/timeout EWMAs, circuit breakers and
/// the retry token bucket, updated from completion records and
/// consulted by routing. One instance per run; the live server guards
/// it with a mutex (off the per-request fast path), the DES owns it
/// directly. All methods take explicit `now_ms`, so both clocks work.
#[derive(Clone, Debug)]
pub struct HealthView {
    cfg: ResilienceConfig,
    pools: Vec<PoolStats>,
    tokens: f64,
    last_refill_ms: f64,
    /// Breaker trips (closed → open transitions) across the run.
    pub breaker_trips: u64,
}

impl HealthView {
    pub fn new(n_pools: usize, cfg: ResilienceConfig) -> HealthView {
        let tokens = cfg.retry_budget;
        HealthView {
            cfg,
            pools: vec![PoolStats { ewma: 0.0, samples: 0, state: BreakerState::Closed }; n_pools],
            tokens,
            last_refill_ms: 0.0,
            breaker_trips: 0,
        }
    }

    pub fn config(&self) -> &ResilienceConfig {
        &self.cfg
    }

    /// The pool's health at `now_ms`: the fault schedule's dark windows
    /// dominate, then the breaker.
    pub fn health(&self, pool: usize, now_ms: f64, faults: &FaultPlan) -> PoolHealth {
        if faults.is_dark_at_ms(pool, now_ms) {
            return PoolHealth::Dark;
        }
        if !self.cfg.enabled {
            return PoolHealth::Healthy;
        }
        match self.pools[pool].state {
            BreakerState::Closed | BreakerState::HalfOpen => PoolHealth::Healthy,
            BreakerState::Open { .. } => PoolHealth::Degraded,
        }
    }

    /// May routing send new work to `pool` at `now_ms`? Transitions an
    /// expired open breaker to half-open (admitting the probe), which
    /// is why this takes `&mut self`. With resilience disabled this is
    /// the historical constant `true`.
    pub fn routable(&mut self, pool: usize, now_ms: f64, faults: &FaultPlan) -> bool {
        if faults.is_dark_at_ms(pool, now_ms) {
            return false;
        }
        if !self.cfg.enabled {
            return true;
        }
        match self.pools[pool].state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open { since_ms } => {
                if now_ms - since_ms >= self.cfg.breaker_open_ms {
                    self.pools[pool].state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record one completion on `pool`: `ok = false` for an engine
    /// error, panic or timeout. Updates the error EWMA and drives the
    /// breaker state machine; returns `true` when this completion
    /// tripped the breaker open.
    pub fn record(&mut self, pool: usize, ok: bool, now_ms: f64) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        let a = self.cfg.breaker_alpha.clamp(1e-6, 1.0);
        let st = &mut self.pools[pool];
        st.samples = st.samples.saturating_add(1);
        st.ewma += a * ((if ok { 0.0 } else { 1.0 }) - st.ewma);
        match st.state {
            BreakerState::HalfOpen => {
                if ok {
                    // Probe succeeded: close and forgive the history.
                    st.state = BreakerState::Closed;
                    st.ewma = 0.0;
                } else {
                    st.state = BreakerState::Open { since_ms: now_ms };
                }
                false
            }
            BreakerState::Closed
                if st.ewma > self.cfg.breaker_threshold
                    && st.samples >= self.cfg.breaker_min_samples =>
            {
                st.state = BreakerState::Open { since_ms: now_ms };
                self.breaker_trips += 1;
                true
            }
            _ => false,
        }
    }

    /// May a request on retry attempt `attempt` (1-based) re-enqueue?
    /// Checks the per-request cap, then spends one token from the
    /// budget bucket (refilled at the configured rate). With resilience
    /// disabled this is the historical constant `false` — failures are
    /// terminal.
    pub fn try_retry(&mut self, attempt: u32, now_ms: f64) -> bool {
        if !self.cfg.enabled || attempt > self.cfg.max_retries {
            return false;
        }
        // Monotone refill (live threads may observe slightly unordered
        // wall clocks; never refill backwards).
        if now_ms > self.last_refill_ms {
            let dt_s = (now_ms - self.last_refill_ms) / 1e3;
            self.tokens = (self.tokens + dt_s * self.cfg.retry_refill_per_s)
                .min(self.cfg.retry_budget.max(1.0));
            self.last_refill_ms = now_ms;
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

impl Topology {
    /// The nearest surviving pool to fail `from`'s traffic over to:
    /// walk [`spill_order`](Topology::spill_order) (the same victim
    /// order the spill plane uses), keep the routable candidates, and
    /// pick the fastest (lowest `speed_factor` — the spill gate's
    /// costing), breaking ties by walk order. `None` when no other
    /// pool is routable.
    pub fn failover_pool(
        &self,
        from: usize,
        mut routable: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        let mut best: Option<usize> = None;
        for q in self.spill_order(from) {
            if !routable(q) {
                continue;
            }
            best = match best {
                Some(b) if self.speed(q) >= self.speed(b) => Some(b),
                _ => Some(q),
            };
        }
        best
    }

    /// Health-aware rung-band routing: [`pool_for_rung`](Topology::pool_for_rung),
    /// failing over to the nearest surviving pool when the band's home
    /// pool is dark or degraded. Returns `(pool, failed_over)`. With
    /// every pool routable this is exactly `pool_for_rung` (the
    /// disabled-resilience path never calls in with a false predicate).
    pub fn pool_for_rung_routable(
        &self,
        rung: usize,
        mut routable: impl FnMut(usize) -> bool,
    ) -> (usize, bool) {
        let home = self.pool_for_rung(rung);
        if routable(home) {
            return (home, false);
        }
        match self.failover_pool(home, routable) {
            Some(p) => (p, true),
            // Nowhere to go: keep the home pool (its drain/reject
            // accounting still conserves every request).
            None => (home, false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::pool::parse_pools;
    use crate::workload::Fault;

    fn enabled() -> ResilienceConfig {
        ResilienceConfig::enabled()
    }

    #[test]
    fn disabled_config_is_inert() {
        let cfg = ResilienceConfig::default();
        assert!(!cfg.enabled);
        let mut hv = HealthView::new(2, cfg);
        let plan = FaultPlan::none();
        assert!(hv.routable(0, 1e6, &plan));
        assert_eq!(hv.health(0, 1e6, &plan), PoolHealth::Healthy);
        assert!(!hv.try_retry(1, 1e6), "disabled: failures are terminal");
        for _ in 0..100 {
            assert!(!hv.record(0, false, 1.0), "disabled: breaker never trips");
        }
        assert_eq!(hv.breaker_trips, 0);
        assert_eq!(hv.config().backoff_ms(3), 0.0);
        assert!(!hv.config().timed_out(1e9));
    }

    #[test]
    fn dark_windows_dominate_health() {
        let plan =
            FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 10.0, until_s: Some(20.0) });
        let mut hv = HealthView::new(2, enabled());
        assert_eq!(hv.health(1, 9_999.0, &plan), PoolHealth::Healthy);
        assert_eq!(hv.health(1, 15_000.0, &plan), PoolHealth::Dark);
        assert!(!hv.routable(1, 15_000.0, &plan));
        // Recovery: the instant the window closes, routing remaps back.
        assert_eq!(hv.health(1, 20_000.0, &plan), PoolHealth::Healthy);
        assert!(hv.routable(1, 20_000.0, &plan));
        // Dark trumps the breaker even when disabled resilience-wise.
        let mut off = HealthView::new(2, ResilienceConfig::default());
        assert!(!off.routable(1, 15_000.0, &plan));
    }

    #[test]
    fn breaker_trips_opens_and_half_open_probes_back() {
        let cfg = ResilienceConfig {
            breaker_threshold: 0.5,
            breaker_alpha: 0.5,
            breaker_min_samples: 4,
            breaker_open_ms: 100.0,
            ..enabled()
        };
        let mut hv = HealthView::new(1, cfg);
        let plan = FaultPlan::none();
        // Failures drive the EWMA up; the trip needs min samples.
        let mut tripped_at = None;
        for i in 0..10 {
            if hv.record(0, false, i as f64) {
                tripped_at = Some(i);
                break;
            }
        }
        let t = tripped_at.expect("persistent failures must trip the breaker") as f64;
        assert_eq!(hv.breaker_trips, 1);
        assert_eq!(hv.health(0, t, &plan), PoolHealth::Degraded);
        assert!(!hv.routable(0, t + 50.0, &plan), "open: routed around");
        // The open window elapses: the next routing check admits a probe.
        assert!(hv.routable(0, t + 100.0, &plan), "half-open admits the probe");
        assert_eq!(hv.health(0, t + 100.0, &plan), PoolHealth::Healthy);
        // Probe succeeds: closed, history forgiven.
        assert!(!hv.record(0, true, t + 110.0));
        assert!(hv.routable(0, t + 111.0, &plan));
        assert_eq!(hv.breaker_trips, 1, "closing is not a trip");
        // Trip again, then fail the probe: straight back to open.
        for i in 0..10 {
            hv.record(0, false, t + 200.0 + i as f64);
        }
        assert_eq!(hv.breaker_trips, 2);
        assert!(hv.routable(0, t + 400.0, &plan));
        hv.record(0, false, t + 401.0);
        assert!(!hv.routable(0, t + 402.0, &plan), "failed probe re-opens");
    }

    #[test]
    fn retry_budget_caps_and_refills() {
        let cfg = ResilienceConfig {
            max_retries: 3,
            retry_budget: 2.0,
            retry_refill_per_s: 1.0,
            ..enabled()
        };
        let mut hv = HealthView::new(1, cfg);
        assert!(!hv.try_retry(4, 0.0), "attempts past the cap are denied");
        assert!(hv.try_retry(1, 0.0));
        assert!(hv.try_retry(1, 0.0));
        assert!(!hv.try_retry(1, 0.0), "bucket exhausted");
        // One second refills one token.
        assert!(hv.try_retry(2, 1000.0));
        assert!(!hv.try_retry(2, 1000.0));
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let cfg = ResilienceConfig { backoff_base_ms: 10.0, backoff_cap_ms: 50.0, ..enabled() };
        assert_eq!(cfg.backoff_ms(1), 10.0);
        assert_eq!(cfg.backoff_ms(2), 20.0);
        assert_eq!(cfg.backoff_ms(3), 40.0);
        assert_eq!(cfg.backoff_ms(4), 50.0, "capped");
        assert_eq!(cfg.backoff_ms(0), 0.0);
    }

    #[test]
    fn failover_picks_the_fastest_surviving_pool() {
        let pools = parse_pools("fast:2:1.0,mid:2:1.5,slow:2:2.5").unwrap();
        let t = Topology::from_pools(&pools, 0.0).unwrap();
        // Pool 2's band fails over to the fastest survivor.
        assert_eq!(t.failover_pool(2, |_| true), Some(0));
        assert_eq!(t.failover_pool(2, |q| q != 0), Some(1));
        assert_eq!(t.failover_pool(2, |_| false), None);
        // Routable home pool: no failover.
        let n_rungs = 3;
        let rung_of_pool2 = 2.min(n_rungs - 1);
        assert_eq!(t.pool_for_rung_routable(rung_of_pool2, |_| true), (2, false));
        // Dark home pool: remapped, flagged.
        let (p, moved) = t.pool_for_rung_routable(rung_of_pool2, |q| q != 2);
        assert_eq!((p, moved), (0, true));
        // No survivor anywhere: keep home (drain accounting conserves).
        assert_eq!(t.pool_for_rung_routable(rung_of_pool2, |_| false), (2, false));
    }

    #[test]
    fn timeout_gate_requires_enabled_and_positive() {
        let mut cfg = ResilienceConfig { request_timeout_ms: 100.0, ..enabled() };
        assert!(cfg.timed_out(101.0));
        assert!(!cfg.timed_out(100.0));
        cfg.request_timeout_ms = 0.0;
        assert!(!cfg.timed_out(1e9));
        let off = ResilienceConfig { request_timeout_ms: 100.0, ..Default::default() };
        assert!(!off.timed_out(1e9));
    }

    #[test]
    fn parse_roundtrips_the_knobs() {
        assert!(!ResilienceConfig::parse("off").unwrap().enabled);
        let cfg = ResilienceConfig::parse("on,max_retries=5,breaker_threshold=0.3,timeout_ms=250")
            .unwrap();
        assert!(cfg.enabled);
        assert_eq!(cfg.max_retries, 5);
        assert_eq!(cfg.breaker_threshold, 0.3);
        assert_eq!(cfg.request_timeout_ms, 250.0);
        assert!(ResilienceConfig::parse("on,nope=1").is_err());
        assert!(ResilienceConfig::parse("garbage").is_err());
    }
}
