//! Bounded lock-free MPMC ring buffer — the shard storage of the
//! lock-free queue backend.
//!
//! This is the classic Vyukov bounded MPMC queue (the design vendored
//! by `crossbeam::ArrayQueue`), reimplemented std-only because the
//! build environment has no registry access. Each slot carries its own
//! **sequence counter** that encodes the slot's state relative to the
//! global head/tail positions, which lets producers and consumers
//! coordinate through one CAS on their own end of the ring plus
//! acquire/release handshakes on the slot itself — no locks, no
//! spinning on a shared flag.
//!
//! # Slot states
//!
//! Head, tail and every sequence counter are monotonically increasing
//! `u64` *positions* (never wrapped; the index into the buffer is
//! `pos & mask`). For a slot at position `pos` with capacity `cap`:
//!
//! | `seq` value | state |
//! |---|---|
//! | `pos`       | empty — waiting for the producer that claims `tail == pos` |
//! | `pos + 1`   | full — value written, waiting for the consumer that claims `head == pos` |
//! | `pos + cap` | empty again, one lap later — waiting for `tail == pos + cap` |
//!
//! A producer loads the slot at `tail`: `seq == tail` means the slot is
//! free, so it CASes `tail → tail+1` to claim it, writes the value, and
//! publishes with `seq = tail + 1` (release). `seq < tail` means the
//! consumer of the *previous lap* has not yet released the slot — the
//! ring is full, and we report that instead of blocking (the admission
//! path turns it into [`QueueError::Full`]). `seq > tail` means our
//! tail load was stale; reload and retry. Consumers mirror this on
//! `head` with `seq == head + 1` as the ready condition and
//! `seq = head + cap` as the release.
//!
//! # Batch claim: one CAS per steal
//!
//! [`MpmcRing::pop_run_into`] reserves a *run* of consecutive committed
//! slots with a **single CAS on `head`**: scan forward from `head`
//! counting slots whose `seq == pos + 1` (acquire), then
//! `head.compare_exchange(h, h + n)`. On success the caller owns all
//! `n` slots exclusively — producers cannot recycle a slot until `head`
//! passes it, so the values can be read out and released one by one at
//! leisure. This is what preserves the queue's "steal-half is ONE
//! operation" contract (one steal-counter increment, one atomicity
//! unit) that the mutex backend gets for free from its critical
//! section; element-at-a-time CAS would make a steal interleavable and
//! break the pinned accounting.
//!
//! # Wraparound
//!
//! Positions are `u64` and never masked, so overflow would take
//! centuries at any realistic rate; correctness across index growth is
//! still tested past the `u32` boundary via [`MpmcRing::with_base`],
//! which starts head/tail/sequences at an arbitrary lap instead of 0.
//!
//! [`QueueError::Full`]: super::queue::QueueError::Full

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::CachePadded;

/// One ring slot: its sequence counter plus (possibly uninitialized)
/// storage for the value. See the module docs for the `seq` protocol.
struct Slot<T> {
    seq: AtomicU64,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free MPMC ring (Vyukov sequence-counter protocol).
///
/// Capacity is rounded up to a power of two so `pos & mask` replaces a
/// division on every access. `head` and `tail` live on their own cache
/// lines: producers hammer `tail`, consumers hammer `head`, and without
/// padding each CAS would invalidate the other side's line.
pub struct MpmcRing<T> {
    buf: Box<[Slot<T>]>,
    /// `capacity - 1`; capacity is a power of two.
    mask: u64,
    cap: u64,
    head: CachePadded<AtomicU64>,
    tail: CachePadded<AtomicU64>,
}

// SAFETY: values are handed across threads through the slot protocol —
// a slot's value is written exclusively by the producer that claimed it
// and read exclusively by the consumer that claimed it, with the
// release/acquire pair on `seq` ordering the handoff. `T: Send`
// suffices; no `&T` is ever shared.
unsafe impl<T: Send> Send for MpmcRing<T> {}
unsafe impl<T: Send> Sync for MpmcRing<T> {}

impl<T> MpmcRing<T> {
    /// Ring with room for at least `capacity` items (rounded up to a
    /// power of two, minimum 1).
    pub fn new(capacity: usize) -> Self {
        Self::with_base(capacity, 0)
    }

    /// Ring whose positions start at `base` (rounded down to a lap
    /// boundary) instead of 0 — equivalent to a ring that has already
    /// completed `base / capacity` laps. Test-only in spirit: it makes
    /// sequence-counter wraparound past any index scale checkable in
    /// microseconds instead of centuries.
    pub fn with_base(capacity: usize, base: u64) -> Self {
        let cap = capacity.max(1).next_power_of_two() as u64;
        let base = base & !(cap - 1);
        let buf: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicU64::new(base + i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        MpmcRing {
            buf,
            mask: cap - 1,
            cap,
            head: CachePadded::new(AtomicU64::new(base)),
            tail: CachePadded::new(AtomicU64::new(base)),
        }
    }

    /// Usable capacity (power of two).
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Snapshot of the occupancy. Racy by nature (two independent
    /// loads) but monotonically consistent enough for sizing a batch
    /// claim — the claim itself re-validates per slot.
    pub fn len(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.saturating_sub(head) as usize
    }

    /// True when the snapshot sees no items.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append `item`, or hand it back if the ring is full.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[(tail & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            match seq.cmp(&tail) {
                std::cmp::Ordering::Equal => {
                    // Slot free at our tail: claim the position.
                    match self.tail.compare_exchange_weak(
                        tail,
                        tail + 1,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            // SAFETY: the CAS gave us exclusive write
                            // access to this slot until we publish.
                            unsafe { (*slot.val.get()).write(item) };
                            slot.seq.store(tail + 1, Ordering::Release);
                            return Ok(());
                        }
                        Err(cur) => tail = cur,
                    }
                }
                // Previous lap's consumer hasn't released it: full.
                std::cmp::Ordering::Less => return Err(item),
                // Stale tail: another producer advanced it; reload.
                std::cmp::Ordering::Greater => tail = self.tail.load(Ordering::Relaxed),
            }
        }
    }

    /// Take the front item, or `None` if the ring is empty.
    pub fn pop(&self) -> Option<T> {
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[(head & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            match seq.cmp(&(head + 1)) {
                std::cmp::Ordering::Equal => {
                    match self.head.compare_exchange_weak(
                        head,
                        head + 1,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            // SAFETY: the CAS gave us exclusive read
                            // access; the value was published by the
                            // release store that set `seq = head + 1`.
                            let item = unsafe { (*slot.val.get()).assume_init_read() };
                            slot.seq.store(head + self.cap, Ordering::Release);
                            return Some(item);
                        }
                        Err(cur) => head = cur,
                    }
                }
                // `seq <= head`: nothing committed at the front.
                std::cmp::Ordering::Less => return None,
                // Stale head: another consumer advanced it; reload.
                std::cmp::Ordering::Greater => head = self.head.load(Ordering::Relaxed),
            }
        }
    }

    /// Claim up to `want` consecutive committed items **in one CAS on
    /// `head`** and append them to `out`, returning how many were
    /// taken (0 = empty). This is the batch/steal primitive: the whole
    /// run is reserved atomically, so a concurrent consumer either
    /// sees the run before the claim or after it — never mid-claim.
    pub fn pop_run_into(&self, want: usize, out: &mut Vec<T>) -> usize {
        let limit = (want.min(self.cap as usize)) as u64;
        if limit == 0 {
            return 0;
        }
        loop {
            let head = self.head.load(Ordering::Relaxed);
            // Scan the committed run: slots whose value is published
            // for exactly this lap.
            let mut n = 0u64;
            while n < limit {
                let pos = head + n;
                let slot = &self.buf[(pos & self.mask) as usize];
                if slot.seq.load(Ordering::Acquire) != pos + 1 {
                    break;
                }
                n += 1;
            }
            if n == 0 {
                let front = &self.buf[(head & self.mask) as usize];
                if front.seq.load(Ordering::Acquire) <= head {
                    // Nothing committed at the front: genuinely empty.
                    return 0;
                }
                // Our head was stale (or a value landed between the
                // scan and this check): retry with a fresh head.
                continue;
            }
            // ONE CAS reserves the whole run [head, head + n). After it
            // succeeds, producers still cannot recycle these slots —
            // a slot is only reusable once its consumer releases it —
            // so the reads below are unhurried and exclusive.
            if self
                .head
                .compare_exchange(head, head + n, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                for i in 0..n {
                    let pos = head + i;
                    let slot = &self.buf[(pos & self.mask) as usize];
                    // SAFETY: the run claim above gave us exclusive
                    // read access to every slot in [head, head + n).
                    out.push(unsafe { (*slot.val.get()).assume_init_read() });
                    slot.seq.store(pos + self.cap, Ordering::Release);
                }
                return n as usize;
            }
        }
    }
}

impl<T> Drop for MpmcRing<T> {
    fn drop(&mut self) {
        // Drop any values still in flight; slots outside [head, tail)
        // are uninitialized and must not be touched.
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn spsc_preserves_fifo_order() {
        let ring = MpmcRing::new(8);
        for i in 0..8 {
            ring.push(i).unwrap();
        }
        for i in 0..8 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert_eq!(ring.pop(), None);
        // Refill after a full drain: sequence counters advanced a lap.
        ring.push(99).unwrap();
        assert_eq!(ring.pop(), Some(99));
    }

    #[test]
    fn full_ring_hands_the_item_back() {
        let ring = MpmcRing::new(3); // rounds up to 4
        assert_eq!(ring.capacity(), 4);
        for i in 0..4 {
            ring.push(i).unwrap();
        }
        assert_eq!(ring.push(4), Err(4));
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.pop(), Some(0));
        ring.push(4).unwrap(); // space again
        assert_eq!(ring.len(), 4);
    }

    #[test]
    fn batch_claim_takes_a_front_run_in_one_reservation() {
        let ring = MpmcRing::new(16);
        for i in 0..10u64 {
            ring.push(i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(ring.pop_run_into(4, &mut out), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        // `want` past the committed run is clamped to what's there.
        out.clear();
        assert_eq!(ring.pop_run_into(64, &mut out), 6);
        assert_eq!(out, vec![4, 5, 6, 7, 8, 9]);
        out.clear();
        assert_eq!(ring.pop_run_into(4, &mut out), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn sequence_counters_survive_wraparound_past_u32_indices() {
        // Start the ring as if it had already completed ~2^32 / cap
        // laps; push/pop across the u32 boundary must stay FIFO and
        // conserve items.
        let cap = 8u64;
        let base = (1u64 << 32) - cap;
        let ring = MpmcRing::with_base(cap as usize, base);
        for i in 0..cap * 3 {
            ring.push(i).unwrap();
            if i >= cap - 1 {
                // Keep one lap in flight while positions cross 2^32.
                assert_eq!(ring.pop(), Some(i + 1 - cap));
            }
        }
        let mut out = Vec::new();
        assert_eq!(ring.pop_run_into(cap as usize, &mut out), cap as usize - 1);
        assert_eq!(out, (cap * 2 + 1..cap * 3).collect::<Vec<_>>());
        assert!(ring.is_empty());
    }

    #[test]
    fn mpmc_conserves_under_racing_producers_and_consumers() {
        use std::sync::atomic::AtomicBool;

        let n_prod = 4u64;
        let per = 2000u64;
        let total = (n_prod * per) as usize;
        let ring = Arc::new(MpmcRing::new(64)); // far smaller than total: laps + backpressure
        let done = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for p in 0..n_prod {
            let r = Arc::clone(&ring);
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    let mut item = p * per + i;
                    // Bounded ring: spin on Full until a consumer frees a slot.
                    while let Err(back) = r.push(item) {
                        item = back;
                        thread::yield_now();
                    }
                }
            }));
        }
        let mut takers = Vec::new();
        for c in 0..4 {
            let r = Arc::clone(&ring);
            let d = Arc::clone(&done);
            takers.push(thread::spawn(move || {
                let mut got = Vec::new();
                let mut buf = Vec::new();
                loop {
                    // Alternate single pops and batch claims to cover both paths.
                    let took = if c % 2 == 0 {
                        match r.pop() {
                            Some(v) => {
                                got.push(v);
                                true
                            }
                            None => false,
                        }
                    } else {
                        buf.clear();
                        let n = r.pop_run_into(7, &mut buf);
                        got.extend_from_slice(&buf);
                        n > 0
                    };
                    if !took {
                        // A transient empty is not the end: keep draining
                        // until the producers are done AND the ring is dry
                        // (exiting early would leave producers spinning on
                        // a full ring with nobody consuming).
                        if d.load(Ordering::SeqCst) && r.is_empty() {
                            break;
                        }
                        thread::yield_now();
                    }
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        done.store(true, Ordering::SeqCst);
        // Racing consumers may exit on the same "dry" observation; any
        // leftover items would be a bug the count below catches.
        let mut seen: Vec<u64> = takers.into_iter().flat_map(|t| t.join().unwrap()).collect();
        while let Some(v) = ring.pop() {
            seen.push(v);
        }
        assert_eq!(seen.len(), total, "no loss, no duplication");
        let unique: HashSet<u64> = seen.iter().copied().collect();
        assert_eq!(unique.len(), total, "every item exactly once");
    }
}
