//! The dispatch topology core — every routing/steal/spill/batch *choice*
//! of the serving plane, as pure functions over an abstract shard-state
//! view.
//!
//! Compass's premise is that the Planner's offline model and Elastico's
//! runtime agree on how requests are dispatched. Before this module that
//! agreement was pinned by parity tests between five hand-kept copies of
//! the same walk (the live [`crate::serving::queue::ShardedQueue`] and
//! four DES loops); now it holds **by construction**: the live queue and
//! the one DES engine ([`crate::sim::simulate_topology`]) both delegate
//! every decision to a [`Topology`] and keep only their own mechanics
//! (locks/parking/atomics live, the event clock and rng simulated).
//!
//! A [`Topology`] owns the decisions and nothing else — no locking, no
//! time, no queue state. It answers:
//!
//! * **shard layout** — which contiguous shard range belongs to which
//!   pool ([`shard_range`](Topology::shard_range),
//!   [`shard_pool`](Topology::shard_pool));
//! * **routing** — round-robin within a pool ([`route`](Topology::route))
//!   and rung band → pool resolution
//!   ([`pool_for_rung`](Topology::pool_for_rung));
//! * **dispatch order** — the home-shard-then-steal walk over a pool's
//!   own shards ([`pool_walk`](Topology::pool_walk)) and the cyclic
//!   spill order over the other pools
//!   ([`spill_order`](Topology::spill_order));
//! * **spill admission** — the cost-aware spill gate
//!   ([`spill_allowed`](Topology::spill_allowed)): with a positive
//!   [`spill_margin`](Topology::spill_margin), a pool poaches foreign
//!   work only when the victim's backlog exceeds the spiller's speed
//!   handicap; margin 0 (the default) is the historical spill-when-dry;
//! * **batch extent** — the front-run / steal-half arithmetic
//!   ([`take_count`](Topology::take_count)): a home dispatch drains up
//!   to B of its shard, a steal or spill takes `⌈len/2⌉` capped at B;
//! * **execution rung** — the policy rung clamped into a pool's band
//!   ([`exec_rung`](Topology::exec_rung)) and the pool's service-time
//!   scale ([`speed`](Topology::speed)).
//!
//! Shard *state* is always passed in (`len(shard)`, per-pool backlogs),
//! so the same choice functions run against locked `VecDeque`s on the
//! live path and plain vectors in the DES.

use anyhow::Result;

use super::pool::{pool_of_rung, pool_rung, validate_pools, PoolSpec};

/// How a dispatch reached its shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// The consumer's home shard (front run, FIFO).
    Home,
    /// A non-home shard of the consumer's own pool (steal-half).
    Steal,
    /// A shard of another pool (spill-half, gated by the margin).
    Spill,
}

/// A dispatch-plane topology: named pools, their shard partition, and
/// the spill-admission margin. Construction validates the pool specs
/// (bands strictly increasing from 0, positive speeds, ≥ 1 worker).
#[derive(Clone, Debug)]
pub struct Topology {
    pools: Vec<PoolSpec>,
    /// Half-open shard ranges per pool (contiguous, in pool order).
    pool_ranges: Vec<(usize, usize)>,
    /// Owning pool of each shard.
    shard_pool: Vec<usize>,
    /// Cost-aware spill margin m: pool `p` spills into pool `q` only
    /// when `len(q) > m · (speed_p / speed_q) · workers_q`. 0 = the
    /// historical spill-when-dry (any non-empty victim).
    spill_margin: f64,
}

impl Topology {
    /// Build a topology from pools and their shard counts.
    pub fn new(
        pools: Vec<PoolSpec>,
        pool_shards: Vec<usize>,
        spill_margin: f64,
    ) -> Result<Topology> {
        validate_pools(&pools)?;
        anyhow::ensure!(
            pools.len() == pool_shards.len(),
            "{} pools but {} shard counts",
            pools.len(),
            pool_shards.len()
        );
        let mut pool_ranges = Vec::with_capacity(pools.len());
        let mut shard_pool = Vec::new();
        let mut start = 0usize;
        for (p, &n) in pool_shards.iter().enumerate() {
            let n = n.max(1);
            pool_ranges.push((start, start + n));
            for _ in 0..n {
                shard_pool.push(p);
            }
            start += n;
        }
        Ok(Topology {
            pools,
            pool_ranges,
            shard_pool,
            spill_margin: spill_margin.max(0.0),
        })
    }

    /// The homogeneous topology: one reference-speed pool of `workers`
    /// servers over `shards` shards. `shards == 1` is the central FIFO;
    /// `shards == workers` the per-worker sharded discipline.
    pub fn uniform(workers: usize, shards: usize) -> Topology {
        Topology::new(vec![PoolSpec::uniform(workers)], vec![shards.max(1)], 0.0)
            .expect("uniform topology is always valid")
    }

    /// The heterogeneous-fleet topology: one shard per worker per pool
    /// (the pooled runtime layout).
    pub fn from_pools(pools: &[PoolSpec], spill_margin: f64) -> Result<Topology> {
        let shards = pools.iter().map(|p| p.workers.max(1)).collect();
        Topology::new(pools.to_vec(), shards, spill_margin)
    }

    /// Anonymous uniform-speed pools over a bare shard partition — the
    /// pool-agnostic queue constructors, where only the shard layout
    /// matters (no bands, no speed asymmetry, no spill gate).
    pub(crate) fn anonymous(pool_shards: &[usize]) -> Topology {
        let pools = pool_shards
            .iter()
            .enumerate()
            .map(|(i, &n)| PoolSpec::new(format!("pool{i}"), n.max(1), i, 1.0))
            .collect();
        Topology::new(pools, pool_shards.to_vec(), 0.0)
            .expect("anonymous topology is always valid")
    }

    /// The pool specs, in shard order.
    pub fn pools(&self) -> &[PoolSpec] {
        &self.pools
    }

    pub fn n_pools(&self) -> usize {
        self.pools.len()
    }

    pub fn n_shards(&self) -> usize {
        self.shard_pool.len()
    }

    /// Total servers/executor threads across the fleet.
    pub fn n_workers(&self) -> usize {
        super::pool::total_workers(&self.pools)
    }

    /// The spill-admission margin (0 = spill-when-dry).
    pub fn spill_margin(&self) -> f64 {
        self.spill_margin
    }

    /// Half-open shard range `[lo, hi)` of a pool.
    pub fn shard_range(&self, pool: usize) -> (usize, usize) {
        self.pool_ranges[pool]
    }

    /// Owning pool of a shard.
    pub fn shard_pool(&self, shard: usize) -> usize {
        self.shard_pool[shard]
    }

    /// Home shard of pool-local consumer `worker`.
    pub fn home_shard(&self, pool: usize, worker: usize) -> usize {
        let (lo, hi) = self.pool_ranges[pool];
        lo + worker % (hi - lo)
    }

    /// Round-robin routing: the shard of `pool` a producer's `cursor`-th
    /// push lands on.
    pub fn route(&self, pool: usize, cursor: usize) -> usize {
        let (lo, hi) = self.pool_ranges[pool];
        lo + cursor % (hi - lo)
    }

    /// The pool whose rung band contains `rung` (rung-aware routing).
    pub fn pool_for_rung(&self, rung: usize) -> usize {
        pool_of_rung(&self.pools, rung)
    }

    /// The rung `pool` executes when the policy sits at `policy_rung`:
    /// the policy rung clamped into the pool's band.
    pub fn exec_rung(&self, pool: usize, policy_rung: usize, n_rungs: usize) -> usize {
        pool_rung(&self.pools, pool, policy_rung, n_rungs)
    }

    /// Service-time multiplier of a pool vs the reference hardware.
    pub fn speed(&self, pool: usize) -> f64 {
        self.pools[pool].speed_factor
    }

    /// The within-pool dispatch walk of pool-local consumer `worker`:
    /// its home shard first, then the pool's other shards in cyclic
    /// order (each a steal candidate). Both the live queue and the DES
    /// take the first non-empty shard of this walk.
    pub fn pool_walk(
        &self,
        pool: usize,
        worker: usize,
    ) -> impl Iterator<Item = (usize, Dispatch)> + '_ {
        let (lo, hi) = self.pool_ranges[pool];
        let n = hi - lo;
        let home = worker % n;
        (0..n).map(move |d| {
            let kind = if d == 0 { Dispatch::Home } else { Dispatch::Steal };
            (lo + (home + d) % n, kind)
        })
    }

    /// The spill sweep order: every *other* pool in cyclic order from
    /// the consumer's pool (a consumer tries each victim's shards from
    /// its first shard). Empty on a single-pool topology.
    pub fn spill_order(&self, pool: usize) -> impl Iterator<Item = usize> + '_ {
        let np = self.pools.len();
        (1..np).map(move |d| (pool + d) % np)
    }

    /// Cost-aware spill gate: may pool `from` poach pool `victim`'s work
    /// given the victim's queued backlog?
    ///
    /// Poaching pays only when the request would otherwise wait longer
    /// for a victim worker than the spiller's (relatively) slow hardware
    /// takes to run it, so the gate compares the victim's *per-worker*
    /// backlog against the spiller's speed handicap:
    /// `len > margin · (speed_from / speed_victim) · workers_victim`.
    /// Margin 0 degenerates to spill-when-dry (any non-empty victim —
    /// the historical behavior, pinned bit-for-bit by the parity tests).
    pub fn spill_allowed(&self, from: usize, victim: usize, victim_backlog: usize) -> bool {
        self.spill_allowed_with(from, victim, victim_backlog, self.spill_margin)
    }

    /// [`spill_allowed`](Self::spill_allowed) against an explicit margin
    /// instead of the topology's static one — the online re-planner
    /// ([`crate::serving::replan`]) raises the effective margin as the
    /// fleet saturates without rebuilding the topology. At
    /// `margin == self.spill_margin()` this is the same arithmetic.
    pub fn spill_allowed_with(
        &self,
        from: usize,
        victim: usize,
        victim_backlog: usize,
        margin: f64,
    ) -> bool {
        if victim_backlog == 0 {
            return false;
        }
        if margin <= 0.0 {
            return true;
        }
        let handicap = self.pools[from].speed_factor / self.pools[victim].speed_factor;
        let workers = self.pools[victim].workers.max(1) as f64;
        victim_backlog as f64 > margin * handicap * workers
    }

    /// Is there any work a consumer of `pool` may take right now —
    /// its own pool's backlog, or a foreign backlog passing the spill
    /// gate? (`pool_len` is the caller's per-pool depth view.) Drives
    /// the park/wake decision of the live queue.
    pub fn can_take(&self, pool: usize, pool_len: impl Fn(usize) -> usize) -> bool {
        self.can_take_with(pool, pool_len, self.spill_margin)
    }

    /// [`can_take`](Self::can_take) against an explicit spill margin
    /// (see [`spill_allowed_with`](Self::spill_allowed_with)).
    pub fn can_take_with(
        &self,
        pool: usize,
        pool_len: impl Fn(usize) -> usize,
        margin: f64,
    ) -> bool {
        if pool_len(pool) > 0 {
            return true;
        }
        self.spill_order(pool)
            .any(|q| self.spill_allowed_with(pool, q, pool_len(q), margin))
    }

    /// Batch extent: how many of a shard's `len` queued items one
    /// dispatch takes under batch bound `max` — a front run
    /// (`min(len, max)`) at home, half the victim's backlog (`⌈len/2⌉`,
    /// capped at `max`, leaving the victim work) on a steal or spill.
    pub fn take_count(len: usize, max: usize, kind: Dispatch) -> usize {
        let max = max.max(1);
        match kind {
            Dispatch::Home => len.min(max),
            Dispatch::Steal | Dispatch::Spill => len.div_ceil(2).min(max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::pool::parse_pools;

    #[test]
    fn uniform_layout_and_walk() {
        let t = Topology::uniform(4, 4);
        assert_eq!(t.n_pools(), 1);
        assert_eq!(t.n_shards(), 4);
        assert_eq!(t.n_workers(), 4);
        assert_eq!(t.shard_range(0), (0, 4));
        // Worker 2's walk: home shard 2, then 3, 0, 1 as steal victims.
        let walk: Vec<_> = t.pool_walk(0, 2).collect();
        assert_eq!(walk.len(), 4);
        assert_eq!(walk[0], (2, Dispatch::Home));
        assert_eq!(walk[1], (3, Dispatch::Steal));
        assert_eq!(walk[2], (0, Dispatch::Steal));
        assert_eq!(walk[3], (1, Dispatch::Steal));
        // One pool: nothing to spill into, but home work is takeable.
        assert_eq!(t.spill_order(0).count(), 0);
        assert!(t.can_take(0, |_| 3));
        assert!(!t.can_take(0, |_| 0));
    }

    #[test]
    fn central_shape_is_one_shard_many_workers() {
        let t = Topology::uniform(8, 1);
        assert_eq!(t.n_shards(), 1);
        assert_eq!(t.n_workers(), 8);
        for w in 0..8 {
            assert_eq!(t.home_shard(0, w), 0);
            assert_eq!(t.pool_walk(0, w).count(), 1, "one shard never steals");
        }
    }

    #[test]
    fn pooled_layout_routes_and_resolves_rungs() {
        let pools = parse_pools("fast:2:1.0,accurate:2:2.5").unwrap();
        let t = Topology::from_pools(&pools, 0.0).unwrap();
        assert_eq!(t.n_shards(), 4);
        assert_eq!(t.shard_range(0), (0, 2));
        assert_eq!(t.shard_range(1), (2, 4));
        assert_eq!(t.shard_pool(3), 1);
        // Per-pool round-robin.
        assert_eq!(t.route(1, 0), 2);
        assert_eq!(t.route(1, 1), 3);
        assert_eq!(t.route(1, 2), 2);
        // Band resolution and the in-band execution rung.
        assert_eq!(t.pool_for_rung(0), 0);
        assert_eq!(t.pool_for_rung(1), 1);
        assert_eq!(t.exec_rung(1, 0, 2), 1, "slow pool clamps into its band");
        assert_eq!(t.exec_rung(0, 1, 2), 0, "fast pool clamps into its band");
        assert_eq!(t.speed(1), 2.5);
        // Spill order is cyclic over the other pools.
        assert_eq!(t.spill_order(0).collect::<Vec<_>>(), vec![1]);
        assert_eq!(t.spill_order(1).collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn take_count_front_run_and_steal_half() {
        assert_eq!(Topology::take_count(5, 8, Dispatch::Home), 5);
        assert_eq!(Topology::take_count(10, 8, Dispatch::Home), 8);
        assert_eq!(Topology::take_count(8, 8, Dispatch::Steal), 4);
        assert_eq!(Topology::take_count(5, 8, Dispatch::Steal), 3);
        assert_eq!(Topology::take_count(8, 2, Dispatch::Spill), 2);
        assert_eq!(Topology::take_count(1, 0, Dispatch::Home), 1, "max clamps to 1");
    }

    #[test]
    fn spill_gate_margin_zero_is_spill_when_dry() {
        let pools = parse_pools("fast:2:1.0,slow:2:2.5").unwrap();
        let t = Topology::from_pools(&pools, 0.0).unwrap();
        assert!(t.spill_allowed(1, 0, 1), "margin 0 poaches any backlog");
        assert!(!t.spill_allowed(1, 0, 0), "an empty victim is never poached");
    }

    #[test]
    fn spill_gate_blocks_a_slow_poacher_until_the_backlog_justifies_it() {
        // slow (2.5x) poaching fast (1x, 2 workers) at margin 1: only a
        // backlog deeper than 1 · 2.5 · 2 = 5 justifies running the
        // request 2.5x slower instead of waiting for a fast worker.
        let pools = parse_pools("fast:2:1.0,slow:2:2.5").unwrap();
        let t = Topology::from_pools(&pools, 1.0).unwrap();
        assert!(!t.spill_allowed(1, 0, 5), "shallow backlog: keep it local");
        assert!(t.spill_allowed(1, 0, 6), "deep backlog: poaching now pays");
        // The fast pool poaching the slow pool has a 1/2.5 handicap —
        // its threshold is proportionally lower (> 0.8 ⇒ any backlog).
        assert!(t.spill_allowed(0, 1, 1));
        // The park/wake predicate follows the same gate.
        assert!(!t.can_take(1, |q| if q == 0 { 4 } else { 0 }));
        assert!(t.can_take(1, |q| if q == 0 { 6 } else { 0 }));
        assert!(t.can_take(1, |q| if q == 1 { 1 } else { 0 }), "own work always");
    }

    #[test]
    fn anonymous_pools_partition_the_shards() {
        let t = Topology::anonymous(&[2, 3]);
        assert_eq!(t.n_pools(), 2);
        assert_eq!(t.n_shards(), 5);
        assert_eq!(t.shard_range(1), (2, 5));
        assert_eq!(t.spill_margin(), 0.0);
        assert!(t.pools().iter().all(|p| p.speed_factor == 1.0));
    }
}
