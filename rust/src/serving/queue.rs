//! The central request queue (paper §III-B): a bounded, thread-safe FIFO
//! buffering incoming inference requests between the arrival injector and
//! the workflow executor.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Queue errors.
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    /// Bounded capacity reached (admission control rejected the request).
    Full,
    /// Queue closed and drained.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Thread-safe bounded FIFO with blocking pop.
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    notify: Condvar,
    capacity: usize,
}

impl<T> RequestQueue<T> {
    pub fn new(capacity: usize) -> Self {
        RequestQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Enqueue; fails when full or closed.
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        g.items.push_back(item);
        drop(g);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop with timeout. `None` on timeout; `Err(Closed)` once
    /// the queue is closed **and** drained.
    ///
    /// The wait is bounded by a deadline (not restarted on every wakeup),
    /// so a consumer racing with other workers for notifications still
    /// returns within `timeout`. `close()` wakes **all** blocked
    /// consumers, and a consumer observing the close — on wakeup or on
    /// its timeout — reports `Closed` immediately rather than waiting
    /// out the remaining timeout.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, QueueError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Ok(Some(item));
            }
            if g.closed {
                return Err(QueueError::Closed);
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return Ok(None);
            }
            let (g2, _res) = self.notify.wait_timeout(g, remaining).unwrap();
            g = g2;
        }
    }

    /// Current depth (the load monitor's primary signal).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers fail afterwards; consumers drain what remains.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), Some(i));
        }
        assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), None);
    }

    #[test]
    fn capacity_enforced() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(QueueError::Full));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_errors() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(QueueError::Closed));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), Some(1));
        assert_eq!(
            q.pop_timeout(Duration::from_millis(1)),
            Err(QueueError::Closed)
        );
    }

    #[test]
    fn close_wakes_all_blocked_consumers_promptly() {
        // k workers blocked with a long timeout must all observe Closed
        // as soon as the producer closes, not after spinning out their
        // timeout (the worker-pool shutdown path).
        let q: Arc<RequestQueue<u32>> = Arc::new(RequestQueue::new(8));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let t0 = std::time::Instant::now();
                    let r = q.pop_timeout(Duration::from_secs(30));
                    (r, t0.elapsed())
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(50)); // let them block
        q.close();
        for h in handles {
            let (r, dt) = h.join().unwrap();
            assert_eq!(r, Err(QueueError::Closed));
            assert!(dt < Duration::from_secs(5), "woke only after {dt:?}");
        }
    }

    #[test]
    fn timeout_is_a_deadline_not_a_restart() {
        // Repeated notifications that yield no item must not extend the
        // wait beyond the requested timeout.
        let q: Arc<RequestQueue<u32>> = Arc::new(RequestQueue::new(8));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let r = q2.pop_timeout(Duration::from_millis(200));
            (r, t0.elapsed())
        });
        // A racing thread drains every pushed item before the consumer
        // can observe it, while still generating wakeups for ~840 ms —
        // well past the consumer's 200 ms deadline. A wait that restarts
        // its timeout on every wakeup would outlast the whole barrage.
        for _ in 0..40 {
            q.push(1).unwrap();
            while q.pop_timeout(Duration::from_millis(1)).unwrap().is_some() {}
            std::thread::sleep(Duration::from_millis(20));
        }
        let (r, dt) = consumer.join().unwrap();
        assert!(r.is_ok(), "{r:?}");
        assert!(dt < Duration::from_millis(600), "waited {dt:?}");
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(RequestQueue::new(100));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                while q2.push(i).is_err() {}
            }
            q2.close();
        });
        let mut got = Vec::new();
        loop {
            match q.pop_timeout(Duration::from_millis(50)) {
                Ok(Some(v)) => got.push(v),
                Ok(None) => {}
                Err(QueueError::Closed) => break,
                Err(e) => panic!("{e:?}"),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }
}
