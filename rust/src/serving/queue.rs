//! Request queues (paper §III-B): the buffers between the arrival
//! injector and the executor pool, in two disciplines.
//!
//! * [`RequestQueue`] — the original **central FIFO**: one bounded
//!   `Mutex<VecDeque>` every producer and consumer crosses. Exact global
//!   FIFO order, but a single coordination point that serializes the hot
//!   path at large worker counts. Kept as the reference implementation
//!   and the contended-bench baseline.
//! * [`ShardedQueue`] — the **sharded work-stealing** discipline: one
//!   bounded FIFO per shard (typically one per worker), round-robin
//!   request routing, and FIFO stealing when a worker's home shard runs
//!   dry. Admission control and the AQM depth signal stay exact via a
//!   lock-free aggregate depth counter maintained on push/pop/steal;
//!   per-shard mutexes are only ever contended by a 1/shards slice of
//!   the traffic.
//!
//! ## Semantics and known divergences
//!
//! * **Admission** is linearized on the aggregate counter in both
//!   disciplines: a push is rejected only if `capacity` slots were
//!   reserved at the instant of its reservation attempt. Slots are
//!   released when an item leaves its shard, so at most `capacity`
//!   requests are ever buffered and a request is never rejected while a
//!   slot genuinely remains (the worker-pool property tests assert
//!   this under concurrent stealing).
//! * **Ordering**: the central queue is globally FIFO. The sharded queue
//!   is FIFO *per shard* (stealing takes the victim's front, never its
//!   back, so no shard is ever drained out of order); global order can
//!   diverge by up to one round-robin lap. `sim::Discipline` models both
//!   so the DES can quantify the ordering/latency delta against theory;
//!   a single-shard [`ShardedQueue`] is the central FIFO exactly.
//! * **Stealing** follows the work-stealing scheduler of Blumofe &
//!   Leiserson's Cilk, with one queueing-theoretic change: thieves take
//!   the oldest entry (FIFO) rather than the newest (LIFO), because the
//!   objective is tail latency of queued requests, not cache locality of
//!   spawned tasks. Batch pops steal **half** the victim's backlog
//!   (capped at the batch bound) in one lock acquisition, so a dry
//!   worker refills with a single steal instead of returning to the
//!   victim once per item.
//! * **Batch dequeue**: [`ShardedQueue::pop_batch`] drains up to `max`
//!   items from the home shard under one lock (front run, FIFO), so the
//!   per-dispatch costs downstream (rung resolution, engine call setup,
//!   policy observation) are paid once per batch instead of once per
//!   request. `max == 1` is exactly [`pop_timeout`](ShardedQueue::pop_timeout)
//!   including the steal-one behavior, so the unbatched hot path is the
//!   `B = 1` case of the same code.
//! * **Depth**: [`ShardedQueue::len`] is one atomic load of the
//!   total-across-shards depth — the signal the AQM thresholds
//!   (`planner::aqm`) and the Elastico controller are derived for. Under
//!   a pooled topology, [`ShardedQueue::pool_len`] is the same signal
//!   restricted to one pool's shards.
//! * **Pools**: [`ShardedQueue::new_pooled`] partitions the shards into
//!   contiguous per-pool groups (one group per
//!   [`crate::serving::pool::PoolSpec`]). Producers route into a chosen
//!   pool ([`push_pool`](ShardedQueue::push_pool), per-pool round-robin);
//!   a pool's consumers drain and steal **within their pool only**, and
//!   **spill** into other pools' shards only once every shard of their
//!   own pool is dry ([`pop_timeout_pool`](ShardedQueue::pop_timeout_pool))
//!   — or, under a positive spill margin
//!   ([`crate::serving::topology::Topology::spill_allowed`]), only once
//!   the victim's backlog also exceeds the spiller's speed handicap.
//!   Spills are counted separately from steals
//!   ([`spills`](ShardedQueue::spills)); a single-pool queue can never
//!   spill and behaves exactly like the un-pooled constructor.
//!
//! ## What is decided here vs in the topology core
//!
//! Since the dispatch-plane unification, this module owns only the
//! *mechanics* of the hot path — shard storage, the lock-free depth
//! counters, the sleeper-gated park/wake handshake, and the atomic
//! steal/spill accounting. Every *choice* — which shard a push routes
//! to, the home-then-steal walk order, when a spill is admitted, how
//! many items one dispatch takes — is delegated to the
//! [`Topology`](crate::serving::topology::Topology) the queue was built
//! with ([`with_topology`](ShardedQueue::with_topology)), the same pure
//! core the DES engine ([`crate::sim::simulate_topology`]) executes.
//! Live/simulated dispatch parity is therefore definitional: there is
//! one copy of the decision logic, not two kept in sync by tests.
//!
//! ## Shard storage backends ([`QueueBackend`])
//!
//! The *mechanics* themselves now come in two interchangeable flavors
//! under the identical `Popped`/batch/park API and the identical
//! topology walk:
//!
//! * [`QueueBackend::Mutex`] (**default**) — one `Mutex<VecDeque>` per
//!   shard, the seed implementation. Unbounded per shard (only the
//!   aggregate reservation bounds it), exact depth accounting under the
//!   shard lock, and the reference for every parity pin.
//! * [`QueueBackend::Ring`] — one bounded lock-free MPMC ring per shard
//!   ([`MpmcRing`](super::ring::MpmcRing), Vyukov per-slot sequence
//!   counters; see the `ring` module docs for the slot-state protocol).
//!   Pushes and single pops are one CAS each; a batch/steal claims its
//!   whole run of slots with **one CAS on the ring head**
//!   ([`MpmcRing::pop_run_into`](super::ring::MpmcRing::pop_run_into)),
//!   which preserves the "one steal operation = one counter increment"
//!   contract the mutex backend gets from its critical section.
//!
//!   Two deliberate divergences, both invisible to the default path:
//!   - **Per-shard bound.** Each ring is sized to its pool's even share
//!     of the total capacity (`⌈capacity / pool_shards⌉`, rounded up to
//!     a power of two), so a push can hit a *full shard ring* while
//!     aggregate capacity remains — e.g. when routing is skewed. The
//!     push then returns [`QueueError::Full`] after rolling back its
//!     reservation: admission becomes (slightly) stricter, never looser,
//!     and round-robin routing makes the case pathological rather than
//!     common.
//!   - **Depth release order.** The mutex backend releases admission
//!     slots *before* removing items, under the shard lock. The ring has
//!     no lock to order those under, so it claims items first and then
//!     releases their slots — a claimed-but-not-yet-released item can
//!     transiently over-count `len()` by the in-flight batch, which only
//!     delays admission/wakeups by nanoseconds and keeps the
//!     close-and-drained check (`closed && depth == 0`) conservative.
//!
//! Selection is wired through `ServeOptions` (`--queue ring|mutex`);
//! the mutex default keeps the seed path bit-identical.
//!
//! The overload plane ([`crate::serving::overload`]) follows the same
//! split: deadline-aware shedding happens **injector-side** (before
//! `push_pool`) and in-queue expiry **worker-side** (after pop), so the
//! queue itself stays class-blind — items carry no priority here and the
//! FIFO/steal/spill mechanics above are untouched whether the overload
//! plane is on or off.
//!
//! The consumer API is exhaustive by construction: [`ShardedQueue`] pops
//! return [`Popped`] (`Item`/`TimedOut`/`Closed`), so a consumer loop
//! cannot reach a `Full` arm and has no panic path.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::ring::MpmcRing;
use super::topology::{Dispatch, Topology};
use crate::util::CachePadded;

/// Queue errors (producer side; see [`Popped`] for the consumer side).
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    /// Bounded capacity reached (admission control rejected the request).
    Full,
    /// Queue closed and drained.
    Closed,
}

/// Queue discipline of the serving hot path (live server and DES).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Discipline {
    /// One central FIFO shared by every worker (the paper's testbed).
    CentralFifo,
    /// Per-worker shards with round-robin routing and FIFO work
    /// stealing.
    ShardedSteal,
}

impl Discipline {
    /// Parse a CLI spelling (`central` | `sharded`).
    pub fn parse(s: &str) -> Option<Discipline> {
        match s {
            "central" | "fifo" => Some(Discipline::CentralFifo),
            "sharded" | "steal" => Some(Discipline::ShardedSteal),
            _ => None,
        }
    }

    /// Display name (reports/CSV headers).
    pub fn name(&self) -> &'static str {
        match self {
            Discipline::CentralFifo => "central",
            Discipline::ShardedSteal => "sharded",
        }
    }

    /// Shard count of a homogeneous k-worker fleet under this
    /// discipline: the central FIFO is always one shard; the sharded
    /// discipline honors an explicit `shards`, defaulting (0) to one
    /// shard per worker. The single copy of this resolution — the live
    /// `ServeOptions`, the `simulate_disc` shim and the ctx-driven
    /// experiment entry all resolve through it.
    pub fn effective_shards(&self, workers: usize, shards: usize) -> usize {
        match self {
            Discipline::CentralFifo => 1,
            Discipline::ShardedSteal => {
                if shards == 0 {
                    workers.max(1)
                } else {
                    shards
                }
            }
        }
    }
}

/// Shard-storage backend of the [`ShardedQueue`] hot path (see the
/// module docs for the trade-offs). Orthogonal to [`Discipline`]: the
/// discipline picks the shard layout, the backend picks what a shard
/// *is*.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueBackend {
    /// One `Mutex<VecDeque>` per shard — the seed mechanics (default).
    #[default]
    Mutex,
    /// One bounded lock-free MPMC ring per shard
    /// ([`MpmcRing`](super::ring::MpmcRing)).
    Ring,
}

impl QueueBackend {
    /// Parse a CLI spelling (`mutex` | `ring`).
    pub fn parse(s: &str) -> Option<QueueBackend> {
        match s {
            "mutex" | "lock" => Some(QueueBackend::Mutex),
            "ring" | "lockfree" | "lock-free" => Some(QueueBackend::Ring),
            _ => None,
        }
    }

    /// Display name (reports/CSV headers).
    pub fn name(&self) -> &'static str {
        match self {
            QueueBackend::Mutex => "mutex",
            QueueBackend::Ring => "ring",
        }
    }
}

/// Outcome of a consumer pop: exhaustive by construction (no error arm a
/// consumer must declare unreachable).
#[derive(Debug, PartialEq, Eq)]
pub enum Popped<T> {
    /// An item (from the home shard or stolen).
    Item(T),
    /// Nothing arrived within the timeout; the queue is still open.
    TimedOut,
    /// Queue closed **and** fully drained.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Thread-safe bounded FIFO with blocking pop (central discipline).
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    notify: Condvar,
    capacity: usize,
}

impl<T> RequestQueue<T> {
    pub fn new(capacity: usize) -> Self {
        RequestQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Enqueue; fails when full or closed.
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        g.items.push_back(item);
        drop(g);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop with timeout. `None` on timeout; `Err(Closed)` once
    /// the queue is closed **and** drained.
    ///
    /// The wait is bounded by a deadline (not restarted on every wakeup),
    /// so a consumer racing with other workers for notifications still
    /// returns within `timeout`. `close()` wakes **all** blocked
    /// consumers, and a consumer observing the close — on wakeup or on
    /// its timeout — reports `Closed` immediately rather than waiting
    /// out the remaining timeout.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, QueueError> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Ok(Some(item));
            }
            if g.closed {
                return Err(QueueError::Closed);
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Ok(None);
            }
            let (g2, _res) = self.notify.wait_timeout(g, remaining).unwrap();
            g = g2;
        }
    }

    /// Current depth (the load monitor's primary signal).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers fail afterwards; consumers drain what remains.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

/// The per-shard storage of a [`ShardedQueue`]: locked deques (seed) or
/// lock-free rings, selected once at construction ([`QueueBackend`]).
/// Everything above this — reservation, routing, walk order, parking —
/// is backend-agnostic.
enum ShardStore<T> {
    Mutex(Vec<Mutex<VecDeque<T>>>),
    Ring(Vec<MpmcRing<T>>),
}

impl<T> ShardStore<T> {
    fn shard_count(&self) -> usize {
        match self {
            ShardStore::Mutex(shards) => shards.len(),
            ShardStore::Ring(rings) => rings.len(),
        }
    }
}

/// Sharded bounded MPMC queue with FIFO work stealing.
///
/// `capacity` bounds the **total** buffered items across all shards
/// (admission control is a property of the server, not of a shard);
/// [`len`](ShardedQueue::len) is the aggregate depth in one atomic load.
/// Producers route round-robin; consumer `w` drains shard `w % shards`
/// first and steals the front of the next non-empty shard when its home
/// shard is dry.
///
/// Hot counters that different cores hammer concurrently (the aggregate
/// depth vs the router cursor vs the steal/spill tallies, and each
/// per-pool depth vs its neighbors) are [`CachePadded`] onto their own
/// 64-byte cache lines so an update to one never invalidates another's
/// line (false sharing).
pub struct ShardedQueue<T> {
    shards: ShardStore<T>,
    /// Aggregate depth: slots reserved by pushes and not yet claimed by
    /// pops. Reserved before insert, released at claim (under the shard
    /// lock, just before removal), so a racing push can only be admitted
    /// early into a freshly freed slot — never spuriously rejected while
    /// capacity genuinely remains. Exact AQM depth signal in quiescence.
    /// (Ring backend: released just *after* the claim — see the module
    /// docs' divergence note.)
    depth: CachePadded<AtomicUsize>,
    capacity: usize,
    /// Round-robin router cursor (pool-agnostic [`push`](ShardedQueue::push)).
    router: CachePadded<AtomicUsize>,
    /// The dispatch topology: shard layout, walk order, spill gate and
    /// batch arithmetic all come from here (shared with the DES engine).
    topo: Topology,
    /// Per-pool depth counters — maintained (and read) only when the
    /// topology has more than one pool, so the single-pool hot path is
    /// exactly the pre-pool code.
    pool_depths: Vec<CachePadded<AtomicUsize>>,
    /// Per-pool round-robin router cursors.
    pool_routers: Vec<CachePadded<AtomicUsize>>,
    closed: AtomicBool,
    /// Pops satisfied from a non-home shard of the consumer's own pool.
    steals: CachePadded<AtomicU64>,
    /// Pops satisfied from another pool's shard (cross-pool spill).
    spills: CachePadded<AtomicU64>,
    /// Consumers parked on `notify`; producers skip the sleep gate
    /// entirely while this is zero (the loaded-system fast path).
    sleepers: AtomicUsize,
    gate: Mutex<()>,
    notify: Condvar,
    /// Live spill-margin override installed by the online re-planner
    /// ([`crate::serving::replan`]): the f64 bit pattern of the margin,
    /// or `u64::MAX` (a NaN encoding no real margin produces) while
    /// unset. While unset every gate reads the topology's static
    /// margin — bit-identical to the pre-override code path.
    margin_override: AtomicU64,
}

impl<T> ShardedQueue<T> {
    pub fn new(capacity: usize, shards: usize) -> Self {
        Self::new_pooled(capacity, &[shards.max(1)])
    }

    /// [`new`](ShardedQueue::new) with an explicit shard-storage
    /// [`QueueBackend`] (`Mutex` is what `new` gives you).
    pub fn new_backend(capacity: usize, shards: usize, backend: QueueBackend) -> Self {
        Self::with_topology_backend(
            capacity,
            Topology::anonymous(&[shards.max(1)]),
            backend,
        )
    }

    /// A pool-partitioned queue: `pool_shards[p]` shards belong to pool
    /// `p` (contiguous ranges, in order). `capacity` still bounds the
    /// **total** buffered items across every pool — admission control
    /// stays a property of the server, not of a pool.
    pub fn new_pooled(capacity: usize, pool_shards: &[usize]) -> Self {
        assert!(!pool_shards.is_empty(), "need at least one pool");
        Self::with_topology(capacity, Topology::anonymous(pool_shards))
    }

    /// A queue over an explicit dispatch [`Topology`]: the shard layout,
    /// walk order, spill gate (margin + speed handicaps) and batch
    /// arithmetic are all the topology's — the queue adds only locks,
    /// counters and parking. This is the constructor the server uses;
    /// [`new`](ShardedQueue::new) / [`new_pooled`](ShardedQueue::new_pooled)
    /// wrap it with uniform-speed, margin-0 topologies.
    pub fn with_topology(capacity: usize, topo: Topology) -> Self {
        Self::with_topology_backend(capacity, topo, QueueBackend::Mutex)
    }

    /// [`with_topology`](ShardedQueue::with_topology) with an explicit
    /// shard-storage [`QueueBackend`]. Under the ring backend each
    /// shard's ring is sized to its pool's even share of the total
    /// capacity (`⌈capacity / pool_shards⌉`, rounded up to a power of
    /// two): a whole pool can absorb every admitted item, while a
    /// single shard need not — round-robin routing spreads a pool's
    /// backlog evenly, and a skew-flooded shard reports `Full` early
    /// (admission stays a *total* bound; see the module docs).
    pub fn with_topology_backend(
        capacity: usize,
        topo: Topology,
        backend: QueueBackend,
    ) -> Self {
        let n_shards = topo.n_shards();
        let n_pools = topo.n_pools();
        let capacity = capacity.max(1);
        let shards = match backend {
            QueueBackend::Mutex => {
                ShardStore::Mutex((0..n_shards).map(|_| Mutex::new(VecDeque::new())).collect())
            }
            QueueBackend::Ring => ShardStore::Ring(
                (0..n_shards)
                    .map(|s| {
                        let (lo, hi) = topo.shard_range(topo.shard_pool(s));
                        MpmcRing::new(capacity.div_ceil((hi - lo).max(1)))
                    })
                    .collect(),
            ),
        };
        ShardedQueue {
            shards,
            depth: CachePadded::new(AtomicUsize::new(0)),
            capacity,
            router: CachePadded::new(AtomicUsize::new(0)),
            pool_depths: (0..n_pools)
                .map(|_| CachePadded::new(AtomicUsize::new(0)))
                .collect(),
            pool_routers: (0..n_pools)
                .map(|_| CachePadded::new(AtomicUsize::new(0)))
                .collect(),
            topo,
            closed: AtomicBool::new(false),
            steals: CachePadded::new(AtomicU64::new(0)),
            spills: CachePadded::new(AtomicU64::new(0)),
            sleepers: AtomicUsize::new(0),
            gate: Mutex::new(()),
            notify: Condvar::new(),
            margin_override: AtomicU64::new(u64::MAX),
        }
    }

    /// Which shard-storage backend this queue was built with.
    pub fn backend(&self) -> QueueBackend {
        match self.shards {
            ShardStore::Mutex(_) => QueueBackend::Mutex,
            ShardStore::Ring(_) => QueueBackend::Ring,
        }
    }

    /// Install a spill-margin override (the re-planner raising the
    /// margin as fleet-wide utilization saturates). Takes effect on the
    /// next gate evaluation; non-finite values are ignored.
    pub fn set_spill_margin(&self, margin: f64) {
        if margin.is_finite() {
            self.margin_override.store(margin.max(0.0).to_bits(), Ordering::Relaxed);
        }
    }

    /// The spill margin gates evaluate right now: the override when one
    /// has been installed, else the topology's static margin.
    fn spill_margin_now(&self) -> f64 {
        let bits = self.margin_override.load(Ordering::Relaxed);
        if bits == u64::MAX {
            self.topo.spill_margin()
        } else {
            f64::from_bits(bits)
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.shard_count()
    }

    /// Number of pools (1 unless built with [`new_pooled`](ShardedQueue::new_pooled)).
    pub fn pool_count(&self) -> usize {
        self.topo.n_pools()
    }

    /// Reserve one admission slot against the total bound (lock-free).
    fn reserve(&self) -> Result<(), QueueError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(QueueError::Closed);
        }
        if self
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                (d < self.capacity).then_some(d + 1)
            })
            .is_err()
        {
            return Err(QueueError::Full);
        }
        Ok(())
    }

    /// Insert a reserved item into `shard` and wake a parked consumer.
    /// Mutex shards always succeed (unbounded per shard). A ring shard
    /// can be full even though the aggregate reservation admitted the
    /// item; the reservation (and pool depth) is rolled back and the
    /// push fails `Full` — stricter admission, never looser.
    fn finish_push(&self, shard: usize, item: T) -> Result<(), QueueError> {
        if self.topo.n_pools() > 1 {
            self.pool_depths[self.topo.shard_pool(shard)].fetch_add(1, Ordering::SeqCst);
        }
        match &self.shards {
            ShardStore::Mutex(shards) => shards[shard].lock().unwrap().push_back(item),
            ShardStore::Ring(rings) => {
                if rings[shard].push(item).is_err() {
                    if self.topo.n_pools() > 1 {
                        self.pool_depths[self.topo.shard_pool(shard)]
                            .fetch_sub(1, Ordering::SeqCst);
                    }
                    self.depth.fetch_sub(1, Ordering::SeqCst);
                    return Err(QueueError::Full);
                }
            }
        }
        // Wake a parked consumer. The sleep gate is only taken when a
        // consumer is actually parked (Dekker-style handshake with the
        // consumer's sleepers-increment / ready-check, both SeqCst:
        // either we see its registration or it sees our depth).
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.gate.lock().unwrap();
            if self.topo.n_pools() > 1 && self.spill_margin_now() > 0.0 {
                // Consumers park on per-pool ready() predicates: a
                // single wakeup could land on a spill-gated consumer
                // that may not take this item while the eligible one
                // sleeps out its timeout. Wake everyone and let each
                // ready() decide; single-pool / margin-0 queues keep
                // the cheap single wakeup (every consumer can take
                // every item there).
                self.notify.notify_all();
            } else {
                self.notify.notify_one();
            }
        }
        Ok(())
    }

    /// Enqueue; fails when the aggregate capacity is reserved or the
    /// queue is closed. The common path is one atomic reservation + one
    /// shard lock touched by `1/shards` of the traffic. Routing is
    /// pool-agnostic round-robin over every shard — the single-pool path
    /// (see [`push_pool`](ShardedQueue::push_pool) for rung-aware pooled
    /// routing).
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        self.reserve()?;
        let shard = self.router.fetch_add(1, Ordering::Relaxed) % self.shards.shard_count();
        self.finish_push(shard, item)
    }

    /// Enqueue into one pool: round-robin over that pool's shards only
    /// (the topology's [`route`](Topology::route)). With a single pool
    /// this is exactly [`push`](ShardedQueue::push) (same cursor
    /// arithmetic over the same shards).
    pub fn push_pool(&self, pool: usize, item: T) -> Result<(), QueueError> {
        self.reserve()?;
        let cursor = self.pool_routers[pool].fetch_add(1, Ordering::Relaxed);
        self.finish_push(self.topo.route(pool, cursor), item)
    }

    /// One steal/spill *operation* is counted regardless of how many
    /// items it takes — the counters track lock-level frequency, which
    /// is what batch stealing amortizes.
    fn count_dispatch(&self, kind: Dispatch) {
        match kind {
            Dispatch::Home => {}
            Dispatch::Steal => {
                self.steals.fetch_add(1, Ordering::Relaxed);
            }
            Dispatch::Spill => {
                self.spills.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Release `n` claimed admission slots (aggregate + pool depth).
    fn release_slots(&self, s: usize, n: usize) {
        self.depth.fetch_sub(n, Ordering::SeqCst);
        if self.topo.n_pools() > 1 {
            self.pool_depths[self.topo.shard_pool(s)].fetch_sub(n, Ordering::SeqCst);
        }
    }

    /// Claim one item from shard `s` (front, FIFO). Mutex backend:
    /// admission slot released *before* removal, under the shard lock —
    /// see the ordering note in
    /// [`take_batch_into`](ShardedQueue::take_batch_into). Ring backend:
    /// claim first, release after (no lock to order under).
    fn take_one_from(&self, s: usize, kind: Dispatch) -> Option<T> {
        let item = match &self.shards {
            ShardStore::Mutex(shards) => {
                let mut g = shards[s].lock().unwrap();
                if g.is_empty() {
                    return None;
                }
                self.release_slots(s, 1);
                g.pop_front()
            }
            ShardStore::Ring(rings) => {
                let item = rings[s].pop()?;
                self.release_slots(s, 1);
                Some(item)
            }
        };
        self.count_dispatch(kind);
        item
    }

    /// Claim up to `max` items from shard `s` in **one operation** —
    /// a front run at home, half the backlog when stealing or spilling
    /// ([`Topology::take_count`] owns the arithmetic; leave a victim
    /// work) — appending them to `out` and returning how many were
    /// taken (0 = the shard was empty; `out` is never touched then).
    ///
    /// Mutex backend: one lock acquisition; all `take` slots are
    /// released *before* any item is removed, so the depth counter never
    /// over-counts a claimed item and a racing push can only be admitted
    /// early (into a freshly freed slot), never spuriously rejected
    /// while capacity genuinely remains. Ring backend: the run is
    /// reserved with one CAS on the ring head
    /// ([`MpmcRing::pop_run_into`]) and the slots released after the
    /// claim — same "one operation" atomicity (and the same single
    /// steal/spill-counter increment), opposite release order.
    fn take_batch_into(&self, s: usize, max: usize, kind: Dispatch, out: &mut Vec<T>) -> usize {
        let take = match &self.shards {
            ShardStore::Mutex(shards) => {
                let mut g = shards[s].lock().unwrap();
                if g.is_empty() {
                    return 0;
                }
                let take = Topology::take_count(g.len(), max, kind);
                self.release_slots(s, take);
                for _ in 0..take {
                    out.push(g.pop_front().unwrap());
                }
                take
            }
            ShardStore::Ring(rings) => {
                let ring = &rings[s];
                let len = ring.len();
                if len == 0 {
                    return 0;
                }
                let want = Topology::take_count(len, max, kind);
                let got = ring.pop_run_into(want, out);
                if got == 0 {
                    return 0;
                }
                self.release_slots(s, got);
                got
            }
        };
        self.count_dispatch(kind);
        take
    }

    /// [`take_batch_into`](ShardedQueue::take_batch_into) into a fresh
    /// `Vec` (the allocating convenience the batch API predates).
    fn take_batch_from(&self, s: usize, max: usize, kind: Dispatch) -> Option<Vec<T>> {
        let mut items = Vec::new();
        let n = self.take_batch_into(s, max, kind, &mut items);
        (n > 0).then_some(items)
    }

    /// Non-blocking pop for consumer `worker` of the first pool — the
    /// single-pool consumer path (on a single-pool queue there is no
    /// spill leg, so this is the plain home-then-steal sweep).
    pub fn try_pop(&self, worker: usize) -> Option<T> {
        self.try_pop_pool(0, worker)
    }

    /// Non-blocking pooled pop for consumer `worker` of pool `pool`:
    /// the topology's within-pool walk (home shard, then a FIFO steal
    /// sweep over the *pool's own* shards); only when every shard of
    /// the pool is dry does the sweep spill into the other pools —
    /// cyclic pool order, each victim gated by
    /// [`Topology::spill_allowed`] (margin 0 admits any non-empty
    /// victim). With a single pool this is exactly
    /// [`try_pop`](ShardedQueue::try_pop).
    pub fn try_pop_pool(&self, pool: usize, worker: usize) -> Option<T> {
        for (s, kind) in self.topo.pool_walk(pool, worker) {
            if let Some(item) = self.take_one_from(s, kind) {
                return Some(item);
            }
        }
        let margin = self.spill_margin_now();
        for q in self.topo.spill_order(pool) {
            if !self.topo.spill_allowed_with(pool, q, self.pool_len(q), margin) {
                continue;
            }
            let (lo, hi) = self.topo.shard_range(q);
            for s in lo..hi {
                if let Some(item) = self.take_one_from(s, Dispatch::Spill) {
                    return Some(item);
                }
            }
        }
        None
    }

    /// Non-blocking pop restricted to `pool`'s *own* shards (the
    /// within-pool walk, no spill leg). Fault injection uses this to
    /// drain a dark pool's stranded backlog without poaching other
    /// pools' work; alive pools never call it.
    pub fn try_pop_home(&self, pool: usize, worker: usize) -> Option<T> {
        for (s, kind) in self.topo.pool_walk(pool, worker) {
            if let Some(item) = self.take_one_from(s, kind) {
                return Some(item);
            }
        }
        None
    }

    /// Has [`close`](ShardedQueue::close) been called? (Producers fail
    /// afterwards; consumers may still drain.)
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Non-blocking batch pop for consumer `worker`: drain up to `max`
    /// items from the front of the home shard in one lock acquisition;
    /// when the home shard is dry, steal **half** the first non-empty
    /// shard's backlog (`⌈len/2⌉`, capped at `max`) in one acquisition.
    /// Returns `None` when every shard is empty; a returned batch is
    /// never empty. `max == 1` behaves exactly like
    /// [`try_pop`](ShardedQueue::try_pop) (steal-one included).
    pub fn try_pop_batch(&self, worker: usize, max: usize) -> Option<Vec<T>> {
        self.try_pop_batch_pool(0, worker, max)
    }

    /// Pooled batch pop: the batch analogue of
    /// [`try_pop_pool`](ShardedQueue::try_pop_pool) — home-pool front
    /// run / steal-half first, cross-pool spill (also half, capped at
    /// `max`, gated by the margin) only once the home pool is fully dry.
    pub fn try_pop_batch_pool(
        &self,
        pool: usize,
        worker: usize,
        max: usize,
    ) -> Option<Vec<T>> {
        let mut items = Vec::new();
        let n = self.try_pop_batch_pool_into(pool, worker, max, &mut items);
        (n > 0).then_some(items)
    }

    /// Allocation-free [`try_pop_batch_pool`](ShardedQueue::try_pop_batch_pool):
    /// the batch lands in the caller's scratch buffer (appended, not
    /// cleared) and the return value is how many items were taken (0 =
    /// every reachable shard was empty). The steady-state dispatch loop
    /// reuses one per-worker buffer across iterations, so batch dequeue
    /// performs no per-batch heap allocation.
    pub fn try_pop_batch_pool_into(
        &self,
        pool: usize,
        worker: usize,
        max: usize,
        out: &mut Vec<T>,
    ) -> usize {
        let max = max.max(1);
        for (s, kind) in self.topo.pool_walk(pool, worker) {
            let n = self.take_batch_into(s, max, kind, out);
            if n > 0 {
                return n;
            }
        }
        let margin = self.spill_margin_now();
        for q in self.topo.spill_order(pool) {
            if !self.topo.spill_allowed_with(pool, q, self.pool_len(q), margin) {
                continue;
            }
            let (lo, hi) = self.topo.shard_range(q);
            for s in lo..hi {
                let n = self.take_batch_into(s, max, Dispatch::Spill, out);
                if n > 0 {
                    return n;
                }
            }
        }
        0
    }

    /// Blocking pop with timeout for consumer `worker`.
    ///
    /// Returns [`Popped::Item`] (home or stolen), [`Popped::TimedOut`]
    /// when nothing arrived within `timeout`, or [`Popped::Closed`] once
    /// the queue is closed **and** every shard is drained. The wait is
    /// deadline-based and `close()` wakes all parked consumers promptly.
    pub fn pop_timeout(&self, worker: usize, timeout: Duration) -> Popped<T> {
        self.pop_with(timeout, 0, || self.try_pop(worker))
    }

    /// Blocking batch pop with timeout: the batch analogue of
    /// [`pop_timeout`](ShardedQueue::pop_timeout), draining up to `max`
    /// items per [`try_pop_batch`](ShardedQueue::try_pop_batch). A
    /// returned [`Popped::Item`] batch is never empty.
    pub fn pop_batch(&self, worker: usize, max: usize, timeout: Duration) -> Popped<Vec<T>> {
        self.pop_with(timeout, 0, || self.try_pop_batch(worker, max))
    }

    /// Blocking pooled pop with timeout — the consumer path of a pooled
    /// executor: within-pool drain/steal, cross-pool spill only when the
    /// home pool is dry (see [`try_pop_pool`](ShardedQueue::try_pop_pool)).
    pub fn pop_timeout_pool(
        &self,
        pool: usize,
        worker: usize,
        timeout: Duration,
    ) -> Popped<T> {
        self.pop_with(timeout, pool, || self.try_pop_pool(pool, worker))
    }

    /// Blocking pooled batch pop with timeout (see
    /// [`try_pop_batch_pool`](ShardedQueue::try_pop_batch_pool)).
    pub fn pop_batch_pool(
        &self,
        pool: usize,
        worker: usize,
        max: usize,
        timeout: Duration,
    ) -> Popped<Vec<T>> {
        self.pop_with(timeout, pool, || self.try_pop_batch_pool(pool, worker, max))
    }

    /// Allocation-free [`pop_batch_pool`](ShardedQueue::pop_batch_pool):
    /// `out` is cleared, the batch (if any) lands in it, and
    /// `Popped::Item(n)` carries the batch size (never 0). The same
    /// park-loop/timeout/close semantics as every other blocking pop.
    pub fn pop_batch_pool_into(
        &self,
        pool: usize,
        worker: usize,
        max: usize,
        timeout: Duration,
        out: &mut Vec<T>,
    ) -> Popped<usize> {
        out.clear();
        let mut out = out;
        self.pop_with(timeout, pool, move || {
            let n = self.try_pop_batch_pool_into(pool, worker, max, out);
            (n > 0).then_some(n)
        })
    }

    /// Is there anything consumer of `pool` could take right now? The
    /// topology's [`can_take`](Topology::can_take) over the live depth
    /// counters: the pool's own backlog, or a foreign backlog passing
    /// the spill gate. Under a positive spill margin this keeps a gated
    /// consumer *parked* (instead of hot-spinning on work it is not
    /// allowed to poach); the next push still wakes it through the
    /// sleeper gate, so no wakeup is ever missed.
    fn ready(&self, pool: usize) -> bool {
        self.topo.can_take_with(pool, |q| self.pool_len(q), self.spill_margin_now())
    }

    /// Shared deadline-based park loop under `attempt` (single or batch
    /// pop, for a consumer of `pool`): re-check, register as a sleeper
    /// under the gate (Dekker handshake with producers), wait, repeat
    /// until item(s), timeout, or closed-and-drained.
    fn pop_with<R>(
        &self,
        timeout: Duration,
        pool: usize,
        mut attempt: impl FnMut() -> Option<R>,
    ) -> Popped<R> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(item) = attempt() {
                return Popped::Item(item);
            }
            if self.closed.load(Ordering::SeqCst) && self.depth.load(Ordering::SeqCst) == 0 {
                return Popped::Closed;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Popped::TimedOut;
            }
            // Park: register as a sleeper, then re-check under the gate
            // so a producer's depth-store/sleepers-load cannot slip
            // between our check and the wait (missed-wakeup handshake).
            let g = self.gate.lock().unwrap();
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            if self.ready(pool) || self.closed.load(Ordering::SeqCst) {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let (g2, _res) = self.notify.wait_timeout(g, remaining).unwrap();
            drop(g2);
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Aggregate depth across all shards — one atomic load; the AQM /
    /// Elastico control signal and the admission bound.
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Depth of one pool's shards — the per-pool AQM/Elastico signal.
    /// With a single pool this is the aggregate depth (same counter, so
    /// the homogeneous path stays exactly the pre-pool code).
    pub fn pool_len(&self, pool: usize) -> usize {
        if self.topo.n_pools() == 1 {
            self.depth.load(Ordering::SeqCst)
        } else {
            self.pool_depths[pool].load(Ordering::SeqCst)
        }
    }

    /// Pops satisfied by stealing from a non-home shard so far.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Pops satisfied by spilling into another pool's shard so far
    /// (always 0 on a single-pool queue).
    pub fn spills(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    /// Close: producers fail afterwards; consumers drain what remains.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let _g = self.gate.lock().unwrap();
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), Some(i));
        }
        assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), None);
    }

    #[test]
    fn capacity_enforced() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(QueueError::Full));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_errors() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(QueueError::Closed));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), Some(1));
        assert_eq!(
            q.pop_timeout(Duration::from_millis(1)),
            Err(QueueError::Closed)
        );
    }

    #[test]
    fn close_wakes_all_blocked_consumers_promptly() {
        // k workers blocked with a long timeout must all observe Closed
        // as soon as the producer closes, not after spinning out their
        // timeout (the worker-pool shutdown path).
        let q: Arc<RequestQueue<u32>> = Arc::new(RequestQueue::new(8));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let t0 = Instant::now();
                    let r = q.pop_timeout(Duration::from_secs(30));
                    (r, t0.elapsed())
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(50)); // let them block
        q.close();
        for h in handles {
            let (r, dt) = h.join().unwrap();
            assert_eq!(r, Err(QueueError::Closed));
            assert!(dt < Duration::from_secs(5), "woke only after {dt:?}");
        }
    }

    #[test]
    fn timeout_is_a_deadline_not_a_restart() {
        // Repeated notifications that yield no item must not extend the
        // wait beyond the requested timeout.
        let q: Arc<RequestQueue<u32>> = Arc::new(RequestQueue::new(8));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let t0 = Instant::now();
            let r = q2.pop_timeout(Duration::from_millis(200));
            (r, t0.elapsed())
        });
        // A racing thread drains every pushed item before the consumer
        // can observe it, while still generating wakeups for ~840 ms —
        // well past the consumer's 200 ms deadline. A wait that restarts
        // its timeout on every wakeup would outlast the whole barrage.
        for _ in 0..40 {
            q.push(1).unwrap();
            while q.pop_timeout(Duration::from_millis(1)).unwrap().is_some() {}
            std::thread::sleep(Duration::from_millis(20));
        }
        let (r, dt) = consumer.join().unwrap();
        assert!(r.is_ok(), "{r:?}");
        assert!(dt < Duration::from_millis(600), "waited {dt:?}");
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(RequestQueue::new(100));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                while q2.push(i).is_err() {}
            }
            q2.close();
        });
        let mut got = Vec::new();
        loop {
            match q.pop_timeout(Duration::from_millis(50)) {
                Ok(Some(v)) => got.push(v),
                Ok(None) => {}
                Err(QueueError::Closed) => break,
                Err(e) => panic!("{e:?}"),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    // ---- ShardedQueue ------------------------------------------------

    #[test]
    fn sharded_round_robin_and_per_shard_fifo() {
        // 8 pushes over 4 shards: shard s holds {s, s+4} in order.
        let q: ShardedQueue<u64> = ShardedQueue::new(64, 4);
        for i in 0..8 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 8);
        // Consumer 2 drains its home shard first (2 then 6)…
        assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(2));
        assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(6));
        assert_eq!(q.steals(), 0);
        // …then steals FIFO from the next shards, wrapping.
        assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(3));
        assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(7));
        assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(0));
        assert_eq!(q.steals(), 3);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn sharded_single_shard_is_the_central_fifo() {
        let q: ShardedQueue<u64> = ShardedQueue::new(16, 1);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        // Any worker index maps to the one shard; global FIFO holds.
        for (w, i) in [(0usize, 0u64), (3, 1), (1, 2), (7, 3), (2, 4), (0, 5)] {
            assert_eq!(q.pop_timeout(w, Duration::from_millis(1)), Popped::Item(i));
        }
        assert_eq!(q.steals(), 0);
        assert_eq!(
            q.pop_timeout(0, Duration::from_millis(1)),
            Popped::TimedOut
        );
    }

    #[test]
    fn sharded_aggregate_capacity_enforced() {
        // Capacity bounds the total, not per shard: 3 slots over 2
        // shards admit exactly 3 regardless of routing.
        let q: ShardedQueue<u64> = ShardedQueue::new(3, 2);
        q.push(0).unwrap();
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(QueueError::Full));
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Item(0));
        // A freed slot readmits.
        q.push(4).unwrap();
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn sharded_close_drains_then_closes() {
        let q: ShardedQueue<u64> = ShardedQueue::new(8, 2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(QueueError::Closed));
        assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Item(1));
        assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Item(2));
        assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Closed);
        assert_eq!(q.pop_timeout(5, Duration::from_millis(1)), Popped::Closed);
    }

    #[test]
    fn sharded_push_wakes_consumer_parked_on_another_home_shard() {
        // Worker 1 (home shard 1) parks on an empty queue; the first
        // push routes to shard 0 — the cross-shard wakeup must reach it
        // and the item arrives by stealing, well within the timeout.
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(8, 2));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let t0 = Instant::now();
            let r = q2.pop_timeout(1, Duration::from_secs(30));
            (r, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(50)); // let it park
        q.push(42).unwrap();
        let (r, dt) = consumer.join().unwrap();
        assert_eq!(r, Popped::Item(42));
        assert!(dt < Duration::from_secs(5), "woke only after {dt:?}");
        assert_eq!(q.steals(), 1);
    }

    #[test]
    fn sharded_close_wakes_all_parked_consumers_promptly() {
        let q: Arc<ShardedQueue<u32>> = Arc::new(ShardedQueue::new(8, 4));
        let handles: Vec<_> = (0..4)
            .map(|w| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let t0 = Instant::now();
                    let r = q.pop_timeout(w, Duration::from_secs(30));
                    (r, t0.elapsed())
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(50));
        q.close();
        for h in handles {
            let (r, dt) = h.join().unwrap();
            assert_eq!(r, Popped::Closed);
            assert!(dt < Duration::from_secs(5), "woke only after {dt:?}");
        }
    }

    #[test]
    fn batch_pop_drains_home_front_run_in_order() {
        // Shard 0 holds {0, 4, 8} after 12 round-robin pushes over 4
        // shards; a batch pop of up to 8 takes exactly that front run.
        let q: ShardedQueue<u64> = ShardedQueue::new(64, 4);
        for i in 0..12 {
            q.push(i).unwrap();
        }
        assert_eq!(
            q.pop_batch(0, 8, Duration::from_millis(1)),
            Popped::Item(vec![0, 4, 8])
        );
        assert_eq!(q.steals(), 0, "home drain is not a steal");
        assert_eq!(q.len(), 9);
    }

    #[test]
    fn batch_pop_bounded_by_max() {
        let q: ShardedQueue<u64> = ShardedQueue::new(64, 1);
        for i in 0..10 {
            q.push(i).unwrap();
        }
        assert_eq!(
            q.pop_batch(0, 4, Duration::from_millis(1)),
            Popped::Item(vec![0, 1, 2, 3])
        );
        assert_eq!(
            q.pop_batch(0, 4, Duration::from_millis(1)),
            Popped::Item(vec![4, 5, 6, 7])
        );
        assert_eq!(
            q.pop_batch(0, 4, Duration::from_millis(1)),
            Popped::Item(vec![8, 9])
        );
        assert_eq!(q.pop_batch(0, 4, Duration::from_millis(1)), Popped::TimedOut);
    }

    #[test]
    fn batch_steal_takes_half_the_victim_in_one_operation() {
        // 16 round-robin pushes over 2 shards: shard 0 holds the evens,
        // shard 1 the odds. Once worker 1 drains its home shard, a dry
        // batch pop steals ⌈8/2⌉ = 4 of shard 0's items FIFO, counted as
        // ONE steal operation (the lock frequency batch stealing cuts).
        let q: ShardedQueue<u64> = ShardedQueue::new(64, 2);
        for i in 0..16 {
            q.push(i).unwrap();
        }
        // Drain home shard 1 fully (8 items: 1,3,…,15).
        assert_eq!(
            q.pop_batch(1, 64, Duration::from_millis(1)),
            Popped::Item(vec![1, 3, 5, 7, 9, 11, 13, 15])
        );
        assert_eq!(q.steals(), 0);
        // Now shard 1 is dry: batch pop steals ⌈8/2⌉ = 4 from shard 0.
        assert_eq!(
            q.pop_batch(1, 64, Duration::from_millis(1)),
            Popped::Item(vec![0, 2, 4, 6])
        );
        assert_eq!(q.steals(), 1, "one batch steal = one steal operation");
        // Cap: next steal takes ⌈4/2⌉ = 2, bounded by max = 1 -> 1 item.
        assert_eq!(
            q.pop_batch(1, 1, Duration::from_millis(1)),
            Popped::Item(vec![8])
        );
        assert_eq!(q.steals(), 2);
    }

    #[test]
    fn batch_pop_conserves_under_racing_consumers() {
        // 4 producers x 1000 items drained by 4 batch consumers with
        // max = 7: every item must come out exactly once (no loss, no
        // duplication) and capacity may never spuriously reject.
        let n_prod = 4usize;
        let per = 1000u64;
        let q: Arc<ShardedQueue<u64>> =
            Arc::new(ShardedQueue::new((n_prod as u64 * per) as usize, 4));
        let producers: Vec<_> = (0..n_prod)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push(p as u64 * per + i).unwrap(); // Full = bug
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4usize)
            .map(|w| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_batch(w, 7, Duration::from_millis(100)) {
                            Popped::Item(items) => {
                                assert!(!items.is_empty() && items.len() <= 7);
                                got.extend(items);
                            }
                            Popped::TimedOut => {}
                            Popped::Closed => break,
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        assert_eq!(all, (0..n_prod as u64 * per).collect::<Vec<u64>>());
        assert_eq!(q.len(), 0);
    }

    // ---- pooled ShardedQueue ----------------------------------------

    #[test]
    fn pooled_routing_is_round_robin_within_each_pool() {
        // 2 pools x 2 shards: pool 0 owns shards {0, 1}, pool 1 owns
        // {2, 3}. Pushes into a pool round-robin its own shards only.
        let q: ShardedQueue<u64> = ShardedQueue::new_pooled(64, &[2, 2]);
        assert_eq!(q.pool_count(), 2);
        assert_eq!(q.shard_count(), 4);
        for i in 0..4 {
            q.push_pool(0, i).unwrap();
        }
        for i in 10..14 {
            q.push_pool(1, i).unwrap();
        }
        assert_eq!(q.len(), 8);
        assert_eq!(q.pool_len(0), 4);
        assert_eq!(q.pool_len(1), 4);
        // Pool-0 consumer 0 drains home shard {0, 2}, then steals {1, 3}
        // from its pool sibling — all without touching pool 1.
        for want in [0u64, 2, 1, 3] {
            assert_eq!(q.pop_timeout_pool(0, 0, Duration::from_millis(1)), Popped::Item(want));
        }
        assert_eq!(q.steals(), 2);
        assert_eq!(q.spills(), 0, "home pool had items: no spill allowed");
        assert_eq!(q.pool_len(0), 0);
        assert_eq!(q.pool_len(1), 4);
    }

    #[test]
    fn spill_only_when_the_home_pool_is_fully_dry() {
        let q: ShardedQueue<u64> = ShardedQueue::new_pooled(64, &[2, 2]);
        // One item in the consumer's pool, plenty in the other.
        q.push_pool(0, 7).unwrap();
        for i in 0..6 {
            q.push_pool(1, 100 + i).unwrap();
        }
        // While pool 0 holds anything, its consumer never crosses pools.
        assert_eq!(q.pop_timeout_pool(0, 0, Duration::from_millis(1)), Popped::Item(7));
        assert_eq!(q.spills(), 0);
        // Now pool 0 is dry: the pop spills — half the victim shard
        // (pool 1 shard 2 holds {100, 102, 104}: spill takes ⌈3/2⌉ = 2).
        assert_eq!(
            q.pop_batch_pool(0, 0, 8, Duration::from_millis(1)),
            Popped::Item(vec![100, 102])
        );
        assert_eq!(q.spills(), 1, "one spill operation per batch");
        assert_eq!(q.steals(), 0, "spills are not steals");
        assert_eq!(q.pool_len(1), 4);
        // Pool 1's own consumer still drains its pool FIFO.
        assert_eq!(q.pop_timeout_pool(1, 0, Duration::from_millis(1)), Popped::Item(104));
        assert_eq!(q.pop_timeout_pool(1, 1, Duration::from_millis(1)), Popped::Item(101));
    }

    #[test]
    fn spill_margin_gates_poaching_until_the_backlog_justifies_it() {
        // fast: 2 shards @1x, slow: 2 shards @2.5x, margin 1: the slow
        // pool may poach only once the fast backlog exceeds
        // 1 · (2.5/1) · 2 = 5 items — below that, the fast workers
        // would finish the work sooner than the slow pool could.
        let pools = crate::serving::pool::parse_pools("fast:2:1.0,slow:2:2.5").unwrap();
        let topo = Topology::from_pools(&pools, 1.0).unwrap();
        let q: ShardedQueue<u64> = ShardedQueue::with_topology(64, topo);
        for i in 0..5 {
            q.push_pool(0, i).unwrap();
        }
        // Slow-pool consumer: own shards dry, gate holds at backlog 5.
        assert_eq!(q.pop_timeout_pool(1, 0, Duration::from_millis(1)), Popped::TimedOut);
        assert_eq!(q.spills(), 0, "margin must block the shallow poach");
        // A sixth item crosses the threshold: the spill is admitted and
        // takes half the victim shard ({0, 2, 4}) in one operation.
        q.push_pool(0, 5).unwrap();
        assert_eq!(
            q.pop_batch_pool(1, 0, 8, Duration::from_millis(1)),
            Popped::Item(vec![0, 2])
        );
        assert_eq!(q.spills(), 1);
        // Margin 0 (the default) is the historical spill-when-dry.
        let q0: ShardedQueue<u64> =
            ShardedQueue::with_topology(64, Topology::from_pools(&pools, 0.0).unwrap());
        q0.push_pool(0, 7).unwrap();
        assert_eq!(q0.pop_timeout_pool(1, 0, Duration::from_millis(1)), Popped::Item(7));
        assert_eq!(q0.spills(), 1);
    }

    #[test]
    fn margin_wakeups_reach_the_eligible_consumer_while_gated_peers_park() {
        // fast:1 @1x, slow:1 @2.5x, margin 1: the slow consumer's spill
        // gate holds until the fast backlog exceeds 1 · 2.5 · 1 = 2.5.
        // Consumers park on per-pool ready() predicates here, so the
        // wake path must reach the *eligible* consumer even when a
        // gated one is parked too (the notify_all branch) — a reverted
        // single wakeup could land on the gated consumer and leave the
        // eligible one sleeping out its full timeout.
        let pools = crate::serving::pool::parse_pools("fast:1:1.0,slow:1:2.5").unwrap();
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::with_topology(
            64,
            Topology::from_pools(&pools, 1.0).unwrap(),
        ));
        let qs = q.clone();
        let slow = std::thread::spawn(move || {
            qs.pop_timeout_pool(1, 0, Duration::from_millis(400))
        });
        let qf = q.clone();
        let fast = std::thread::spawn(move || {
            let t0 = Instant::now();
            let r = qf.pop_timeout_pool(0, 0, Duration::from_secs(30));
            (r, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(50)); // let both park
        // One item into the fast pool: only the fast consumer may take
        // it (backlog 1 never crosses the slow consumer's gate).
        q.push_pool(0, 9).unwrap();
        let (r, dt) = fast.join().unwrap();
        assert_eq!(r, Popped::Item(9));
        assert!(dt < Duration::from_secs(5), "eligible consumer woke after {dt:?}");
        assert_eq!(slow.join().unwrap(), Popped::TimedOut, "gate must hold");
        assert_eq!(q.spills(), 0);
        // Crossing the gate must wake a parked gated consumer into a
        // spill: its ready() flips once the victim backlog exceeds 2.5.
        let qs = q.clone();
        let slow = std::thread::spawn(move || {
            let t0 = Instant::now();
            let r = qs.pop_timeout_pool(1, 0, Duration::from_secs(30));
            (r, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(50)); // let it park
        for i in 0..3 {
            q.push_pool(0, 10 + i).unwrap();
        }
        let (r, dt) = slow.join().unwrap();
        assert!(matches!(r, Popped::Item(_)), "gate crossed: must spill, got {r:?}");
        assert!(dt < Duration::from_secs(5), "gated consumer woke after {dt:?}");
        assert_eq!(q.spills(), 1);
    }

    #[test]
    fn single_pool_pooled_api_matches_the_unpooled_api_exactly() {
        // new(capacity, k) == new_pooled(capacity, &[k]), and the pooled
        // consumer entry points reduce to the un-pooled ones: same drain
        // order, same steal counts, no spill path.
        let a: ShardedQueue<u64> = ShardedQueue::new(16, 4);
        let b: ShardedQueue<u64> = ShardedQueue::new_pooled(16, &[4]);
        for i in 0..8 {
            a.push(i).unwrap();
            b.push_pool(0, i).unwrap();
        }
        for _ in 0..8 {
            let x = a.pop_timeout(2, Duration::from_millis(1));
            let y = b.pop_timeout_pool(0, 2, Duration::from_millis(1));
            assert_eq!(x, y);
        }
        assert_eq!(a.steals(), b.steals());
        assert_eq!(b.spills(), 0);
        assert_eq!(b.pool_len(0), 0);
    }

    #[test]
    fn pooled_mpmc_conserves_across_pools_under_racing_consumers() {
        // 2 producers per pool, consumers on both pools racing, pool 1's
        // shards reachable by pool 0 only via spill: every item must
        // come out exactly once and per-pool FIFO must never invert for
        // items served by their own pool.
        let per = 800u64;
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new_pooled(8192, &[2, 2]));
        let producers: Vec<_> = (0..2usize)
            .map(|pool| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push_pool(pool, pool as u64 * per + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = [(0usize, 0usize), (0, 1), (1, 0), (1, 1)]
            .into_iter()
            .map(|(pool, w)| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_batch_pool(pool, w, 5, Duration::from_millis(100)) {
                            Popped::Item(items) => {
                                assert!(!items.is_empty() && items.len() <= 5);
                                got.extend(items);
                            }
                            Popped::TimedOut => {}
                            Popped::Closed => break,
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        assert_eq!(all, (0..2 * per).collect::<Vec<u64>>());
        assert_eq!(q.len(), 0);
        assert_eq!(q.pool_len(0), 0);
        assert_eq!(q.pool_len(1), 0);
    }

    #[test]
    fn sharded_mpmc_conserves_items_and_never_spuriously_rejects() {
        // 4 producers x 1000 items through 4 racing consumers. At most
        // 4000 items ever exist and capacity is 4000, so admission may
        // never report Full (each item holds at most one reserved slot,
        // and a consumer frees the slot before the item could ever be
        // re-pushed); every item must come out exactly once.
        let n_prod = 4usize;
        let per = 1000u64;
        let q: Arc<ShardedQueue<u64>> =
            Arc::new(ShardedQueue::new((n_prod as u64 * per) as usize, 4));
        let producers: Vec<_> = (0..n_prod)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push(p as u64 * per + i).unwrap(); // Full = bug
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4usize)
            .map(|w| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_timeout(w, Duration::from_millis(100)) {
                            Popped::Item(v) => got.push(v),
                            Popped::TimedOut => {}
                            Popped::Closed => break,
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        assert_eq!(all, (0..n_prod as u64 * per).collect::<Vec<u64>>());
        assert_eq!(q.len(), 0);
    }

    // ---- both shard-storage backends --------------------------------
    //
    // Every behavioral pin above runs on the default (mutex) backend
    // unmodified. The tests below run the same contracts across BOTH
    // backends through one parameterized body, plus the ring-only
    // divergence (per-shard bound backpressure).

    fn backends() -> [QueueBackend; 2] {
        [QueueBackend::Mutex, QueueBackend::Ring]
    }

    #[test]
    fn both_backends_report_their_backend() {
        for backend in backends() {
            let q: ShardedQueue<u64> = ShardedQueue::new_backend(16, 2, backend);
            assert_eq!(q.backend(), backend);
        }
        let q: ShardedQueue<u64> = ShardedQueue::new(16, 2);
        assert_eq!(q.backend(), QueueBackend::Mutex, "default stays the seed mechanics");
    }

    #[test]
    fn both_backends_round_robin_and_per_shard_fifo() {
        for backend in backends() {
            let q: ShardedQueue<u64> = ShardedQueue::new_backend(64, 4, backend);
            for i in 0..8 {
                q.push(i).unwrap();
            }
            assert_eq!(q.len(), 8, "{}", backend.name());
            assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(2));
            assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(6));
            assert_eq!(q.steals(), 0, "{}", backend.name());
            assert_eq!(q.pop_timeout(2, Duration::from_millis(1)), Popped::Item(3));
            assert_eq!(q.steals(), 1, "{}", backend.name());
        }
    }

    #[test]
    fn both_backends_enforce_the_aggregate_capacity() {
        for backend in backends() {
            let q: ShardedQueue<u64> = ShardedQueue::new_backend(3, 2, backend);
            q.push(0).unwrap();
            q.push(1).unwrap();
            q.push(2).unwrap();
            assert_eq!(q.push(3), Err(QueueError::Full), "{}", backend.name());
            assert_eq!(q.len(), 3);
            assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Item(0));
            q.push(4).unwrap(); // a freed slot readmits
            assert_eq!(q.len(), 3, "{}", backend.name());
        }
    }

    #[test]
    fn both_backends_steal_half_the_victim_in_one_operation() {
        // The steal-correctness pin, across backends: half the victim's
        // backlog in ONE operation — one lock acquisition (mutex) / one
        // CAS-reserved slot run (ring) — and exactly one steal-counter
        // increment either way.
        for backend in backends() {
            let q: ShardedQueue<u64> = ShardedQueue::new_backend(64, 2, backend);
            for i in 0..16 {
                q.push(i).unwrap();
            }
            assert_eq!(
                q.pop_batch(1, 64, Duration::from_millis(1)),
                Popped::Item(vec![1, 3, 5, 7, 9, 11, 13, 15])
            );
            assert_eq!(q.steals(), 0, "{}: home drain is not a steal", backend.name());
            assert_eq!(
                q.pop_batch(1, 64, Duration::from_millis(1)),
                Popped::Item(vec![0, 2, 4, 6])
            );
            assert_eq!(q.steals(), 1, "{}: one batch steal = one steal op", backend.name());
            assert_eq!(
                q.pop_batch(1, 1, Duration::from_millis(1)),
                Popped::Item(vec![8])
            );
            assert_eq!(q.steals(), 2, "{}", backend.name());
        }
    }

    #[test]
    fn both_backends_close_drain_then_closed_for_all_parked_consumers() {
        for backend in backends() {
            // Drain-then-closed for a poller…
            let q: ShardedQueue<u64> = ShardedQueue::new_backend(8, 2, backend);
            q.push(1).unwrap();
            q.push(2).unwrap();
            q.close();
            assert_eq!(q.push(3), Err(QueueError::Closed), "{}", backend.name());
            assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Item(1));
            assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Item(2));
            assert_eq!(q.pop_timeout(0, Duration::from_millis(1)), Popped::Closed);
            // …and Closed must promptly reach every *parked* consumer.
            let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new_backend(8, 4, backend));
            let handles: Vec<_> = (0..4)
                .map(|w| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        let t0 = Instant::now();
                        let r = q.pop_timeout(w, Duration::from_secs(30));
                        (r, t0.elapsed())
                    })
                })
                .collect();
            std::thread::sleep(Duration::from_millis(50));
            q.close();
            for h in handles {
                let (r, dt) = h.join().unwrap();
                assert_eq!(r, Popped::Closed, "{}", backend.name());
                assert!(dt < Duration::from_secs(5), "{}: woke only after {dt:?}", backend.name());
            }
        }
    }

    #[test]
    fn both_backends_conserve_under_racing_producers_and_consumers() {
        // 4 producers x 1000 items, 4 batch consumers on the
        // scratch-buffer path: no loss, no duplication, either backend.
        for backend in backends() {
            let n_prod = 4usize;
            let per = 1000u64;
            let q: Arc<ShardedQueue<u64>> =
                Arc::new(ShardedQueue::new_backend((n_prod as u64 * per) as usize, 4, backend));
            let producers: Vec<_> = (0..n_prod)
                .map(|p| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        for i in 0..per {
                            q.push(p as u64 * per + i).unwrap(); // Full = bug: rr fits the even share
                        }
                    })
                })
                .collect();
            let consumers: Vec<_> = (0..4usize)
                .map(|w| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        let mut got = Vec::new();
                        let mut buf = Vec::with_capacity(8);
                        loop {
                            match q.pop_batch_pool_into(0, w, 7, Duration::from_millis(100), &mut buf)
                            {
                                Popped::Item(n) => {
                                    assert!(n == buf.len() && (1..=7).contains(&n));
                                    got.extend_from_slice(&buf);
                                }
                                Popped::TimedOut => {}
                                Popped::Closed => break,
                            }
                        }
                        got
                    })
                })
                .collect();
            for p in producers {
                p.join().unwrap();
            }
            q.close();
            let mut all: Vec<u64> = Vec::new();
            for c in consumers {
                all.extend(c.join().unwrap());
            }
            all.sort_unstable();
            assert_eq!(all, (0..n_prod as u64 * per).collect::<Vec<u64>>(), "{}", backend.name());
            assert_eq!(q.len(), 0, "{}", backend.name());
        }
    }

    #[test]
    fn pop_batch_pool_into_reuses_the_caller_buffer() {
        for backend in backends() {
            let q: ShardedQueue<u64> = ShardedQueue::new_backend(64, 1, backend);
            let mut buf: Vec<u64> = Vec::with_capacity(16);
            for i in 0..10 {
                q.push(i).unwrap();
            }
            assert_eq!(
                q.pop_batch_pool_into(0, 0, 4, Duration::from_millis(1), &mut buf),
                Popped::Item(4)
            );
            assert_eq!(buf, vec![0, 1, 2, 3]);
            let ptr = buf.as_ptr();
            assert_eq!(
                q.pop_batch_pool_into(0, 0, 4, Duration::from_millis(1), &mut buf),
                Popped::Item(4)
            );
            assert_eq!(buf, vec![4, 5, 6, 7]);
            assert_eq!(buf.as_ptr(), ptr, "{}: scratch reused, not reallocated", backend.name());
            assert_eq!(
                q.pop_batch_pool_into(0, 0, 4, Duration::from_millis(1), &mut buf),
                Popped::Item(2)
            );
            assert_eq!(buf, vec![8, 9]);
            assert_eq!(
                q.pop_batch_pool_into(0, 0, 4, Duration::from_millis(1), &mut buf),
                Popped::TimedOut
            );
            assert!(buf.is_empty(), "cleared on non-Item outcomes");
        }
    }

    #[test]
    fn ring_shard_backpressure_full_rolls_back_the_reservation() {
        // capacity 16 over 4 shards -> each ring bounds ⌈16/4⌉ = 4. Skew
        // the backlog onto shard 0 (drain every other shard), then push
        // with the router cursor pointing at the full shard: admission
        // must surface Full AND release the aggregate reservation it
        // took, so the next push (routed to an empty shard) is admitted.
        let q: ShardedQueue<u64> = ShardedQueue::new_backend(16, 4, QueueBackend::Ring);
        for i in 0..16 {
            q.push(i).unwrap();
        }
        for w in 1..4usize {
            for _ in 0..4 {
                assert!(matches!(q.pop_timeout(w, Duration::from_millis(1)), Popped::Item(_)));
            }
        }
        assert_eq!(q.steals(), 0, "home shards held all four items each");
        assert_eq!(q.len(), 4, "only shard 0's items remain");
        // Cursor is at 16 -> shard 0, whose ring is still full.
        assert_eq!(q.push(99), Err(QueueError::Full));
        assert_eq!(q.len(), 4, "failed push must roll back its reservation");
        // Cursor advanced to 17 -> shard 1 (empty ring): admitted.
        q.push(100).unwrap();
        assert_eq!(q.len(), 5);
        assert_eq!(q.pop_timeout(1, Duration::from_millis(1)), Popped::Item(100));
    }
}
