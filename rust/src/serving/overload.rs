//! The overload plane — SLO classes, deadline-aware admission and
//! shedding, in-queue expiry, and brownout degradation — applied
//! identically in the live executor and the DES.
//!
//! PR 7's resilience plane handles *component failure*; this module
//! handles *sustained overload*, where every queued request competes
//! for capacity that no longer covers the offered load. Four
//! mechanisms, all pure state machines driven by either clock:
//!
//! * **SLO classes.** Requests carry a [`ClassSpec`] (mix weight,
//!   deadline, rung floor) parsed from
//!   `--classes gold:0.2:500,silver:0.5:2000,bronze:0.3:0`. The class
//!   of a request is a *deterministic hash of its id*
//!   ([`crate::workload::gen::class_of_id`]) — never threaded through
//!   queues or records — so the live executor, the DES and post-hoc
//!   log analysis all assign identical classes, and arrivals stay
//!   bit-identical whether the plane is on or off.
//! * **Deadline-aware admission** ([`OverloadConfig::admit`]). On
//!   pressure the victim is the request that is *already doomed*
//!   (least slack) or of the *lowest class* — not the newest. The
//!   per-class thresholds generalize the AQM's Eq. 10 depth budget
//!   (`N = w·Δ/s̄`, [`crate::planner::aqm::admission_depth_budget`])
//!   with the class-effective deadline as the slack: a finite-deadline
//!   request sheds once the backlog ahead of it already exceeds what
//!   `w` workers can drain within its deadline, and lower classes are
//!   admitted only into nested shares of the tightest class's budget,
//!   so bronze load can never queue gold into doom. The tail-drop
//!   alternative (`shed=tail`) drops the newest at a fixed depth —
//!   kept as the comparison twin the scenario matrix gates against.
//! * **In-queue expiry** ([`OverloadConfig::expired`]). Workers
//!   skip-and-count requests whose deadline already passed at pop time
//!   — lazy, no scanner thread — so stale work never occupies a
//!   server.
//! * **Brownout** ([`Brownout`]). A deadline-pressure EWMA (fraction
//!   of pops that would finish past their deadline) steps the
//!   *effective* rung down — toward the fast end — before shedding
//!   starts, and steps back up on recovery. The hysteresis mirrors
//!   PR 7's circuit breaker: a trip threshold with a minimum-sample
//!   guard, a lower recovery threshold, and re-arming after every
//!   step. The offset is bounded by `brownout_max_steps`, so the
//!   effective rung never leaves the policy's no-switch band
//!   `[rung − max_steps, rung]` — brownout degrades within the band;
//!   it never countermands an explicit policy switch.
//!
//! Conservation extends to
//! `served + rejected + failed + shed + expired == arrivals` in both
//! executors, and everything is **off by default**: a disabled
//! [`OverloadConfig`] admits everything, expires nothing, browns out
//! never — the executors skip the overload branches entirely, so a
//! disabled run is bit-identical to the pre-overload engine (the same
//! precedent as the disabled resilience plane, pinned by
//! `tests/overload.rs`).

use anyhow::Result;

use super::topology::Topology;
use crate::planner::aqm::admission_depth_budget;
use crate::workload::gen::class_of_id;

/// One SLO class of the request mix: a weight (share of arrivals), a
/// deadline (0 = none), and a rung floor (never serve this class below
/// that rung).
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSpec {
    pub name: String,
    /// Share of arrivals (weights need not sum to 1; they are
    /// normalized by the assignment hash).
    pub weight: f64,
    /// Per-request deadline in ms from arrival; 0 = no deadline.
    pub deadline_ms: f64,
    /// Minimum ladder rung this class is served at (0 = no floor),
    /// enforced via [`Topology::exec_rung_floor`].
    pub rung_floor: usize,
}

/// Parse `--classes name:weight:deadline_ms[:rung_floor],...`, e.g.
/// `gold:0.2:500,silver:0.5:2000,bronze:0.3:0`. Classes are listed in
/// priority order (first = highest).
pub fn parse_classes(s: &str) -> Result<Vec<ClassSpec>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        anyhow::ensure!(
            fields.len() == 3 || fields.len() == 4,
            "class spec {part:?} wants name:weight:deadline_ms[:rung_floor]"
        );
        let weight: f64 = fields[1]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad class weight {:?} in {part:?}", fields[1]))?;
        anyhow::ensure!(weight > 0.0, "class weight must be positive in {part:?}");
        let deadline_ms: f64 = fields[2]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad class deadline {:?} in {part:?}", fields[2]))?;
        anyhow::ensure!(deadline_ms >= 0.0, "class deadline must be >= 0 in {part:?}");
        let rung_floor: usize = match fields.get(3) {
            None => 0,
            Some(f) => f
                .parse()
                .map_err(|_| anyhow::anyhow!("bad class rung floor {f:?} in {part:?}"))?,
        };
        out.push(ClassSpec {
            name: fields[0].to_string(),
            weight,
            deadline_ms,
            rung_floor,
        });
    }
    anyhow::ensure!(!out.is_empty(), "empty class list");
    Ok(out)
}

/// The paper-style three-tier default mix:
/// `gold:0.2:500,silver:0.5:2000,bronze:0.3:0`.
pub fn default_classes() -> Vec<ClassSpec> {
    parse_classes("gold:0.2:500,silver:0.5:2000,bronze:0.3:0").expect("default classes parse")
}

/// Overload-plane configuration. `Default` is **disabled**: every
/// query degenerates to the historical behavior (admit everything,
/// nothing expires, brownout never steps) and the executors skip the
/// overload branches entirely, so a disabled run is bit-identical to
/// the pre-overload engine.
#[derive(Clone, Debug, PartialEq)]
pub struct OverloadConfig {
    pub enabled: bool,
    /// `true` = deadline-aware shedding (doomed / lowest-class victim,
    /// the default); `false` = tail-drop the newest at `shed_depth`
    /// (the comparison twin).
    pub deadline_aware: bool,
    /// DES-only class-priority service order (highest class first
    /// within a shard, FIFO within a class) — used by the two-class
    /// M/M/k theory validation; off by default so live and DES cells
    /// share FIFO semantics.
    pub priority: bool,
    /// Tail-drop threshold, and the cap on every deadline-aware
    /// admission budget.
    pub shed_depth: usize,
    /// Brownout EWMA smoothing factor.
    pub brownout_alpha: f64,
    /// Deadline-pressure level that steps the effective rung down.
    pub brownout_threshold: f64,
    /// Pressure level below which a brownout step is undone.
    pub brownout_recover: f64,
    /// Pops required before the EWMA may trigger a step (re-armed
    /// after every step, the hysteresis guard).
    pub brownout_min_samples: u32,
    /// Bound on the brownout offset: the effective rung never leaves
    /// `[rung − max_steps, rung]`.
    pub brownout_max_steps: usize,
    /// The SLO classes, in priority order (first = highest).
    pub classes: Vec<ClassSpec>,
    /// Per-rung mean service times (ms) the **live** executor feeds the
    /// admission budgets and the brownout risk signal (the DES reads
    /// its plan ladder directly). Not part of the CLI grammar — the
    /// harness fills it from the plan via
    /// [`with_rung_means`](OverloadConfig::with_rung_means). Empty = no
    /// service-time knowledge: deadline budgets degenerate to the
    /// `shed_depth` cap (still class-ordered, no longer
    /// deadline-calibrated).
    pub rung_means_ms: Vec<f64>,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            enabled: false,
            deadline_aware: true,
            priority: false,
            shed_depth: 256,
            brownout_alpha: 0.2,
            brownout_threshold: 0.5,
            brownout_recover: 0.1,
            brownout_min_samples: 20,
            brownout_max_steps: 1,
            classes: default_classes(),
            rung_means_ms: Vec::new(),
        }
    }
}

impl OverloadConfig {
    /// The plane enabled with every default knob (deadline-aware
    /// shedding over the default three-tier mix).
    pub fn enabled() -> OverloadConfig {
        OverloadConfig { enabled: true, ..OverloadConfig::default() }
    }

    /// The tail-drop twin: the plane on (classes, expiry, brownout all
    /// identical) but shedding the *newest* request at `shed_depth` —
    /// the control the scenario matrix compares deadline-aware
    /// shedding against.
    pub fn tail_drop() -> OverloadConfig {
        OverloadConfig { deadline_aware: false, ..OverloadConfig::enabled() }
    }

    /// Same config with another class mix.
    pub fn with_classes(self, classes: Vec<ClassSpec>) -> OverloadConfig {
        OverloadConfig { classes, ..self }
    }

    /// Same config with the per-rung mean service times the live
    /// executor should assume (typically the plan ladder's means).
    pub fn with_rung_means(self, rung_means_ms: Vec<f64>) -> OverloadConfig {
        OverloadConfig { rung_means_ms, ..self }
    }

    /// The assumed mean service time (ms) at `rung` for live admission
    /// and brownout-risk arithmetic; 0 when no means were provided.
    pub fn mean_at(&self, rung: usize) -> f64 {
        self.rung_means_ms.get(rung).copied().unwrap_or(0.0)
    }

    /// Parse `--overload off` / `--overload on[,key=value,...]`.
    /// Keys: `shed=deadline|tail`, `priority=on|off`, `shed_depth`,
    /// `brownout_alpha`, `brownout_threshold`, `brownout_recover`,
    /// `brownout_min_samples`, `brownout_max_steps`. The class mix
    /// comes from `--classes` ([`parse_classes`]).
    pub fn parse(s: &str) -> Result<OverloadConfig> {
        let mut cfg = OverloadConfig::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part {
                "on" | "enabled" => cfg.enabled = true,
                "off" | "disabled" => cfg.enabled = false,
                _ => {
                    let Some((key, value)) = part.split_once('=') else {
                        anyhow::bail!("overload option {part:?} wants key=value");
                    };
                    let num = || -> Result<f64> {
                        value.parse().map_err(|_| {
                            anyhow::anyhow!("bad overload value {value:?} for {key:?}")
                        })
                    };
                    match key {
                        "shed" => match value {
                            "deadline" => cfg.deadline_aware = true,
                            "tail" => cfg.deadline_aware = false,
                            other => anyhow::bail!("shed expects deadline|tail, got {other:?}"),
                        },
                        "priority" => match value {
                            "on" => cfg.priority = true,
                            "off" => cfg.priority = false,
                            other => anyhow::bail!("priority expects on|off, got {other:?}"),
                        },
                        "shed_depth" => cfg.shed_depth = num()?.max(1.0) as usize,
                        "brownout_alpha" => cfg.brownout_alpha = num()?,
                        "brownout_threshold" => cfg.brownout_threshold = num()?,
                        "brownout_recover" => cfg.brownout_recover = num()?,
                        "brownout_min_samples" => cfg.brownout_min_samples = num()? as u32,
                        "brownout_max_steps" => cfg.brownout_max_steps = num()? as usize,
                        other => anyhow::bail!("unknown overload key {other:?}"),
                    }
                }
            }
        }
        Ok(cfg)
    }

    /// The class index of request `id` — a pure function of the id and
    /// the mix weights, identical in both executors and in post-hoc
    /// log analysis. Class 0 when the plane is disabled.
    pub fn class_of(&self, id: u64) -> usize {
        if !self.enabled || self.classes.is_empty() {
            return 0;
        }
        let weights: Vec<f64> = self.classes.iter().map(|c| c.weight).collect();
        class_of_id(id, &weights)
    }

    /// The class name of request `id` (`"-"` when disabled).
    pub fn class_name(&self, id: u64) -> &str {
        if !self.enabled || self.classes.is_empty() {
            return "-";
        }
        &self.classes[self.class_of(id)].name
    }

    /// The *relative* deadline (ms after arrival) of request `id`'s
    /// class; 0 when the plane is off or the class has none. This is
    /// the value the request-log schema persists
    /// ([`crate::workload::trace::RequestLogRow::deadline_ms`]).
    pub fn class_deadline_ms(&self, id: u64) -> f64 {
        if !self.enabled || self.classes.is_empty() {
            return 0.0;
        }
        self.classes[self.class_of(id)].deadline_ms
    }

    /// The absolute deadline (ms) of request `id` arriving at
    /// `arrival_ms`; infinite when the plane is off or the class has
    /// no deadline.
    pub fn deadline_ms(&self, id: u64, arrival_ms: f64) -> f64 {
        if !self.enabled || self.classes.is_empty() {
            return f64::INFINITY;
        }
        let d = self.classes[self.class_of(id)].deadline_ms;
        if d <= 0.0 {
            f64::INFINITY
        } else {
            arrival_ms + d
        }
    }

    /// Lazy in-queue expiry: has request `id`'s deadline already
    /// passed at pop time? Always `false` when disabled.
    pub fn expired(&self, id: u64, arrival_ms: f64, now_ms: f64) -> bool {
        self.enabled && now_ms > self.deadline_ms(id, arrival_ms)
    }

    /// The brownout pressure signal: would a pop starting service now
    /// at a rung with mean `mean_ms` finish past its deadline?
    pub fn at_risk(&self, id: u64, arrival_ms: f64, now_ms: f64, mean_ms: f64) -> bool {
        self.enabled && now_ms + mean_ms > self.deadline_ms(id, arrival_ms)
    }

    /// The rung floor of request `id`'s class (0 when disabled).
    pub fn rung_floor(&self, id: u64) -> usize {
        if !self.enabled || self.classes.is_empty() {
            return 0;
        }
        self.classes[self.class_of(id)].rung_floor
    }

    /// Admission decision for request `id` arriving to a backlog of
    /// `depth`, drained by `workers` servers at mean service `mean_ms`.
    ///
    /// Tail-drop mode sheds any class at `shed_depth` (newest loses —
    /// the classic bounded queue). Deadline-aware mode generalizes
    /// Eq. 10's depth budget `N = w·Δ/s̄` with the class-effective
    /// deadline as the slack:
    ///
    /// * **doomed check** — a finite-deadline request sheds when the
    ///   backlog already exceeds its own budget `w·d_c/s̄` (it would
    ///   expire in queue; shedding it now is free);
    /// * **nested class shares** — class `c` (rank `c` of `n`) is
    ///   admitted only while `depth < guard·(n−c)/n`, where `guard` is
    ///   the *tightest* class's budget (capped at `shed_depth`) — so
    ///   lower classes stop queueing before they can doom the classes
    ///   above them, and the shallow end of the queue is reserved for
    ///   the traffic that can still meet its targets.
    ///
    /// Always `true` when disabled.
    pub fn admit(&self, id: u64, depth: usize, mean_ms: f64, workers: usize) -> bool {
        if !self.enabled {
            return true;
        }
        if !self.deadline_aware {
            return depth < self.shed_depth;
        }
        let w = workers.max(1) as f64;
        let d = depth as f64;
        let c = self.class_of(id);
        let budget_of = |spec: &ClassSpec| -> f64 {
            if spec.deadline_ms > 0.0 {
                admission_depth_budget(w, spec.deadline_ms, mean_ms)
            } else {
                f64::INFINITY
            }
        };
        if !self.classes.is_empty() && d >= budget_of(&self.classes[c]) {
            return false; // already doomed: cannot make its own deadline
        }
        let guard = self
            .classes
            .iter()
            .map(budget_of)
            .fold(self.shed_depth as f64, f64::min);
        let n = self.classes.len().max(1) as f64;
        d < guard * (n - c as f64) / n
    }

    /// Per-class SLO compliance over a run: for each class, the
    /// fraction of its arrivals (ids `0..n_arrivals`) served within
    /// the class target — its deadline when set, else `slo_ms`. With
    /// the plane disabled there is one implicit class whose target is
    /// the SLO, so the vector degenerates to `[slo_compliance]`.
    pub fn class_compliance(
        &self,
        records: &[crate::metrics::RequestRecord],
        n_arrivals: usize,
        slo_ms: f64,
    ) -> Vec<f64> {
        let n_classes = if self.enabled { self.classes.len().max(1) } else { 1 };
        let mut arrivals = vec![0usize; n_classes];
        for id in 0..n_arrivals as u64 {
            arrivals[self.class_of(id)] += 1;
        }
        let mut within = vec![0usize; n_classes];
        for r in records {
            let c = self.class_of(r.id);
            let target = if self.enabled && self.classes[c].deadline_ms > 0.0 {
                self.classes[c].deadline_ms
            } else {
                slo_ms
            };
            if r.latency_ms() <= target {
                within[c] += 1;
            }
        }
        (0..n_classes)
            .map(|c| {
                if arrivals[c] == 0 {
                    1.0
                } else {
                    within[c] as f64 / arrivals[c] as f64
                }
            })
            .collect()
    }
}

impl Topology {
    /// [`Topology::exec_rung`] with a class floor: the requested rung
    /// is raised to `floor` *before* the pool-band clamp, so a
    /// floored class is never served below its floor — unless the
    /// executing pool's entire band lies below it, in which case the
    /// band top (the closest that pool can get) is used.
    pub fn exec_rung_floor(
        &self,
        pool: usize,
        policy_rung: usize,
        floor: usize,
        n_rungs: usize,
    ) -> usize {
        self.exec_rung(pool, policy_rung.max(floor), n_rungs)
    }
}

/// The brownout state machine: a deadline-pressure EWMA over pop
/// observations that steps the effective rung down (toward the fast
/// end) under sustained pressure and back up on recovery — the same
/// trip/probe-back hysteresis shape as the resilience plane's circuit
/// breaker, driven by either executor's clock.
#[derive(Clone, Debug)]
pub struct Brownout {
    enabled: bool,
    alpha: f64,
    threshold: f64,
    recover: f64,
    min_samples: u32,
    max_steps: usize,
    ewma: f64,
    samples: u32,
    offset: usize,
    /// Total step-down events over the run (reported as
    /// `brownout_steps`).
    pub steps: u64,
}

impl Brownout {
    pub fn new(cfg: &OverloadConfig) -> Brownout {
        Brownout {
            enabled: cfg.enabled,
            alpha: cfg.brownout_alpha,
            threshold: cfg.brownout_threshold,
            recover: cfg.brownout_recover,
            min_samples: cfg.brownout_min_samples,
            max_steps: cfg.brownout_max_steps,
            ewma: 0.0,
            samples: 0,
            offset: 0,
            steps: 0,
        }
    }

    /// Record one pop observation (`at_risk` = the request would
    /// finish past its deadline). May step the offset down (pressure
    /// over the threshold) or up (pressure under the recovery level);
    /// the min-sample guard re-arms after every step, so steps are
    /// spaced — the hysteresis.
    pub fn observe_pop(&mut self, at_risk: bool) {
        if !self.enabled {
            return;
        }
        let x = if at_risk { 1.0 } else { 0.0 };
        self.ewma += self.alpha * (x - self.ewma);
        self.samples += 1;
        if self.samples < self.min_samples {
            return;
        }
        if self.ewma > self.threshold && self.offset < self.max_steps {
            self.offset += 1;
            self.steps += 1;
            self.samples = 0;
        } else if self.ewma < self.recover && self.offset > 0 {
            self.offset -= 1;
            self.samples = 0;
        }
    }

    /// The current degradation offset (0 = no brownout).
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// The effective rung under brownout: the policy rung lowered by
    /// the offset, never leaving `[rung − max_steps, rung]` (the
    /// brownout band) and never below rung 0.
    pub fn effective_rung(&self, policy_rung: usize) -> usize {
        policy_rung.saturating_sub(self.offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_config_is_inert() {
        let cfg = OverloadConfig::default();
        assert!(!cfg.enabled);
        for id in 0..50u64 {
            assert_eq!(cfg.class_of(id), 0);
            assert_eq!(cfg.class_name(id), "-");
            assert_eq!(cfg.rung_floor(id), 0);
            assert!(cfg.deadline_ms(id, 0.0).is_infinite());
            assert!(!cfg.expired(id, 0.0, 1e12));
            assert!(!cfg.at_risk(id, 0.0, 1e12, 1e6));
            assert!(cfg.admit(id, usize::MAX, 10.0, 1));
        }
        let mut b = Brownout::new(&cfg);
        for _ in 0..10_000 {
            b.observe_pop(true);
        }
        assert_eq!(b.offset(), 0);
        assert_eq!(b.steps, 0);
        assert_eq!(b.effective_rung(3), 3);
    }

    #[test]
    fn parse_classes_grammar() {
        let classes = parse_classes("gold:0.2:500,silver:0.5:2000,bronze:0.3:0").unwrap();
        assert_eq!(classes.len(), 3);
        assert_eq!(classes[0].name, "gold");
        assert_eq!(classes[0].deadline_ms, 500.0);
        assert_eq!(classes[0].rung_floor, 0);
        assert_eq!(classes[2].deadline_ms, 0.0, "0 = no deadline");
        // Optional 4th field: the rung floor.
        let floored = parse_classes("gold:1:500:2").unwrap();
        assert_eq!(floored[0].rung_floor, 2);
        assert!(parse_classes("").is_err());
        assert!(parse_classes("gold:0.2").is_err());
        assert!(parse_classes("gold:-1:500").is_err());
        assert!(parse_classes("gold:0.2:oops").is_err());
        assert!(parse_classes("gold:0.2:500:x").is_err());
    }

    #[test]
    fn parse_roundtrips_the_knobs() {
        let cfg = OverloadConfig::parse(
            "on,shed=tail,priority=on,shed_depth=64,brownout_alpha=0.4,\
             brownout_threshold=0.6,brownout_recover=0.05,brownout_min_samples=9,\
             brownout_max_steps=2",
        )
        .unwrap();
        assert!(cfg.enabled);
        assert!(!cfg.deadline_aware);
        assert!(cfg.priority);
        assert_eq!(cfg.shed_depth, 64);
        assert_eq!(cfg.brownout_alpha, 0.4);
        assert_eq!(cfg.brownout_threshold, 0.6);
        assert_eq!(cfg.brownout_recover, 0.05);
        assert_eq!(cfg.brownout_min_samples, 9);
        assert_eq!(cfg.brownout_max_steps, 2);
        assert_eq!(OverloadConfig::parse("off").unwrap(), OverloadConfig::default());
        assert!(OverloadConfig::parse("on,bogus=1").is_err());
        assert!(OverloadConfig::parse("on,shed=sideways").is_err());
        assert!(OverloadConfig::parse("on,shed_depth=abc").is_err());
    }

    #[test]
    fn class_assignment_is_deterministic_and_matches_the_mix() {
        let cfg = OverloadConfig::enabled();
        let n = 100_000u64;
        let mut counts = [0usize; 3];
        for id in 0..n {
            let c = cfg.class_of(id);
            assert_eq!(c, cfg.class_of(id), "same id, same class, always");
            counts[c] += 1;
        }
        // gold:0.2, silver:0.5, bronze:0.3 within 2% absolute.
        for (c, want) in [(0usize, 0.2f64), (1, 0.5), (2, 0.3)] {
            let got = counts[c] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "class {c}: {got} vs {want}");
        }
    }

    #[test]
    fn deadlines_and_expiry_follow_the_class() {
        let cfg = OverloadConfig::enabled();
        // Find one id of each class.
        let gold = (0..).find(|&id| cfg.class_of(id) == 0).unwrap();
        let bronze = (0..).find(|&id| cfg.class_of(id) == 2).unwrap();
        assert_eq!(cfg.deadline_ms(gold, 100.0), 600.0);
        assert!(cfg.deadline_ms(bronze, 100.0).is_infinite());
        assert!(!cfg.expired(gold, 100.0, 600.0), "at the deadline is not past it");
        assert!(cfg.expired(gold, 100.0, 600.1));
        assert!(!cfg.expired(bronze, 100.0, 1e12), "no deadline never expires");
        // at_risk fires earlier: now + mean past the deadline.
        assert!(cfg.at_risk(gold, 100.0, 550.0, 90.0));
        assert!(!cfg.at_risk(gold, 100.0, 400.0, 90.0));
    }

    #[test]
    fn tail_mode_sheds_the_newest_at_the_depth_bound() {
        let cfg = OverloadConfig { shed_depth: 8, ..OverloadConfig::tail_drop() };
        for id in 0..20u64 {
            assert!(cfg.admit(id, 7, 10.0, 2));
            assert!(!cfg.admit(id, 8, 10.0, 2), "class-blind at the bound");
        }
    }

    #[test]
    fn deadline_mode_sheds_doomed_and_low_class_first() {
        // mean 10 ms, 2 workers: gold (500 ms) budget = 2·500/10 = 100,
        // silver (2000 ms) = 400, bronze = ∞; guard = min(100, 256) =
        // 100. Nested shares: gold < 100, silver < 66.7, bronze < 33.3.
        let cfg = OverloadConfig::enabled();
        let gold = (0..).find(|&id| cfg.class_of(id) == 0).unwrap();
        let silver = (0..).find(|&id| cfg.class_of(id) == 1).unwrap();
        let bronze = (0..).find(|&id| cfg.class_of(id) == 2).unwrap();
        // Bronze stops first, then silver, gold last.
        assert!(cfg.admit(bronze, 33, 10.0, 2));
        assert!(!cfg.admit(bronze, 34, 10.0, 2));
        assert!(cfg.admit(silver, 66, 10.0, 2));
        assert!(!cfg.admit(silver, 67, 10.0, 2));
        assert!(cfg.admit(gold, 99, 10.0, 2));
        // The doomed check: at depth 100 gold cannot make 500 ms even
        // if everything drains perfectly.
        assert!(!cfg.admit(gold, 100, 10.0, 2));
    }

    #[test]
    fn brownout_steps_down_under_pressure_and_recovers() {
        let cfg = OverloadConfig {
            brownout_min_samples: 5,
            brownout_max_steps: 2,
            ..OverloadConfig::enabled()
        };
        let mut b = Brownout::new(&cfg);
        // Sustained pressure: EWMA crosses 0.5 after the sample guard.
        let mut downs = 0;
        for _ in 0..40 {
            let before = b.offset();
            b.observe_pop(true);
            if b.offset() > before {
                downs += 1;
            }
        }
        assert_eq!(b.offset(), 2, "stepped to the bound");
        assert_eq!(b.steps, 2);
        assert_eq!(downs, 2, "steps are spaced by the re-armed guard");
        // Recovery: pressure falls below the recover threshold and the
        // offset walks back up to 0.
        for _ in 0..200 {
            b.observe_pop(false);
        }
        assert_eq!(b.offset(), 0, "recovered");
        assert_eq!(b.steps, 2, "recovery does not count as a step");
    }

    #[test]
    fn brownout_never_exits_the_no_switch_band() {
        let cfg = OverloadConfig {
            brownout_min_samples: 1,
            brownout_max_steps: 2,
            ..OverloadConfig::enabled()
        };
        let mut b = Brownout::new(&cfg);
        for i in 0..10_000 {
            b.observe_pop(i % 3 != 0);
            assert!(b.offset() <= 2, "offset bounded by max_steps");
            for rung in 0..5usize {
                let eff = b.effective_rung(rung);
                assert!(eff <= rung, "brownout only degrades");
                assert!(eff >= rung.saturating_sub(2), "within the band");
            }
        }
    }

    #[test]
    fn brownout_min_sample_guard_holds() {
        let cfg =
            OverloadConfig { brownout_min_samples: 50, ..OverloadConfig::enabled() };
        let mut b = Brownout::new(&cfg);
        for _ in 0..49 {
            b.observe_pop(true);
            assert_eq!(b.offset(), 0, "no step before the guard fills");
        }
        b.observe_pop(true);
        assert_eq!(b.offset(), 1);
    }

    #[test]
    fn rung_floor_is_enforced_through_the_pool_band_clamp() {
        use crate::serving::pool::parse_pools;
        let pools = parse_pools("fast:2:1.0,accurate:2:2.5").unwrap();
        let t = Topology::from_pools(&pools, 0.0).unwrap();
        // No floor: the historical exec_rung.
        assert_eq!(t.exec_rung_floor(0, 1, 0, 2), t.exec_rung(0, 1, 2));
        // A floor raises the requested rung before the band clamp: the
        // accurate pool serves rung 1 even when the policy sits at 0.
        assert_eq!(t.exec_rung_floor(1, 0, 1, 2), 1);
        // A pool whose whole band is below the floor serves its band
        // top — the closest it can get.
        assert_eq!(t.exec_rung_floor(0, 0, 1, 2), 0);
    }

    #[test]
    fn class_compliance_scores_against_class_targets() {
        use crate::metrics::RequestRecord;
        let cfg = OverloadConfig::enabled();
        let gold = (0..).find(|&id| cfg.class_of(id) == 0).unwrap();
        let bronze = (0..).find(|&id| cfg.class_of(id) == 2).unwrap();
        let mk = |id: u64, latency: f64| RequestRecord {
            id,
            arrival_ms: 0.0,
            start_ms: 0.0,
            finish_ms: latency,
            config_idx: 0,
            accuracy: 0.8,
            success: None,
        };
        // One gold in deadline, one bronze far past the gold deadline
        // but inside the SLO (bronze has no deadline: SLO target).
        let records = vec![mk(gold, 400.0), mk(bronze, 900.0)];
        let n = (gold.max(bronze) + 1) as usize;
        let by_class = cfg.class_compliance(&records, n, 1000.0);
        assert_eq!(by_class.len(), 3);
        let gold_arrivals = (0..n as u64).filter(|&i| cfg.class_of(i) == 0).count();
        let bronze_arrivals = (0..n as u64).filter(|&i| cfg.class_of(i) == 2).count();
        assert!((by_class[0] - 1.0 / gold_arrivals as f64).abs() < 1e-12);
        assert!((by_class[2] - 1.0 / bronze_arrivals as f64).abs() < 1e-12);
        // Disabled: one implicit class scored against the SLO.
        let off = OverloadConfig::default();
        let flat = off.class_compliance(&records, n, 1000.0);
        assert_eq!(flat.len(), 1);
        assert!((flat[0] - 2.0 / n as f64).abs() < 1e-12);
    }
}
