//! The inference-serving system (paper §III-B): request queues, a load
//! monitor, the Elastico controller, and workflow executor threads —
//! the online phase of Compass.
//!
//! The controller logic lives in [`policy`] and is shared verbatim with
//! the discrete-event simulator ([`crate::sim`]), so simulated and live
//! behavior can be compared 1:1.
//!
//! ## Serving architecture (k workers, sharded hot path)
//!
//! The runtime is an M/G/k system ([`ServeOptions::workers`], default 1
//! = the paper's single-server testbed):
//!
//! * **one bounded [`ShardedQueue`]** is the admission point — requests
//!   route round-robin to per-worker shards ([`Discipline::ShardedSteal`])
//!   or to a single shard ([`Discipline::CentralFifo`], the exact seed
//!   semantics); a worker whose home shard runs dry steals the *front*
//!   of the next non-empty shard. Admission control and the AQM depth
//!   signal use a lock-free total-across-shards counter, a full queue
//!   rejects at push, and `close()` wakes every blocked worker for
//!   prompt shutdown;
//! * **k executor threads** drain the queue. PJRT handles are `!Send`,
//!   so each worker constructs its *own* engine inside its thread from a
//!   shared `Fn() -> Result<E>` factory; the run clock starts once the
//!   last worker finishes compiling, so engine startup never counts as
//!   queueing delay;
//! * **lock-light control plane**: the monitor's arrival counter is a
//!   plain atomic; the shared policy sits behind a handle that caches
//!   the current rung and the policy's no-switch depth band
//!   ([`ScalingPolicy::no_switch_band`]) in atomics — in the common
//!   case (no threshold crossing) arrivals, dequeues and departures
//!   never take the policy mutex. Threshold crossings and the periodic
//!   monitor tick run the full locked decision and append to the one
//!   switch audit trail, so the pool still adapts as a unit;
//! * **per-worker records are merged at join** and sorted by request id
//!   (a no-op at k = 1), and `served + rejected == arrivals` always
//!   holds;
//! * **worker-aware thresholds**: plans carry the worker count they
//!   were derived for ([`crate::planner::Plan::workers`]) — the AQM
//!   scales queue-depth thresholds with the effective service rate k·μ
//!   against the *aggregate* depth, and [`crate::sim::simulate_disc`]
//!   models both queue disciplines. (One known observation difference,
//!   inherited from the seed: on arrival the simulator's policy sees
//!   queue depth *plus* in-service count, while the live injector sees
//!   only queue depth — an off-by-≤1 at k = 1 that grows to ≤k for a
//!   pool. Under `ShardedSteal`, global service order additionally
//!   diverges from strict FIFO by up to one round-robin lap; see
//!   [`queue`] for the full contract.)

pub mod elastico;
pub mod executor;
pub mod monitor;
pub mod policy;
pub mod predictive;
pub mod queue;
pub mod server;

pub use elastico::ElasticoPolicy;
pub use policy::{ScalingPolicy, StaticPolicy};
pub use predictive::PredictivePolicy;
pub use queue::{Discipline, Popped, QueueError, RequestQueue, ShardedQueue};
pub use server::{serve, ServeOptions, ServeOutcome};
