//! The inference-serving system (paper §III-B): a central request queue,
//! a load monitor, the Elastico controller, and workflow executor
//! threads — the online phase of Compass.
//!
//! The controller logic lives in [`policy`] and is shared verbatim with
//! the discrete-event simulator ([`crate::sim`]), so simulated and live
//! behavior can be compared 1:1.

pub mod elastico;
pub mod executor;
pub mod monitor;
pub mod policy;
pub mod predictive;
pub mod queue;
pub mod server;

pub use elastico::ElasticoPolicy;
pub use predictive::PredictivePolicy;
pub use policy::{ScalingPolicy, StaticPolicy};
pub use queue::{QueueError, RequestQueue};
pub use server::{serve, ServeOptions, ServeOutcome};
