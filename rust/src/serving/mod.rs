//! The inference-serving system (paper §III-B): a central request queue,
//! a load monitor, the Elastico controller, and workflow executor
//! threads — the online phase of Compass.
//!
//! The controller logic lives in [`policy`] and is shared verbatim with
//! the discrete-event simulator ([`crate::sim`]), so simulated and live
//! behavior can be compared 1:1.
//!
//! ## Serving architecture (k workers)
//!
//! The runtime is an M/G/k system ([`ServeOptions::workers`], default 1
//! = the paper's single-server testbed):
//!
//! * **one bounded FIFO [`RequestQueue`]** is the admission point — a
//!   full queue rejects at push (admission control), and `close()`
//!   wakes every blocked worker for prompt shutdown;
//! * **k executor threads** drain that shared queue. PJRT handles are
//!   `!Send`, so each worker constructs its *own* engine inside its
//!   thread from a shared `Fn() -> Result<E>` factory; the run clock
//!   starts once the last worker finishes compiling, so engine startup
//!   never counts as queueing delay;
//! * **shared control plane**: one policy cell (mutex) takes every load
//!   observation — each arrival, each dequeue, each departure, and a
//!   periodic monitor tick — and appends to one switch audit trail, so
//!   the pool adapts as a unit exactly like the single server did;
//! * **per-worker records are merged at join** and sorted by request id
//!   (a no-op at k = 1), and `served + rejected == arrivals` always
//!   holds;
//! * **worker-aware thresholds**: plans carry the worker count they
//!   were derived for ([`crate::planner::Plan::workers`]) — the AQM
//!   scales queue-depth thresholds with the effective service rate k·μ,
//!   and [`crate::sim::simulate_k`] models the same FIFO/earliest-free
//!   discipline. (One known observation difference, inherited from the
//!   seed: on arrival the simulator's policy sees queue depth *plus*
//!   in-service count, while the live injector sees only queue depth —
//!   an off-by-≤1 at k = 1 that grows to ≤k for a pool.)

pub mod elastico;
pub mod executor;
pub mod monitor;
pub mod policy;
pub mod predictive;
pub mod queue;
pub mod server;

pub use elastico::ElasticoPolicy;
pub use predictive::PredictivePolicy;
pub use policy::{ScalingPolicy, StaticPolicy};
pub use queue::{QueueError, RequestQueue};
pub use server::{serve, ServeOptions, ServeOutcome};
