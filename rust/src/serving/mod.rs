//! The inference-serving system (paper §III-B): request queues, a load
//! monitor, the Elastico controller, and workflow executor threads —
//! the online phase of Compass.
//!
//! Two layers are shared verbatim with the discrete-event simulator
//! ([`crate::sim`]), so simulated and live behavior can be compared 1:1
//! — and, since the dispatch-plane unification, agree *by construction*
//! rather than by parity test:
//!
//! * the controller logic ([`policy`]) — the same `ScalingPolicy`
//!   implementations decide rungs in both worlds;
//! * the dispatch decisions ([`topology`]) — shard layout, round-robin
//!   routing, rung-band → pool resolution, the home/steal/spill walk
//!   order, the cost-aware spill gate, and the front-run / steal-half
//!   batch arithmetic are pure functions of a [`topology::Topology`].
//!   The live [`ShardedQueue`] executes them against locked shards; the
//!   one DES engine ([`crate::sim::simulate_topology`]) executes them
//!   against simulated queues. What remains *here* is only mechanics:
//!   locks, atomics, parking, threads and the wall clock.
//!
//! A guided tour of this dispatch plane — where routing, steal, spill,
//! batch and AQM each live, and why live/DES parity holds by
//! construction — is in `docs/ARCHITECTURE.md`. Failure injection
//! ([`ServeOptions::faults`], a [`crate::workload::FaultPlan`]) is
//! applied at the same run times in both executors.
//!
//! ## Serving architecture (k workers, sharded hot path)
//!
//! The runtime is an M/G/k system ([`ServeOptions::workers`], default 1
//! = the paper's single-server testbed):
//!
//! * **one bounded [`ShardedQueue`]** is the admission point — requests
//!   route round-robin to per-worker shards ([`Discipline::ShardedSteal`])
//!   or to a single shard ([`Discipline::CentralFifo`], the exact seed
//!   semantics); a worker whose home shard runs dry steals the *front*
//!   of the next non-empty shard. Admission control and the AQM depth
//!   signal use a lock-free total-across-shards counter, a full queue
//!   rejects at push, and `close()` wakes every blocked worker for
//!   prompt shutdown;
//! * **k executor threads** drain the queue, up to
//!   [`ServeOptions::batch`] requests per engine dispatch
//!   ([`ShardedQueue::pop_batch`] takes a front run of the home shard —
//!   or a steal-half from a victim — in one lock acquisition, and
//!   [`executor::RequestEngine::execute_batch`] runs the rung once for
//!   all of them). PJRT handles are `!Send`, so each worker constructs
//!   its *own* engine inside its thread from a shared
//!   `Fn() -> Result<E>` factory; the run clock starts once the last
//!   worker finishes compiling, so engine startup never counts as
//!   queueing delay;
//! * **lock-light control plane**: the monitor's arrival counter is a
//!   plain atomic; the shared policy sits behind a handle that caches
//!   the current rung and the policy's no-switch depth band
//!   ([`ScalingPolicy::no_switch_band`]) in atomics — in the common
//!   case (no threshold crossing) arrivals, dequeues and departures
//!   never take the policy mutex. Threshold crossings and the periodic
//!   monitor tick run the full locked decision and append to the one
//!   switch audit trail, so the pool still adapts as a unit;
//! * **per-worker records are merged at join** and sorted by request id
//!   (a no-op at k = 1), and `served + rejected == arrivals` always
//!   holds;
//! * **worker-aware thresholds**: plans carry the worker count they
//!   were derived for ([`crate::planner::Plan::workers`]) — the AQM
//!   scales queue-depth thresholds with the effective service rate k·μ
//!   against the *aggregate* depth, and [`crate::sim::simulate_disc`]
//!   models both queue disciplines. (One known observation difference,
//!   inherited from the seed: on arrival the simulator's policy sees
//!   queue depth *plus* in-service count, while the live injector sees
//!   only queue depth — an off-by-≤1 at k = 1 that grows to ≤k for a
//!   pool. Under `ShardedSteal`, global service order additionally
//!   diverges from strict FIFO by up to one round-robin lap; see
//!   [`queue`] for the full contract.)
//!
//! ## Batched dispatch (`s̄(B) = α + β·B`)
//!
//! At `batch > 1` a worker drains up to B queued requests in one lock
//! acquisition and executes the rung once for all of them: the
//! per-dispatch fixed cost α — rung resolution, engine call setup, the
//! policy observation — is paid once per batch instead of once per
//! request, so a worker's effective per-request service rate rises from
//! `1/(α + β)` to `B/(α + β·B)`. Every request in a batch shares the
//! batch's `start_ms`/`finish_ms` (a request completes when its batch
//! does) and the policy is consulted once per batch at dequeue and once
//! at completion. **When batching helps**: under load with a
//! non-trivial α, throughput scales toward `1/β` and queues drain
//! faster than the tail inflates — the AQM model
//! ([`crate::planner::aqm`]) deepens the thresholds accordingly. **When
//! it hurts**: with α ≈ 0 a batch just makes its earliest requests wait
//! for the whole batch (`s̄(B) ≈ B·s̄(1)`) — tail latency inflates with
//! no throughput gain, the batch-aware slack shrinks, and slow rungs
//! drop off the feasible ladder; keep `batch = 1` (the default, exact
//! seed semantics) unless the dispatch overhead is measurable
//! ([`crate::planner::fit_batch_model`] profiles it at B ∈ {1, 4, 8}).

//! ## Pool topology (heterogeneous fleets)
//!
//! [`ServeOptions::pools`] generalizes the uniform k-worker pool to
//! **named worker pools** ([`pool::PoolSpec`]) — e.g. a fast CPU pool
//! plus a slower, more accurate accelerator pool (`--pools
//! fast:4:1.0,accurate:2:2.5`). The topology changes three things:
//!
//! * **routing is rung-aware**: the pools partition the Pareto ladder
//!   into contiguous rung bands, and an arrival routes to the pool
//!   whose band contains the *current policy rung* (per-pool
//!   round-robin over that pool's shards). A rung switch across a band
//!   boundary therefore redirects new load to a different pool — the
//!   controller moves load *between pools*, not only up and down one
//!   shared ladder;
//! * **each pool resolves its own engine config**: a pool executes the
//!   policy rung clamped into its band ([`pool::pool_rung`]), so an
//!   accelerator pool keeps running its accurate rungs even while the
//!   policy tours the fast end — and a spilled request runs at the
//!   *executing* pool's rung, priced at that pool's `speed_factor`;
//! * **stealing stays pool-local, spilling is last-resort**: a worker
//!   steals only from its own pool's shards; it crosses pools (one
//!   "spill", counted separately) only when every shard of its pool is
//!   dry — and, under a positive [`ServeOptions::spill_margin`], only
//!   when the victim's backlog also exceeds the spiller's speed
//!   handicap ([`topology::Topology::spill_allowed`]), so slow hardware
//!   never poaches work the victim's own workers would finish sooner.
//!   Heterogeneous fleets thus scavenge idle cycles without inverting a
//!   loaded pool's FIFO order. The policy/AQM depth signal is **per
//!   pool** — the backlog of the pool the current rung routes to —
//!   matching the per-pool thresholds the Planner derives
//!   ([`crate::planner::derive_plan_pools`], Erlang-C or legacy mode).
//!
//! **When rung-aware routing beats a shared ladder**: whenever the
//! fleet is actually heterogeneous. A shared ladder index forces every
//! worker through the same configuration, so a slow pool drags the tail
//! of fast rungs (its requests inflate p95 by `speed_factor`) and a
//! fast pool wastes its headroom on accurate rungs it executes no
//! better than the accelerator. Band routing keeps each hardware class
//! on the rungs it is provisioned for and turns a rung switch into a
//! *pool* switch, which is the knob a heterogeneous fleet really has.
//! **When it doesn't**: on a uniform fleet a single
//! [`pool::PoolSpec::uniform`] pool is the exact pre-pool runtime (the
//! parity tests pin record-for-record equality in the DES), and slicing
//! a uniform fleet into many small bands only shrinks each band's
//! steal neighborhood — prefer one pool unless the hardware differs.
//!
//! Live heterogeneous engines come from [`server::serve_pools`], whose
//! factory receives each worker's [`pool::PoolSpec`]; the DES mirror is
//! [`crate::sim::simulate_pools`], validated against M/M/k and Erlang-C
//! theory by `tests/theory_validation.rs`.

pub mod elastico;
pub mod executor;
pub mod monitor;
pub mod overload;
pub mod policy;
pub mod pool;
pub mod predictive;
pub mod queue;
pub mod replan;
pub mod resilience;
pub mod ring;
pub mod server;
pub mod topology;

pub use elastico::ElasticoPolicy;
pub use executor::{MockEngine, RequestEngine, WorkflowEngine};
pub use overload::{default_classes, parse_classes, Brownout, ClassSpec, OverloadConfig};
pub use policy::{ScalingPolicy, StaticPolicy};
pub use pool::{parse_pools, PoolSpec};
pub use predictive::PredictivePolicy;
pub use queue::{Discipline, Popped, QueueBackend, QueueError, RequestQueue, ShardedQueue};
pub use replan::{ReplanConfig, ReplanEngine, ReplanUpdate};
pub use resilience::{HealthView, PoolHealth, ResilienceConfig};
pub use ring::MpmcRing;
pub use server::{serve, serve_pools, ServeOptions, ServeOutcome};
pub use topology::{Dispatch, Topology};
