//! The serving loop (paper Fig. 2, online phase): arrival injector →
//! central queue → a pool of k executor threads (M/G/k), with the
//! controller observing load on every arrival, every dequeue and a
//! periodic monitor tick.
//!
//! Threading: PJRT handles are `!Send`, so each worker *constructs its
//! own engine inside its thread* from a shared `Fn() -> Result<E>`
//! factory. The policy is shared behind a mutex (decisions are
//! microseconds; the lock is uncontended relative to service times), as
//! is the switch audit trail; per-worker request records are merged at
//! join. With `workers == 1` the semantics are identical to the paper's
//! single-server testbed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::executor::RequestEngine;
use super::monitor::LoadMonitor;
use super::policy::ScalingPolicy;
use super::queue::{QueueError, RequestQueue};
use crate::metrics::{RequestRecord, SwitchEvent};

/// Serving run options.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Queue capacity (admission control bound).
    pub queue_capacity: usize,
    /// Monitor tick period (ms) — drives hysteresis progress when idle.
    pub tick_ms: u64,
    /// Executor worker threads k (M/G/k). Each worker builds its own
    /// engine from the factory; all drain the shared queue.
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { queue_capacity: 4096, tick_ms: 20, workers: 1 }
    }
}

/// Result of a serving run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub records: Vec<RequestRecord>,
    pub switches: Vec<SwitchEvent>,
    /// Requests rejected by admission control (queue full).
    pub rejected: usize,
    /// Mean smoothed arrival rate at end of run (diagnostics).
    pub final_rate_qps: f64,
}

/// Shared policy state: decisions + switch audit trail.
struct PolicyCell {
    policy: Box<dyn ScalingPolicy>,
    observed: usize,
    switches: Vec<SwitchEvent>,
}

impl PolicyCell {
    fn observe(&mut self, now_ms: f64, depth: usize) -> usize {
        let next = self.policy.decide(now_ms, depth);
        if next != self.observed {
            self.switches.push(SwitchEvent {
                at_ms: now_ms,
                from_idx: self.observed,
                to_idx: next,
            });
            self.observed = next;
        }
        next
    }
}

/// The run-clock gate: the clock starts only once **every** worker has
/// built (and PJRT-compiled) its engine, so compilation never masquerades
/// as queueing delay. The last worker to finish building sets `start`.
struct StartGate {
    pending: usize,
    start: Option<Instant>,
}

/// Run a serving experiment.
///
/// * `make_engine` is called **inside** each executor thread (PJRT is
///   thread-bound); with `opts.workers == k` it is called k times.
/// * `arrivals` are offsets in seconds from run start; the injector
///   sleeps them out in real time (service times are real compute, so
///   time cannot be compressed without changing utilization).
pub fn serve<F, E>(
    make_engine: F,
    policy: Box<dyn ScalingPolicy>,
    arrivals: &[f64],
    opts: &ServeOptions,
) -> Result<ServeOutcome>
where
    F: Fn() -> Result<E> + Send + Sync,
    E: RequestEngine,
{
    let workers = opts.workers.max(1);
    let gate: Arc<(Mutex<StartGate>, Condvar)> = Arc::new((
        Mutex::new(StartGate { pending: workers, start: None }),
        Condvar::new(),
    ));
    let wait_start = {
        let gate = gate.clone();
        move || -> Instant {
            let (lock, cv) = &*gate;
            let mut g = lock.lock().unwrap();
            while g.start.is_none() {
                g = cv.wait(g).unwrap();
            }
            g.start.unwrap()
        }
    };

    let queue: Arc<RequestQueue<(u64, f64)>> =
        Arc::new(RequestQueue::new(opts.queue_capacity));
    let monitor = Arc::new(LoadMonitor::new(0.3));
    let initial = policy.current();
    let cell = Arc::new(Mutex::new(PolicyCell {
        policy,
        observed: initial,
        switches: Vec::new(),
    }));
    let done = Arc::new(AtomicBool::new(false));
    let rejected = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let make_engine = &make_engine;

    std::thread::scope(|scope| -> Result<ServeOutcome> {
        // ---- monitor tick thread: keeps hysteresis moving when idle.
        {
            let queue = queue.clone();
            let cell = cell.clone();
            let monitor = monitor.clone();
            let done = done.clone();
            let tick = opts.tick_ms;
            let wait_start = wait_start.clone();
            scope.spawn(move || {
                let start = wait_start();
                while !done.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(tick));
                    let t = start.elapsed().as_secs_f64() * 1e3;
                    monitor.tick(t);
                    cell.lock().unwrap().observe(t, queue.len());
                }
            });
        }

        // ---- arrival injector.
        {
            let queue = queue.clone();
            let cell = cell.clone();
            let monitor = monitor.clone();
            let rejected = rejected.clone();
            let arrivals = arrivals.to_vec();
            let wait_start = wait_start.clone();
            scope.spawn(move || {
                let start = wait_start();
                for (id, &t_s) in arrivals.iter().enumerate() {
                    let target = Duration::from_secs_f64(t_s);
                    let elapsed = start.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                    let t = start.elapsed().as_secs_f64() * 1e3;
                    monitor.on_arrival();
                    match queue.push((id as u64, t)) {
                        Ok(()) => {
                            cell.lock().unwrap().observe(t, queue.len());
                        }
                        Err(QueueError::Full) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(QueueError::Closed) => break,
                    }
                }
                queue.close();
            });
        }

        // ---- executor pool: k workers drain the shared queue.
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let queue = queue.clone();
                let cell = cell.clone();
                let gate = gate.clone();
                scope.spawn(move || -> Result<Vec<RequestRecord>> {
                    // Build (and PJRT-compile) the engine; the last
                    // worker to finish releases the run clock. A failed
                    // build still releases it so the run can wind down.
                    let engine = make_engine();
                    let start = {
                        let (lock, cv) = &*gate;
                        let mut g = lock.lock().unwrap();
                        g.pending -= 1;
                        if g.pending == 0 {
                            g.start = Some(Instant::now());
                            cv.notify_all();
                        }
                        while g.start.is_none() {
                            g = cv.wait(g).unwrap();
                        }
                        g.start.unwrap()
                    };
                    let mut engine = engine?;
                    let now_ms = move || start.elapsed().as_secs_f64() * 1e3;
                    let mut records = Vec::new();
                    loop {
                        match queue.pop_timeout(Duration::from_millis(50)) {
                            Ok(Some((id, arrival_ms))) => {
                                let t_start = now_ms();
                                // Switches take effect at dequeue.
                                let idx = cell
                                    .lock()
                                    .unwrap()
                                    .observe(t_start, queue.len());
                                let out = engine.execute(idx)?;
                                let t_fin = now_ms();
                                records.push(RequestRecord {
                                    id,
                                    arrival_ms,
                                    start_ms: t_start,
                                    finish_ms: t_fin,
                                    config_idx: idx,
                                    accuracy: out.accuracy,
                                    success: out.success,
                                });
                                cell.lock().unwrap().observe(t_fin, queue.len());
                            }
                            Ok(None) => {}
                            Err(QueueError::Closed) => break,
                            Err(QueueError::Full) => unreachable!(),
                        }
                    }
                    Ok(records)
                })
            })
            .collect();

        // Join every worker before signalling `done` (the monitor must
        // keep ticking while any worker still drains the queue), then
        // merge the per-worker records and propagate the first error.
        let results: Vec<Result<Vec<RequestRecord>>> = handles
            .into_iter()
            .map(|h| h.join().expect("executor panicked"))
            .collect();
        done.store(true, Ordering::Relaxed);
        let mut records = Vec::new();
        for r in results {
            records.extend(r?);
        }
        // Deterministic order regardless of which worker served what
        // (a no-op at k = 1: one FIFO consumer pops in id order).
        records.sort_by_key(|r| r.id);

        let switches = {
            let cell = cell.lock().unwrap();
            cell.switches.clone()
        };
        Ok(ServeOutcome {
            records,
            switches,
            rejected: rejected.load(Ordering::Relaxed),
            final_rate_qps: monitor.rate_qps(),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::executor::MockEngine;
    use crate::serving::policy::StaticPolicy;

    #[test]
    fn serves_all_requests_fifo() {
        let arrivals: Vec<f64> = (0..40).map(|i| i as f64 * 0.005).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![2.0],
                    accuracy: vec![0.8],
                })
            },
            Box::new(StaticPolicy::new(0, "fast")),
            &arrivals,
            &ServeOptions::default(),
        )
        .unwrap();
        assert_eq!(out.records.len(), 40);
        assert_eq!(out.rejected, 0);
        let mut by_start = out.records.clone();
        by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
        for w in by_start.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
            assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "single-server violated");
        }
    }

    #[test]
    fn overload_builds_queue_latency() {
        // 10 ms service, arrivals every 4 ms -> queue grows, latency >>
        // service time by the tail of the run.
        let arrivals: Vec<f64> = (0..50).map(|i| i as f64 * 0.004).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![10.0],
                    accuracy: vec![0.8],
                })
            },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions::default(),
        )
        .unwrap();
        let last = out.records.iter().max_by_key(|r| r.id).unwrap();
        assert!(
            last.latency_ms() > 100.0,
            "tail latency {} should reflect queueing",
            last.latency_ms()
        );
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.001).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![20.0],
                    accuracy: vec![0.8],
                })
            },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions { queue_capacity: 4, tick_ms: 10, workers: 1 },
        )
        .unwrap();
        assert!(out.rejected > 0);
        assert_eq!(out.records.len() + out.rejected, 30);
    }

    #[test]
    fn engine_build_failure_propagates() {
        let arrivals = [0.0, 0.001];
        let err = serve(
            || -> Result<MockEngine> { anyhow::bail!("no accelerator") },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions::default(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no accelerator"));
    }
}
