//! The serving loop (paper Fig. 2, online phase): arrival injector →
//! request queue → a pool of k executor threads (M/G/k), with the
//! controller observing load off the hot path and up to
//! [`ServeOptions::batch`] requests executed per engine dispatch.
//!
//! Threading: PJRT handles are `!Send`, so each worker *constructs its
//! own engine inside its thread* from a shared `Fn() -> Result<E>`
//! factory. With `workers == 1` and the central discipline the semantics
//! are identical to the paper's single-server testbed.
//!
//! ## Hot-path coordination (lock-light control plane)
//!
//! Three coordinator structures used to serialize every request:
//!
//! * the **queue** is a [`ShardedQueue`] — per-worker bounded FIFOs with
//!   round-robin routing and FIFO work stealing ([`Discipline`] selects
//!   the shard count; `CentralFifo` is the single-shard case). Push and
//!   pop touch one shard mutex shared by `1/shards` of the traffic, and
//!   the AQM depth signal is a lock-free aggregate counter;
//! * the **monitor**'s `on_arrival` is a relaxed atomic increment;
//! * the **policy** sits behind a [`PolicyHandle`]: the current rung and
//!   the policy's advertised no-switch depth band are cached in atomics,
//!   so the common case (depth inside the band — no switch possible)
//!   reads two atomics and never takes the mutex. Only a threshold
//!   crossing — or the periodic monitor tick, which keeps smoothing and
//!   hysteresis state moving — falls into the lock, runs the full
//!   decision, appends to the switch audit trail, and refreshes the
//!   cached band.
//!
//! A fast-path read may observe a rung up to one in-flight switch stale;
//! this is indistinguishable from the reading thread having been
//! scheduled just before the switch, and the audit trail (always
//! lock-protected) stays exact.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::executor::RequestEngine;
use super::monitor::LoadMonitor;
use super::overload::{Brownout, OverloadConfig};
use super::policy::ScalingPolicy;
use super::pool::PoolSpec;
use super::queue::{Discipline, Popped, QueueBackend, ShardedQueue};
use super::replan::{ReplanConfig, ReplanEngine};
use super::resilience::{HealthView, ResilienceConfig};
use super::topology::Topology;
use crate::metrics::{RequestRecord, SwitchEvent};
use crate::workload::FaultPlan;

/// One queued request: (id, arrival ms, retry attempt). Attempt 0 is
/// the first try; the resilience plane re-enqueues failures with an
/// incremented attempt so the retry cap and the flaky coin see it.
type Job = (u64, f64, u32);

/// Serving run options.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Queue capacity (admission control bound, total across shards).
    pub queue_capacity: usize,
    /// Monitor tick period (ms) — drives hysteresis progress when idle.
    pub tick_ms: u64,
    /// Executor worker threads k (M/G/k). Each worker builds its own
    /// engine from the factory; all drain the request queue. Ignored
    /// when [`pools`](ServeOptions::pools) names an explicit topology
    /// (the pool worker counts take over).
    pub workers: usize,
    /// Queue discipline: one central FIFO (the paper's testbed) or
    /// per-worker shards with work stealing. Ignored under an explicit
    /// pool topology (pools always run per-worker shards).
    pub discipline: Discipline,
    /// Shard count under [`Discipline::ShardedSteal`]; 0 = one shard
    /// per worker. Ignored (forced to 1) under `CentralFifo`, and
    /// ignored under an explicit pool topology.
    pub shards: usize,
    /// Max requests dequeued and executed per engine dispatch (batch
    /// bound B). 1 (the default) is the unbatched seed behavior: every
    /// dequeue dispatches exactly one request. At B > 1 a worker drains
    /// up to B compatible requests from its home shard in one lock
    /// acquisition and executes the rung once for all of them
    /// ([`RequestEngine::execute_batch`]), amortizing the per-dispatch
    /// overhead; all requests in a batch share `start_ms`/`finish_ms`
    /// and one policy observation.
    pub batch: usize,
    /// Heterogeneous worker-pool topology. Empty (the default) runs the
    /// homogeneous `workers`/`discipline`/`shards` runtime unchanged;
    /// non-empty runs named pools with rung-aware routing, within-pool
    /// stealing and cross-pool spill (see [`crate::serving::pool`]).
    pub pools: Vec<PoolSpec>,
    /// Cost-aware spill margin m: a pool spills into a victim pool only
    /// when the victim's backlog exceeds
    /// `m · (speed_spiller / speed_victim) · workers_victim`
    /// ([`Topology::spill_allowed`]). 0 (the default) is the historical
    /// spill-when-dry. Meaningless on a single-pool fleet.
    pub spill_margin: f64,
    /// Injected faults (pool dark, slowdown windows, queue squeeze,
    /// flaky engines), applied at the same run times as the DES engine
    /// applies them ([`crate::sim::simulate_topology_faults`]). Empty
    /// (the default) changes nothing.
    pub faults: FaultPlan,
    /// The resilience plane: health-aware failover routing, bounded
    /// retries with backoff, per-pool circuit breakers and request
    /// timeouts ([`ResilienceConfig`]). Disabled (the default) is
    /// bit-identical to the pre-resilience runtime — failures are
    /// still *counted* (an engine `Err` can no longer abort the run),
    /// but nothing is retried or routed around.
    pub resilience: ResilienceConfig,
    /// The overload plane: SLO classes with per-request deadlines,
    /// deadline-aware admission shedding, lazy in-queue expiry and
    /// brownout rung degradation ([`OverloadConfig`]). Disabled (the
    /// default) is bit-identical to the pre-overload runtime. The live
    /// executor has no plan ladder, so deadline budgets use
    /// [`OverloadConfig::rung_means_ms`] — fill it from the plan via
    /// [`OverloadConfig::with_rung_means`] when shedding should be
    /// service-time calibrated.
    pub overload: OverloadConfig,
    /// Online re-planning ([`crate::serving::replan`]). Disabled (the
    /// default) is bit-identical to the static runtime. Enabling it
    /// requires the base [`crate::planner::Plan`] attached via
    /// [`ReplanConfig::with_plan`] — the re-planner re-derives *that*
    /// ladder against live speed/α/ρ̂ estimates and swaps the result
    /// into the policy on the monitor tick.
    pub replan: ReplanConfig,
    /// Shard-storage backend of the queue hot path (`--queue
    /// ring|mutex`): locked deques (the seed mechanics; default) or
    /// bounded lock-free MPMC rings ([`QueueBackend::Ring`]). The
    /// dispatch *decisions* (routing, steal-half, spill gates, batch
    /// extents) are the topology's either way — only the mechanics
    /// under them change — so the mutex default stays bit-identical to
    /// the seed path.
    pub backend: QueueBackend,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            queue_capacity: 4096,
            tick_ms: 20,
            workers: 1,
            discipline: Discipline::CentralFifo,
            shards: 0,
            batch: 1,
            pools: Vec::new(),
            spill_margin: 0.0,
            faults: FaultPlan::default(),
            resilience: ResilienceConfig::default(),
            overload: OverloadConfig::default(),
            replan: ReplanConfig::default(),
            backend: QueueBackend::default(),
        }
    }
}

impl ServeOptions {
    /// Effective shard count for this run (homogeneous topology).
    pub fn effective_shards(&self) -> usize {
        self.discipline.effective_shards(self.workers.max(1), self.shards)
    }

    /// The pool topology this run executes: the explicit pools, or a
    /// single uniform pool wrapping the homogeneous options.
    pub fn effective_pools(&self) -> Vec<PoolSpec> {
        if self.pools.is_empty() {
            vec![PoolSpec::uniform(self.workers.max(1))]
        } else {
            self.pools.clone()
        }
    }

    /// Shard count of each effective pool: the homogeneous path keeps
    /// the discipline/shards semantics (central = 1 shard); explicit
    /// pools run one shard per worker.
    pub fn pool_shard_counts(&self) -> Vec<usize> {
        if self.pools.is_empty() {
            vec![self.effective_shards()]
        } else {
            self.pools.iter().map(|p| p.workers.max(1)).collect()
        }
    }

    /// Total executor threads across the fleet.
    pub fn total_workers(&self) -> usize {
        if self.pools.is_empty() {
            self.workers.max(1)
        } else {
            super::pool::total_workers(&self.pools)
        }
    }

    /// The dispatch [`Topology`] this run executes — the one decision
    /// core shared by the live queue walks and the DES engine. Validates
    /// the pool specs.
    pub fn topology(&self) -> Result<Topology> {
        Topology::new(self.effective_pools(), self.pool_shard_counts(), self.spill_margin)
    }
}

/// Result of a serving run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub records: Vec<RequestRecord>,
    pub switches: Vec<SwitchEvent>,
    /// Requests rejected by admission control (queue full).
    pub rejected: usize,
    /// Mean smoothed arrival rate at end of run (diagnostics).
    pub final_rate_qps: f64,
    /// Dequeues satisfied by stealing from a non-home shard of the
    /// worker's own pool (always 0 under the central discipline).
    pub steals: u64,
    /// Dequeues satisfied by spilling into another pool's shards
    /// (always 0 on a homogeneous fleet).
    pub spills: u64,
    /// Requests served by each pool, ordered as
    /// [`ServeOptions::effective_pools`] (a single entry on the
    /// homogeneous path).
    pub pool_served: Vec<usize>,
    /// Arrivals the rung-aware router sent to each pool (same order;
    /// counts offered arrivals, so rejected requests are included —
    /// `pool_arrivals` sums to the arrival total, `pool_served` to the
    /// record count).
    pub pool_arrivals: Vec<u64>,
    /// Requests that failed terminally (engine error / injected flake /
    /// timeout / recovered panic, with no retry admitted or the retried
    /// push refused). Conservation extends to
    /// `served + rejected + failed == arrivals`.
    pub failed: usize,
    /// Failed requests re-enqueued through the normal routing path.
    pub retries: u64,
    /// Worker panics caught by the supervisor; each also fails (or
    /// retries) the in-flight request and rebuilds the engine in place.
    pub panics_recovered: u64,
    /// Completions discarded for exceeding the resilience request
    /// timeout (0 unless [`ResilienceConfig::request_timeout_ms`] > 0).
    pub timeouts: u64,
    /// Circuit-breaker open transitions across all pools.
    pub breaker_trips: u64,
    /// Requests routed to a non-home pool because the home pool was
    /// dark or breaker-open (admission remaps + dark-backlog
    /// redistribution).
    pub failovers: u64,
    /// Arrivals shed by deadline-aware admission before entering the
    /// queue (0 unless the overload plane is enabled). Conservation
    /// extends to `served + rejected + failed + shed + expired ==
    /// arrivals`.
    pub shed: usize,
    /// Queued requests skipped at pop time because their deadline had
    /// already passed (lazy in-queue expiry; 0 unless the overload
    /// plane is enabled).
    pub expired: usize,
    /// Brownout rung-degradation steps taken (down-steps only; 0 unless
    /// the overload plane is enabled).
    pub brownout_steps: u64,
    /// Re-derived plans the policy adopted (0 unless the re-plan loop
    /// is enabled).
    pub replans: u64,
}

/// Shared run-wide resilience state: the health view (breakers + retry
/// token bucket) behind one mutex — taken only on completion records,
/// retries, and health-aware routing when the plane is enabled — plus
/// lock-free failure counters.
struct ResilienceState {
    enabled: bool,
    health: Mutex<HealthView>,
    failed: AtomicUsize,
    retries: AtomicU64,
    panics: AtomicU64,
    timeouts: AtomicU64,
    failovers: AtomicU64,
}

impl ResilienceState {
    fn new(n_pools: usize, cfg: ResilienceConfig) -> ResilienceState {
        ResilienceState {
            enabled: cfg.enabled,
            health: Mutex::new(HealthView::new(n_pools, cfg)),
            failed: AtomicUsize::new(0),
            retries: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
        }
    }

    /// Feed a completion into the pool's breaker EWMA. Guarded here so
    /// the disabled path never touches the health mutex.
    fn record(&self, pool: usize, ok: bool, now_ms: f64) {
        if self.enabled {
            self.health.lock().unwrap().record(pool, ok, now_ms);
        }
    }
}

/// Shared run-wide overload state: the brownout controller behind one
/// mutex — taken only on pops while the plane is enabled — plus
/// lock-free shed/expired counters. The disabled path never touches any
/// of it (structural bit-identity with the pre-overload runtime).
struct OverloadState {
    cfg: OverloadConfig,
    enabled: bool,
    brown: Mutex<Brownout>,
    shed: AtomicUsize,
    expired: AtomicUsize,
}

impl OverloadState {
    fn new(cfg: OverloadConfig) -> OverloadState {
        OverloadState {
            enabled: cfg.enabled,
            brown: Mutex::new(Brownout::new(&cfg)),
            cfg,
            shed: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
        }
    }

    /// Feed one pop observation into the deadline-pressure EWMA and
    /// return the current brownout rung offset. Inert (and lock-free)
    /// when the plane is disabled.
    fn observe_pop(&self, at_risk: bool) -> usize {
        if !self.enabled {
            return 0;
        }
        let mut b = self.brown.lock().unwrap();
        b.observe_pop(at_risk);
        b.offset()
    }

    /// Admission gate for one arrival; `false` means the arrival was
    /// shed (and counted). Always admits when the plane is disabled.
    fn admit(&self, id: u64, depth: usize, mean_ms: f64, workers: usize) -> bool {
        if !self.enabled || self.cfg.admit(id, depth, mean_ms, workers) {
            return true;
        }
        self.shed.fetch_add(1, Ordering::Relaxed);
        false
    }

    /// Lazy in-queue expiry for a popped batch, in place: requests
    /// whose deadline passed while they queued are retained out of
    /// `items` before dispatch, each counted and fed to the brownout
    /// EWMA as a deadline miss. Only the survivors remain in `items`
    /// (the whole batch when the plane is disabled; relative order is
    /// preserved). In place so the steady-state dispatch loop keeps its
    /// one scratch buffer instead of re-partitioning into fresh `Vec`s.
    fn expire_batch(&self, items: &mut Vec<Job>, now_ms: f64) {
        if !self.enabled {
            return;
        }
        let mut dead = 0usize;
        items.retain(|&(id, arr, _)| {
            if self.cfg.expired(id, arr, now_ms) {
                dead += 1;
                false
            } else {
                true
            }
        });
        if dead > 0 {
            self.expired.fetch_add(dead, Ordering::Relaxed);
            for _ in 0..dead {
                self.observe_pop(true);
            }
        }
    }

    /// Resolve the executing rung for a popped batch: feed each job's
    /// deadline risk into the brownout EWMA, step the effective rung
    /// down by the brownout offset, and enforce the strictest class
    /// rung floor across the batch *before* the pool-band clamp.
    /// Exactly `Topology::exec_rung` when the plane is disabled.
    fn exec_rung(
        &self,
        topo: &Topology,
        pool: usize,
        idx: usize,
        n_rungs: usize,
        jobs: &[Job],
        now_ms: f64,
    ) -> usize {
        if !self.enabled {
            return topo.exec_rung(pool, idx, n_rungs);
        }
        let mean_now = self.cfg.mean_at(idx);
        let mut floor = 0usize;
        let mut off = 0usize;
        for &(id, arr, _) in jobs {
            off = self.observe_pop(self.cfg.at_risk(id, arr, now_ms, mean_now));
            floor = floor.max(self.cfg.rung_floor(id));
        }
        topo.exec_rung_floor(pool, idx.saturating_sub(off), floor, n_rungs)
    }

    fn steps(&self) -> u64 {
        if self.enabled {
            self.brown.lock().unwrap().steps
        } else {
            0
        }
    }
}

/// Resilience failover: a dark pool's worker redistributes its stranded
/// home-shard backlog to the nearest surviving pool (counted as
/// failovers) instead of letting it sit for a drain-reject, then parks
/// until the dark window closes — at which point it returns and the
/// worker resumes serving — or the run winds down.
#[allow(clippy::too_many_arguments)]
fn failover_dark_pool(
    queue: &ShardedQueue<Job>,
    topo: &Topology,
    pool: usize,
    worker: usize,
    res: &ResilienceState,
    faults: &FaultPlan,
    until_ms: f64,
    now_ms: &dyn Fn() -> f64,
    rejected: &AtomicUsize,
) {
    loop {
        while let Some(job) = queue.try_pop_home(pool, worker) {
            let t = now_ms();
            let target = {
                let mut hv = res.health.lock().unwrap();
                topo.failover_pool(pool, |q| hv.routable(q, t, faults))
            };
            match target.map(|q| queue.push_pool(q, job)) {
                Some(Ok(())) => {
                    res.failovers.fetch_add(1, Ordering::Relaxed);
                }
                // No surviving pool, or its shards are full/closed:
                // reject, never drop (conservation).
                _ => {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        if now_ms() >= until_ms {
            return;
        }
        if queue.is_closed() && queue.pool_len(pool) == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A request failed (engine error, injected flake, recovered panic, or
/// timeout): re-enqueue it through the normal health-aware routing path
/// when the retry policy admits it — per-request cap, token-bucket
/// budget, exponential backoff — else count it terminally failed.
/// Either way the request stays accounted:
/// `served + rejected + failed == arrivals`.
#[allow(clippy::too_many_arguments)]
fn retry_or_fail(
    queue: &ShardedQueue<Job>,
    topo: &Topology,
    handle: &PolicyHandle,
    res: &ResilienceState,
    faults: &FaultPlan,
    cfg: &ResilienceConfig,
    job: Job,
    now_ms: &dyn Fn() -> f64,
) {
    let (id, arrival_ms, attempt) = job;
    let next = attempt + 1;
    let admitted = cfg.enabled && res.health.lock().unwrap().try_retry(next, now_ms());
    if !admitted {
        res.failed.fetch_add(1, Ordering::Relaxed);
        return;
    }
    let backoff = cfg.backoff_ms(next);
    if backoff > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(backoff / 1e3));
    }
    let t = now_ms();
    let (pool, moved) = {
        let mut hv = res.health.lock().unwrap();
        topo.pool_for_rung_routable(handle.current_rung(), |q| hv.routable(q, t, faults))
    };
    match queue.push_pool(pool, (id, arrival_ms, next)) {
        Ok(()) => {
            res.retries.fetch_add(1, Ordering::Relaxed);
            if moved {
                res.failovers.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Full or closed: the retry has nowhere to go — terminal.
        Err(_) => {
            res.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Shared policy state: decisions + switch audit trail.
struct PolicyCell {
    policy: Box<dyn ScalingPolicy>,
    observed: usize,
    switches: Vec<SwitchEvent>,
}

impl PolicyCell {
    fn observe(&mut self, now_ms: f64, depth: usize) -> usize {
        let next = self.policy.decide(now_ms, depth);
        if next != self.observed {
            self.switches.push(SwitchEvent {
                at_ms: now_ms,
                from_idx: self.observed,
                to_idx: next,
            });
            self.observed = next;
        }
        next
    }
}

/// Empty-band sentinel: `lo > hi` matches no depth.
const EMPTY_BAND: u64 = (u32::MAX as u64) << 32;

/// Pack an inclusive depth band into one atomic word (lo in the high 32
/// bits). Depths are clamped to `u32::MAX`, far above any queue bound.
fn pack_band(band: Option<(usize, usize)>) -> u64 {
    match band {
        None => EMPTY_BAND,
        Some((lo, hi)) => {
            let lo = lo.min(u32::MAX as usize) as u64;
            let hi = hi.min(u32::MAX as usize) as u64;
            (lo << 32) | hi
        }
    }
}

/// Lock-light wrapper around the shared policy: the current rung and the
/// policy's no-switch band are mirrored in atomics so in-band load
/// observations skip the mutex (see the module docs for the contract).
pub(crate) struct PolicyHandle {
    current: AtomicUsize,
    band: AtomicU64,
    inner: Mutex<PolicyCell>,
}

impl PolicyHandle {
    fn new(policy: Box<dyn ScalingPolicy>) -> PolicyHandle {
        let observed = policy.current();
        let band = pack_band(policy.no_switch_band());
        PolicyHandle {
            current: AtomicUsize::new(observed),
            band: AtomicU64::new(band),
            inner: Mutex::new(PolicyCell {
                policy,
                observed,
                switches: Vec::new(),
            }),
        }
    }

    /// Observe load; lock-free when `depth` is inside the cached
    /// no-switch band, locked (full decision + band refresh) otherwise.
    fn observe(&self, now_ms: f64, depth: usize) -> usize {
        let band = self.band.load(Ordering::Acquire);
        let (lo, hi) = ((band >> 32) as usize, (band & u32::MAX as u64) as usize);
        if depth >= lo && depth <= hi {
            return self.current.load(Ordering::Acquire);
        }
        self.observe_locked(now_ms, depth)
    }

    /// Observe through the policy lock unconditionally — the monitor
    /// tick path, which must keep smoothing/hysteresis state moving
    /// even when the depth sits inside the band.
    fn observe_locked(&self, now_ms: f64, depth: usize) -> usize {
        let mut cell = self.inner.lock().unwrap();
        let next = cell.observe(now_ms, depth);
        // Store order matters: current before band, so a fast path that
        // sees the fresh band also sees the fresh rung.
        self.current.store(next, Ordering::Release);
        self.band
            .store(pack_band(cell.policy.no_switch_band()), Ordering::Release);
        next
    }

    /// The cached current rung — one atomic load, up to one in-flight
    /// switch stale (the same staleness contract as the fast path).
    /// Drives rung-aware routing and the per-pool depth signal.
    fn current_rung(&self) -> usize {
        self.current.load(Ordering::Acquire)
    }

    fn take_switches(&self) -> Vec<SwitchEvent> {
        self.inner.lock().unwrap().switches.clone()
    }

    /// Swap a re-derived plan into the policy (the re-planner's install
    /// hook). Under the policy lock so no observation interleaves with
    /// the threshold swap; on adoption the cached rung and band are
    /// refreshed from the policy (the rung is contractually unchanged,
    /// the band may not be).
    fn replace_plan(&self, plan: crate::planner::Plan) -> bool {
        let mut cell = self.inner.lock().unwrap();
        if !cell.policy.replace_plan(plan) {
            return false;
        }
        let cur = cell.policy.current();
        cell.observed = cur;
        self.current.store(cur, Ordering::Release);
        self.band
            .store(pack_band(cell.policy.no_switch_band()), Ordering::Release);
        true
    }
}

/// Shared run-wide re-plan state: the estimator behind one mutex (taken
/// only on batch completions and the monitor tick when the loop is
/// enabled — a disabled loop is a single branch on the hot path), plus
/// the adaptive batch bound mirrored in an atomic for the workers.
struct ReplanState {
    enabled: bool,
    /// Workers read the batch bound per pop instead of a fixed one.
    adaptive: bool,
    engine: Mutex<Option<ReplanEngine>>,
    batch: AtomicUsize,
    replans: AtomicU64,
}

impl ReplanState {
    fn new(cfg: &ReplanConfig, topo: &Topology, batch: usize) -> Result<ReplanState> {
        let engine = if cfg.enabled {
            let plan = cfg.plan.clone().ok_or_else(|| {
                anyhow::anyhow!(
                    "replan enabled without a base plan — attach one via ReplanConfig::with_plan"
                )
            })?;
            Some(ReplanEngine::new(
                cfg.clone(),
                plan,
                topo.pools().to_vec(),
                batch,
                topo.spill_margin(),
            ))
        } else {
            None
        };
        Ok(ReplanState {
            enabled: cfg.enabled,
            adaptive: cfg.enabled && cfg.b_max > 0,
            engine: Mutex::new(engine),
            batch: AtomicUsize::new(batch),
            replans: AtomicU64::new(0),
        })
    }

    /// Record one executed batch: (pool, executed rung, size, wall ms).
    fn on_completion(&self, pool: usize, rung: usize, n: usize, ms: f64) {
        if !self.enabled {
            return;
        }
        if let Some(engine) = self.engine.lock().unwrap().as_mut() {
            engine.on_completion(pool, rung, n, ms);
        }
    }

    /// One re-plan evaluation (monitor-tick cadence): step the
    /// estimator and install whatever it decided — plan into the
    /// policy, batch bound into the atomic, margin into the queue.
    fn step(&self, now_ms: f64, rate_qps: f64, handle: &PolicyHandle, queue: &ShardedQueue<Job>) {
        if !self.enabled {
            return;
        }
        let mut guard = self.engine.lock().unwrap();
        let Some(engine) = guard.as_mut() else { return };
        let depth = queue.len();
        if let Some(upd) = engine.step(now_ms, rate_qps, depth, handle.current_rung()) {
            if let Some(plan) = upd.plan {
                if handle.replace_plan(plan) {
                    self.replans.fetch_add(1, Ordering::Relaxed);
                }
            }
            self.batch.store(upd.batch.max(1), Ordering::Relaxed);
            queue.set_spill_margin(upd.spill_margin);
        }
    }
}

/// The run-clock gate: the clock starts only once **every** worker has
/// built (and PJRT-compiled) its engine, so compilation never masquerades
/// as queueing delay. The last worker to finish building sets `start`.
struct StartGate {
    pending: usize,
    start: Option<Instant>,
}

/// The per-pool depth signal: the queued depth of the pool the current
/// policy rung routes to. This is what the policy (and the AQM
/// thresholds, derived per pool) observes — pressure where new traffic
/// lands — so a threshold crossing moves load *between pools*, not just
/// along one shared ladder. On a single-pool fleet this is exactly the
/// aggregate depth (the seed signal).
fn pooled_depth<T>(queue: &ShardedQueue<T>, topo: &Topology, handle: &PolicyHandle) -> usize {
    queue.pool_len(topo.pool_for_rung(handle.current_rung()))
}

/// Run a serving experiment on the homogeneous runtime.
///
/// * `make_engine` is called **inside** each executor thread (PJRT is
///   thread-bound); with `opts.workers == k` it is called k times.
/// * `arrivals` are offsets in seconds from run start; the injector
///   sleeps them out in real time (service times are real compute, so
///   time cannot be compressed without changing utilization).
///
/// With `opts.pools` set this delegates to [`serve_pools`], handing
/// every pool the same engine factory; use [`serve_pools`] directly to
/// build pool-specific engines.
pub fn serve<F, E>(
    make_engine: F,
    policy: Box<dyn ScalingPolicy>,
    arrivals: &[f64],
    opts: &ServeOptions,
) -> Result<ServeOutcome>
where
    F: Fn() -> Result<E> + Send + Sync,
    E: RequestEngine,
{
    serve_pools(|_pool: &PoolSpec| make_engine(), policy, arrivals, opts)
}

/// Run a serving experiment over the (possibly heterogeneous) pool
/// topology of `opts` — see the module docs for the runtime contract.
///
/// `make_engine` is called inside each executor thread with its pool's
/// [`PoolSpec`], once per worker; a harness can build pool-appropriate
/// engines (e.g. scale a mock's service times by `speed_factor`). With
/// a single [`PoolSpec::uniform`] pool this is exactly the homogeneous
/// k-worker runtime (routing, stealing, depth signal and records all
/// reduce to the pre-pool code; the parity tests in
/// `tests/worker_pool.rs` pin it).
pub fn serve_pools<F, E>(
    make_engine: F,
    policy: Box<dyn ScalingPolicy>,
    arrivals: &[f64],
    opts: &ServeOptions,
) -> Result<ServeOutcome>
where
    F: Fn(&PoolSpec) -> Result<E> + Send + Sync,
    E: RequestEngine,
{
    // One topology core decides routing, stealing, spilling and batch
    // extents for this run — the queue below and the DES both execute
    // exactly these choices.
    let topo: Arc<Topology> = Arc::new(opts.topology()?);
    let workers = topo.n_workers();
    let gate: Arc<(Mutex<StartGate>, Condvar)> = Arc::new((
        Mutex::new(StartGate { pending: workers, start: None }),
        Condvar::new(),
    ));
    let wait_start = {
        let gate = gate.clone();
        move || -> Instant {
            let (lock, cv) = &*gate;
            let mut g = lock.lock().unwrap();
            while g.start.is_none() {
                g = cv.wait(g).unwrap();
            }
            g.start.unwrap()
        }
    };

    let queue: Arc<ShardedQueue<Job>> = Arc::new(ShardedQueue::with_topology_backend(
        opts.queue_capacity,
        (*topo).clone(),
        opts.backend,
    ));
    let monitor = Arc::new(LoadMonitor::with_pools_period(
        0.3,
        topo.n_pools(),
        opts.tick_ms.max(1) as f64,
    ));
    let handle = Arc::new(PolicyHandle::new(policy));
    let rp = Arc::new(ReplanState::new(&opts.replan, &topo, opts.batch.max(1))?);
    let done = Arc::new(AtomicBool::new(false));
    let rejected = Arc::new(AtomicUsize::new(0));
    let res = Arc::new(ResilienceState::new(topo.n_pools(), opts.resilience.clone()));
    let ov = Arc::new(OverloadState::new(opts.overload.clone()));
    let make_engine = &make_engine;

    std::thread::scope(|scope| -> Result<ServeOutcome> {
        // ---- monitor tick thread: keeps hysteresis moving when idle.
        // Always takes the locked path so smoothing state progresses
        // even while every arrival/dequeue rides the lock-free band.
        {
            let queue = queue.clone();
            let handle = handle.clone();
            let monitor = monitor.clone();
            let done = done.clone();
            let topo = topo.clone();
            let tick = opts.tick_ms;
            let wait_start = wait_start.clone();
            let rp = rp.clone();
            scope.spawn(move || {
                let start = wait_start();
                while !done.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(tick));
                    let t = start.elapsed().as_secs_f64() * 1e3;
                    let rate = monitor.tick(t);
                    handle.observe_locked(t, pooled_depth(&queue, &topo, &handle));
                    // Re-plan evaluation rides the tick: the estimator
                    // decides (interval + min-change hysteresis inside)
                    // and the update lands atomically — plan into the
                    // policy, batch bound and spill margin into the
                    // shared cells the workers read per pop.
                    rp.step(t, rate, &handle, &queue);
                }
            });
        }

        // ---- arrival injector: rung-aware routing — an arrival goes to
        // the pool whose rung band contains the current policy rung, so
        // a rung switch across a band boundary redirects new load to a
        // different pool.
        {
            let queue = queue.clone();
            let handle = handle.clone();
            let monitor = monitor.clone();
            let rejected = rejected.clone();
            let topo = topo.clone();
            let arrivals = arrivals.to_vec();
            let wait_start = wait_start.clone();
            let faults = opts.faults.clone();
            let res = res.clone();
            let res_on = opts.resilience.enabled;
            let ov = ov.clone();
            scope.spawn(move || {
                let start = wait_start();
                for (id, &t_s) in arrivals.iter().enumerate() {
                    let target = Duration::from_secs_f64(t_s);
                    let elapsed = start.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                    let t = start.elapsed().as_secs_f64() * 1e3;
                    // An active queue squeeze tightens the admission
                    // bound below the configured capacity; a squeezed
                    // arrival is rejected before it is observed (the
                    // same pre-push check the DES admission runs).
                    if let Some(cap) = faults.capacity_at_ms(t) {
                        if queue.len() >= cap {
                            rejected.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                    // Deadline-aware admission (overload plane): shed
                    // the doomed/over-share arrival before it is
                    // observed or routed — the same pre-push admission
                    // point the DES engine runs.
                    if ov.enabled {
                        let mean = ov.cfg.mean_at(handle.current_rung());
                        if !ov.admit(id as u64, queue.len(), mean, topo.n_workers()) {
                            continue;
                        }
                    }
                    // Health-aware routing (resilience only): a rung
                    // band whose home pool is dark or breaker-open
                    // remaps to the nearest surviving pool, and remaps
                    // back the instant health returns.
                    let pool = if res_on {
                        let rung = handle.current_rung();
                        let (p, moved) = {
                            let mut hv = res.health.lock().unwrap();
                            topo.pool_for_rung_routable(rung, |q| hv.routable(q, t, &faults))
                        };
                        if moved {
                            res.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        p
                    } else {
                        topo.pool_for_rung(handle.current_rung())
                    };
                    monitor.on_arrival_pool(pool);
                    match queue.push_pool(pool, (id as u64, t, 0u32)) {
                        Ok(()) => {
                            handle.observe(t, pooled_depth(&queue, &topo, &handle));
                        }
                        Err(super::queue::QueueError::Full) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(super::queue::QueueError::Closed) => {
                            // Conservation: the queue can only close under
                            // our feet if an external actor closed it; the
                            // current arrival and everything after it are
                            // rejected, not silently dropped, so
                            // `records + rejected == arrivals` still holds.
                            rejected.fetch_add(arrivals.len() - id, Ordering::Relaxed);
                            break;
                        }
                    }
                }
                queue.close();
            });
        }

        // ---- executor pools: worker `lw` of pool `p` drains its home
        // shard, steals within its pool when dry, spills across pools
        // only when its whole pool is dry — up to `batch` requests per
        // engine dispatch. Each pool resolves its *own* rung: the policy
        // rung clamped into the pool's band.
        let batch = opts.batch.max(1);
        let mut handles = Vec::with_capacity(workers);
        for (p, spec) in topo.pools().iter().enumerate() {
            for lw in 0..spec.workers.max(1) {
                let queue = queue.clone();
                let handle = handle.clone();
                let gate = gate.clone();
                let topo = topo.clone();
                let spec = spec.clone();
                let rejected = rejected.clone();
                let faults = opts.faults.clone();
                let dark_at = opts.faults.dark_at_ms(p);
                let dark_until = opts.faults.dark_until_ms(p);
                let res = res.clone();
                let res_cfg = opts.resilience.clone();
                let ov = ov.clone();
                let rp = rp.clone();
                handles.push(scope.spawn(move || -> Result<(usize, Vec<RequestRecord>)> {
                    // Build (and PJRT-compile) the engine; the last
                    // worker to finish releases the run clock. A failed
                    // build still releases it so the run can wind down.
                    let engine = make_engine(&spec);
                    let start = {
                        let (lock, cv) = &*gate;
                        let mut g = lock.lock().unwrap();
                        g.pending -= 1;
                        if g.pending == 0 {
                            g.start = Some(Instant::now());
                            cv.notify_all();
                        }
                        while g.start.is_none() {
                            g = cv.wait(g).unwrap();
                        }
                        g.start.unwrap()
                    };
                    let mut engine = engine?;
                    let n_rungs = engine.rungs();
                    let now_ms = move || start.elapsed().as_secs_f64() * 1e3;
                    let mut records = Vec::new();
                    // The pop result is exhaustive by construction:
                    // Item / TimedOut / Closed — no error arm to
                    // declare unreachable. A batch (never empty) is
                    // dispatched once: one rung resolution, one engine
                    // call, one policy observation at dequeue and one
                    // at completion; every request in it shares the
                    // batch's start/finish bounds (its latency is the
                    // batch's latency — requests complete when their
                    // batch does). B = 1 takes the allocation-free
                    // single-item path — exactly the seed loop — unless
                    // the re-planner sizes batches adaptively, which
                    // needs the batch machinery even when the current
                    // bound happens to be 1.
                    if batch == 1 && !rp.adaptive {
                        loop {
                            if dark_at.is_some() && faults.is_dark_at_ms(p, now_ms()) {
                                let until = dark_until.unwrap_or(f64::INFINITY);
                                if res_cfg.enabled {
                                    // Failover: redistribute the stranded
                                    // backlog, park out the window, resume.
                                    failover_dark_pool(
                                        &queue,
                                        &topo,
                                        p,
                                        lw,
                                        &res,
                                        &faults,
                                        until,
                                        &now_ms,
                                        &rejected,
                                    );
                                    if until.is_finite() {
                                        continue;
                                    }
                                    break;
                                }
                                if until.is_finite() {
                                    // Windowed dark without resilience:
                                    // the pool pauses and its backlog
                                    // waits (or is spill-absorbed) until
                                    // the window closes.
                                    std::thread::sleep(Duration::from_millis(5));
                                    continue;
                                }
                                drain_dark_pool(&queue, p, lw, &rejected);
                                break;
                            }
                            match queue.pop_timeout_pool(p, lw, Duration::from_millis(50)) {
                                Popped::Item(job) => {
                                    let (id, arrival_ms, attempt) = job;
                                    let t_start = now_ms();
                                    // Lazy in-queue expiry (overload
                                    // plane): a request whose deadline
                                    // passed while it queued is skipped
                                    // and counted — stale work never
                                    // occupies the server.
                                    if ov.enabled && ov.cfg.expired(id, arrival_ms, t_start) {
                                        ov.expired.fetch_add(1, Ordering::Relaxed);
                                        ov.observe_pop(true);
                                        continue;
                                    }
                                    // Switches take effect at dequeue;
                                    // the pool executes the rung of its
                                    // own band — browned out and
                                    // class-floored under overload.
                                    let d = pooled_depth(&queue, &topo, &handle);
                                    let idx = handle.observe(t_start, d);
                                    let exec =
                                        ov.exec_rung(&topo, p, idx, n_rungs, &[job], t_start);
                                    // Injected flake: a deterministic coin
                                    // on (id, attempt) — the same coin the
                                    // DES flips — fails the request before
                                    // the engine is called.
                                    let flaked = faults.flaky_fails(p, id, attempt, arrival_ms);
                                    let outcome = if flaked {
                                        None
                                    } else {
                                        let caught =
                                            catch_unwind(AssertUnwindSafe(|| engine.execute(exec)));
                                        match caught {
                                            Ok(Ok(out)) => Some(out),
                                            // Engine error: counted per
                                            // request, never a run abort.
                                            Ok(Err(_)) => None,
                                            Err(_) => {
                                                // Supervised panic: count it
                                                // and rebuild the engine in
                                                // place from the factory —
                                                // the worker survives.
                                                res.panics.fetch_add(1, Ordering::Relaxed);
                                                engine = make_engine(&spec)?;
                                                None
                                            }
                                        }
                                    };
                                    match outcome {
                                        Some(out) => {
                                            // An active slowdown window
                                            // stretches this pool's service
                                            // wall-clock by the fault factor.
                                            let stretch = faults.slowdown_at_ms(p, t_start)
                                                * faults.drift_at_ms(p, t_start);
                                            if stretch > 1.0 {
                                                let extra = (now_ms() - t_start) * (stretch - 1.0);
                                                std::thread::sleep(Duration::from_secs_f64(
                                                    extra / 1e3,
                                                ));
                                            }
                                            let t_fin = now_ms();
                                            // Feed the re-planner's fit
                                            // buffer (same observable the
                                            // DES records).
                                            rp.on_completion(p, exec, 1, t_fin - t_start);
                                            if res_cfg.timed_out(t_fin - t_start) {
                                                // Too slow to count: a
                                                // timeout failure (feeds
                                                // the breaker EWMA).
                                                res.timeouts.fetch_add(1, Ordering::Relaxed);
                                                res.record(p, false, t_fin);
                                                retry_or_fail(
                                                    &queue,
                                                    &topo,
                                                    &handle,
                                                    &res,
                                                    &faults,
                                                    &res_cfg,
                                                    job,
                                                    &now_ms,
                                                );
                                            } else {
                                                res.record(p, true, t_fin);
                                                records.push(RequestRecord {
                                                    id,
                                                    arrival_ms,
                                                    start_ms: t_start,
                                                    finish_ms: t_fin,
                                                    config_idx: exec,
                                                    accuracy: out.accuracy,
                                                    success: out.success,
                                                });
                                            }
                                            handle.observe(
                                                t_fin,
                                                pooled_depth(&queue, &topo, &handle),
                                            );
                                        }
                                        None => {
                                            let t_fin = now_ms();
                                            res.record(p, false, t_fin);
                                            retry_or_fail(
                                                &queue,
                                                &topo,
                                                &handle,
                                                &res,
                                                &faults,
                                                &res_cfg,
                                                job,
                                                &now_ms,
                                            );
                                            handle.observe(
                                                t_fin,
                                                pooled_depth(&queue, &topo, &handle),
                                            );
                                        }
                                    }
                                }
                                Popped::TimedOut => {}
                                Popped::Closed => break,
                            }
                        }
                        return Ok((p, records));
                    }
                    // Reusable per-worker scratch: the popped batch, its
                    // flaked-out members and the engine outcomes live in
                    // buffers that survive iterations, so the
                    // steady-state dispatch path performs zero per-batch
                    // heap allocations (asserted by tests/alloc_free.rs).
                    let mut batch_buf: Vec<Job> = Vec::with_capacity(batch.max(8));
                    let mut flaked_buf: Vec<Job> = Vec::with_capacity(batch.max(8));
                    let mut outs_buf = Vec::with_capacity(batch.max(8));
                    loop {
                        if dark_at.is_some() && faults.is_dark_at_ms(p, now_ms()) {
                            let until = dark_until.unwrap_or(f64::INFINITY);
                            if res_cfg.enabled {
                                failover_dark_pool(
                                    &queue,
                                    &topo,
                                    p,
                                    lw,
                                    &res,
                                    &faults,
                                    until,
                                    &now_ms,
                                    &rejected,
                                );
                                if until.is_finite() {
                                    continue;
                                }
                                break;
                            }
                            if until.is_finite() {
                                std::thread::sleep(Duration::from_millis(5));
                                continue;
                            }
                            drain_dark_pool(&queue, p, lw, &rejected);
                            break;
                        }
                        // Adaptive batch: the bound is whatever the last
                        // re-plan update published (B = min(depth, B_max)
                        // with hysteresis); static runs read the fixed
                        // configured bound.
                        let want = if rp.adaptive {
                            rp.batch.load(Ordering::Relaxed).max(1)
                        } else {
                            batch
                        };
                        match queue.pop_batch_pool_into(
                            p,
                            lw,
                            want,
                            Duration::from_millis(50),
                            &mut batch_buf,
                        ) {
                            Popped::Item(_) => {
                                let t_start = now_ms();
                                // Lazy in-queue expiry (overload
                                // plane): already-doomed requests fall
                                // out of the batch before dispatch.
                                ov.expire_batch(&mut batch_buf, t_start);
                                // Switches take effect at dequeue;
                                // browned out and class-floored under
                                // overload.
                                let d = pooled_depth(&queue, &topo, &handle);
                                let idx = handle.observe(t_start, d);
                                let exec =
                                    ov.exec_rung(&topo, p, idx, n_rungs, &batch_buf, t_start);
                                // Injected flakes fail out of the batch
                                // before dispatch (the same per-request
                                // coin as the DES); the engine runs the
                                // survivors, left in place in the batch
                                // scratch (order preserved).
                                flaked_buf.clear();
                                batch_buf.retain(|&(id, arr, att)| {
                                    if faults.flaky_fails(p, id, att, arr) {
                                        flaked_buf.push((id, arr, att));
                                        false
                                    } else {
                                        true
                                    }
                                });
                                // `ok` plays the old `outs.is_some()`:
                                // the engine ran the survivors and
                                // filled the outcome scratch 1:1.
                                let ok = if batch_buf.is_empty() {
                                    outs_buf.clear();
                                    true
                                } else {
                                    match catch_unwind(AssertUnwindSafe(|| {
                                        engine.execute_batch_into(
                                            exec,
                                            batch_buf.len(),
                                            &mut outs_buf,
                                        )
                                    })) {
                                        Ok(Ok(())) => {
                                            anyhow::ensure!(
                                                outs_buf.len() == batch_buf.len(),
                                                "engine returned {} outcomes for a batch of {}",
                                                outs_buf.len(),
                                                batch_buf.len()
                                            );
                                            true
                                        }
                                        // Engine error: the whole batch
                                        // takes the failure path, the
                                        // worker survives.
                                        Ok(Err(_)) => false,
                                        Err(_) => {
                                            res.panics.fetch_add(1, Ordering::Relaxed);
                                            engine = make_engine(&spec)?;
                                            false
                                        }
                                    }
                                };
                                // Slowdown (and drift) windows stretch the
                                // batch's wall-clock exactly like the B = 1
                                // path.
                                let stretch = faults.slowdown_at_ms(p, t_start)
                                    * faults.drift_at_ms(p, t_start);
                                if stretch > 1.0 {
                                    let extra = (now_ms() - t_start) * (stretch - 1.0);
                                    std::thread::sleep(Duration::from_secs_f64(extra / 1e3));
                                }
                                let t_fin = now_ms();
                                // Executed batches feed the re-planner's
                                // (size, wall ms) fit buffer — flaked-out
                                // or engine-failed batches measured no
                                // service and are not recorded.
                                if ok && !batch_buf.is_empty() {
                                    rp.on_completion(p, exec, batch_buf.len(), t_fin - t_start);
                                }
                                if ok && !res_cfg.timed_out(t_fin - t_start) {
                                    for (&(id, arrival_ms, _), out) in
                                        batch_buf.iter().zip(outs_buf.iter())
                                    {
                                        res.record(p, true, t_fin);
                                        records.push(RequestRecord {
                                            id,
                                            arrival_ms,
                                            start_ms: t_start,
                                            finish_ms: t_fin,
                                            config_idx: exec,
                                            accuracy: out.accuracy,
                                            success: out.success,
                                        });
                                    }
                                } else if ok {
                                    // Beat the engine but not the
                                    // clock: the whole batch times out.
                                    let timed = batch_buf.len() as u64;
                                    res.timeouts.fetch_add(timed, Ordering::Relaxed);
                                    for &job in &batch_buf {
                                        res.record(p, false, t_fin);
                                        retry_or_fail(
                                            &queue,
                                            &topo,
                                            &handle,
                                            &res,
                                            &faults,
                                            &res_cfg,
                                            job,
                                            &now_ms,
                                        );
                                    }
                                } else {
                                    for &job in &batch_buf {
                                        res.record(p, false, t_fin);
                                        retry_or_fail(
                                            &queue,
                                            &topo,
                                            &handle,
                                            &res,
                                            &faults,
                                            &res_cfg,
                                            job,
                                            &now_ms,
                                        );
                                    }
                                }
                                for &job in &flaked_buf {
                                    res.record(p, false, t_fin);
                                    retry_or_fail(
                                        &queue,
                                        &topo,
                                        &handle,
                                        &res,
                                        &faults,
                                        &res_cfg,
                                        job,
                                        &now_ms,
                                    );
                                }
                                handle.observe(t_fin, pooled_depth(&queue, &topo, &handle));
                            }
                            Popped::TimedOut => {}
                            Popped::Closed => break,
                        }
                    }
                    Ok((p, records))
                }));
            }
        }

        // Join every worker before signalling `done` (the monitor must
        // keep ticking while any worker still drains the queue), then
        // merge the per-worker records and propagate the first error.
        // Worker panics inside the execute path are caught and
        // supervised; a panic escaping to here (outside the supervised
        // region) surfaces as an error instead of poisoning the join.
        let results: Vec<Result<(usize, Vec<RequestRecord>)>> = handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!(
                    "executor thread panicked outside the supervised execute path"
                )),
            })
            .collect();
        done.store(true, Ordering::Relaxed);
        let mut records = Vec::new();
        let mut pool_served = vec![0usize; topo.n_pools()];
        for r in results {
            let (p, rs) = r?;
            pool_served[p] += rs.len();
            records.extend(rs);
        }
        // Deterministic order regardless of which worker served what
        // (a no-op at k = 1: one FIFO consumer pops in id order).
        records.sort_by_key(|r| r.id);

        let pool_arrivals = (0..topo.n_pools())
            .map(|p| monitor.pool_arrivals_total(p))
            .collect();
        let breaker_trips = res.health.lock().unwrap().breaker_trips;
        Ok(ServeOutcome {
            records,
            switches: handle.take_switches(),
            rejected: rejected.load(Ordering::Relaxed),
            final_rate_qps: monitor.rate_qps(),
            steals: queue.steals(),
            spills: queue.spills(),
            pool_served,
            pool_arrivals,
            failed: res.failed.load(Ordering::Relaxed),
            retries: res.retries.load(Ordering::Relaxed),
            panics_recovered: res.panics.load(Ordering::Relaxed),
            timeouts: res.timeouts.load(Ordering::Relaxed),
            breaker_trips,
            failovers: res.failovers.load(Ordering::Relaxed),
            shed: ov.shed.load(Ordering::Relaxed),
            expired: ov.expired.load(Ordering::Relaxed),
            brownout_steps: ov.steps(),
            replans: rp.replans.load(Ordering::Relaxed),
        })
    })
}

/// Fault injection: a dark worker stops serving, parks until the run
/// winds down, then rejects whatever backlog is still stranded on its
/// pool's own shards. Alive pools may spill-absorb the backlog in the
/// meantime (the spill gate still applies) and nothing is silently
/// dropped, so `records + rejected == arrivals` holds under the fault.
fn drain_dark_pool<T>(queue: &ShardedQueue<T>, pool: usize, worker: usize, lost: &AtomicUsize) {
    let mut n = 0usize;
    loop {
        if queue.is_closed() {
            while queue.try_pop_home(pool, worker).is_some() {
                n += 1;
            }
            if queue.pool_len(pool) == 0 {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    if n > 0 {
        lost.fetch_add(n, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::executor::MockEngine;
    use crate::serving::policy::StaticPolicy;
    use crate::serving::ElasticoPolicy;

    #[test]
    fn serves_all_requests_fifo() {
        let arrivals: Vec<f64> = (0..40).map(|i| i as f64 * 0.005).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![2.0],
                    accuracy: vec![0.8],
                    dispatch_ms: 0.0,
                })
            },
            Box::new(StaticPolicy::new(0, "fast")),
            &arrivals,
            &ServeOptions::default(),
        )
        .unwrap();
        assert_eq!(out.records.len(), 40);
        assert_eq!(out.rejected, 0);
        assert_eq!(out.steals, 0, "central discipline never steals");
        let mut by_start = out.records.clone();
        by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
        for w in by_start.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
            assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "single-server violated");
        }
    }

    #[test]
    fn overload_builds_queue_latency() {
        // 10 ms service, arrivals every 4 ms -> queue grows, latency >>
        // service time by the tail of the run.
        let arrivals: Vec<f64> = (0..50).map(|i| i as f64 * 0.004).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![10.0],
                    accuracy: vec![0.8],
                    dispatch_ms: 0.0,
                })
            },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions::default(),
        )
        .unwrap();
        let last = out.records.iter().max_by_key(|r| r.id).unwrap();
        assert!(
            last.latency_ms() > 100.0,
            "tail latency {} should reflect queueing",
            last.latency_ms()
        );
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.001).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![20.0],
                    accuracy: vec![0.8],
                    dispatch_ms: 0.0,
                })
            },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions {
                queue_capacity: 4,
                tick_ms: 10,
                workers: 1,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        assert!(out.rejected > 0);
        assert_eq!(out.records.len() + out.rejected, 30);
    }

    #[test]
    fn batched_dispatch_serves_everything_with_shared_bounds() {
        // 60 near-simultaneous arrivals, B = 8, α = 4 of 5 ms fixed:
        // batches amortize the dispatch cost, every request is served
        // exactly once, and each batch's records share start/finish.
        let arrivals: Vec<f64> = (0..60).map(|i| i as f64 * 0.0002).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![5.0],
                    accuracy: vec![0.8],
                    dispatch_ms: 4.0,
                })
            },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions { batch: 8, ..ServeOptions::default() },
        )
        .unwrap();
        assert_eq!(out.records.len() + out.rejected, 60, "conservation");
        assert_eq!(out.rejected, 0);
        let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..60).collect::<Vec<u64>>());
        // Group records by (start, finish): batches of up to 8, each
        // with identical bounds, and at least one real multi-request
        // batch under this backlog.
        let mut sizes: std::collections::HashMap<(u64, u64), usize> =
            std::collections::HashMap::new();
        for r in &out.records {
            *sizes
                .entry((r.start_ms.to_bits(), r.finish_ms.to_bits()))
                .or_default() += 1;
        }
        assert!(sizes.values().all(|&n| n <= 8), "batch bound violated");
        assert!(
            sizes.values().any(|&n| n > 1),
            "no multi-request batch formed under a 60-deep backlog"
        );
    }

    #[test]
    fn batch_of_one_matches_unbatched_semantics() {
        // batch = 1 must keep the seed path: strict FIFO, one request
        // per dispatch (no two records share their service interval).
        let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.001).collect();
        let out = serve(
            || {
                Ok(MockEngine {
                    service_ms: vec![3.0],
                    accuracy: vec![0.8],
                    dispatch_ms: 2.0,
                })
            },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions { batch: 1, ..ServeOptions::default() },
        )
        .unwrap();
        assert_eq!(out.records.len(), 30);
        let mut bounds: Vec<(u64, u64)> = out
            .records
            .iter()
            .map(|r| (r.start_ms.to_bits(), r.finish_ms.to_bits()))
            .collect();
        bounds.sort_unstable();
        bounds.dedup();
        assert_eq!(bounds.len(), 30, "B=1 must dispatch one request at a time");
    }

    #[test]
    fn engine_build_failure_propagates() {
        let arrivals = [0.0, 0.001];
        let err = serve(
            || -> Result<MockEngine> { anyhow::bail!("no accelerator") },
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions::default(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no accelerator"));
    }

    #[test]
    fn effective_shards_resolution() {
        let central = ServeOptions { workers: 8, ..ServeOptions::default() };
        assert_eq!(central.effective_shards(), 1);
        let auto = ServeOptions {
            workers: 8,
            discipline: Discipline::ShardedSteal,
            ..ServeOptions::default()
        };
        assert_eq!(auto.effective_shards(), 8);
        let pinned = ServeOptions {
            workers: 8,
            discipline: Discipline::ShardedSteal,
            shards: 3,
            ..ServeOptions::default()
        };
        assert_eq!(pinned.effective_shards(), 3);
    }

    #[test]
    fn pool_topology_resolution() {
        // Homogeneous options wrap into one uniform pool; the shard
        // layout keeps the discipline semantics (central = 1 shard).
        let legacy = ServeOptions { workers: 4, ..ServeOptions::default() };
        assert_eq!(legacy.effective_pools(), vec![PoolSpec::uniform(4)]);
        assert_eq!(legacy.pool_shard_counts(), vec![1]);
        assert_eq!(legacy.total_workers(), 4);
        let sharded = ServeOptions {
            workers: 4,
            discipline: Discipline::ShardedSteal,
            ..ServeOptions::default()
        };
        assert_eq!(sharded.pool_shard_counts(), vec![4]);
        // Explicit pools override workers and run per-worker shards.
        let pooled = ServeOptions {
            workers: 1,
            pools: crate::serving::pool::parse_pools("fast:3:1.0,acc:2:2.0").unwrap(),
            ..ServeOptions::default()
        };
        assert_eq!(pooled.pool_shard_counts(), vec![3, 2]);
        assert_eq!(pooled.total_workers(), 5);
    }

    #[test]
    fn policy_handle_fast_path_matches_locked_decisions() {
        // Drive the same observation stream through a PolicyHandle and a
        // bare policy; the handle's returned rungs and recorded switches
        // must match (single-threaded: band staleness cannot appear).
        let plan = {
            let mk = |label: &str, acc: f64, mean: f64| {
                crate::planner::ProfiledConfig {
                    config: vec![],
                    label: label.into(),
                    accuracy: acc,
                    latency: crate::planner::LatencyProfile {
                        mean_ms: mean,
                        p50_ms: mean,
                        p95_ms: mean * 1.2,
                        runs: 5,
                    },
                }
            };
            crate::planner::derive_plan(
                &[mk("fast", 0.76, 20.0), mk("accurate", 0.85, 90.0)],
                crate::planner::AqmParams::for_slo(300.0),
            )
        };
        let handle = PolicyHandle::new(Box::new(ElasticoPolicy::new(plan.clone())));
        let mut bare = ElasticoPolicy::new(plan);
        let mut bare_switches = 0usize;
        let depths = [0usize, 0, 1, 4, 9, 14, 9, 3, 1, 0, 0, 0, 0, 2, 7, 0];
        let mut t = 0.0;
        for (i, &d) in depths.iter().cycle().take(600).enumerate() {
            t += if i % 11 == 0 { 1200.0 } else { 15.0 };
            let got = handle.observe(t, d);
            // Reference: the same elision rule applied to a bare policy,
            // so both sides skip exactly the same observations.
            let want = match bare.no_switch_band() {
                Some((lo, hi)) if d >= lo && d <= hi => bare.current(),
                _ => {
                    let before = bare.current();
                    let next = bare.decide(t, d);
                    if next != before {
                        bare_switches += 1;
                    }
                    next
                }
            };
            assert_eq!(got, want, "diverged at t={t} depth={d}");
            // Ticks hit the locked path in both worlds.
            if i % 5 == 0 {
                let before = bare.current();
                let next = bare.decide(t + 1.0, d);
                if next != before {
                    bare_switches += 1;
                }
                assert_eq!(handle.observe_locked(t + 1.0, d), next);
            }
        }
        let switches = handle.take_switches();
        assert!(!switches.is_empty(), "stream should have produced switches");
        assert_eq!(switches.len(), bare_switches, "audit trail diverged");
    }
}
