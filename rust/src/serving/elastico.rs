//! Elastico — the runtime adaptation controller (paper §III-B, §V).
//!
//! Decision rule on every load observation:
//!
//! * **upscale** (toward fast): if queue depth exceeds the current rung's
//!   `N↑` threshold, step one rung down the ladder immediately (upscale
//!   cooldown `t↑ ≈ 0`: violations are imminent, react now);
//! * **downscale** (toward accurate): if depth has stayed below the
//!   current rung's `N↓` threshold for a sustained window `t↓` (the
//!   asymmetric hysteresis of §V-F), step one rung up.
//!
//! Multi-rung spikes are absorbed by repeated upscale steps on subsequent
//! observations — with `t↑ = 0` and per-arrival observations this drops
//! to the fastest sustainable rung within a handful of arrivals, matching
//! the paper's "switches occur within seconds of load changes".
//!
//! On a heterogeneous fleet the same state machine runs unchanged, but
//! the depth it observes is **per pool** — the backlog of the pool the
//! current rung routes to (see [`crate::serving::pool`]) — and each
//! rung's thresholds were derived from its owning pool's worker count
//! and speed ([`crate::planner::derive_plan_pools`]). An upscale across
//! a band boundary therefore doesn't just pick a faster config: it
//! redirects new arrivals to the faster *pool*, and the signal follows
//! the traffic to wherever it now queues.

use super::policy::ScalingPolicy;
use crate::planner::Plan;

/// The Elastico controller state machine.
#[derive(Clone, Debug)]
pub struct ElasticoPolicy {
    plan: Plan,
    current: usize,
    /// Last time we moved toward fast (for t↑).
    last_upscale_ms: f64,
    /// Start of the current sustained-low-load window, if any.
    low_since_ms: Option<f64>,
    /// EWMA-smoothed queue depth: upscaling reacts to the instantaneous
    /// depth (violations are imminent), downscaling to the smoothed depth
    /// (so M/G/1 stochastic flutter around the threshold cannot defeat
    /// the hysteresis window).
    depth_ewma: f64,
    /// EWMA weight for the smoothed depth.
    pub ewma_alpha: f64,
}

impl ElasticoPolicy {
    /// Start at the most accurate rung (paper: converges there under low
    /// load; starting accurate maximizes quality until load says
    /// otherwise).
    pub fn new(plan: Plan) -> ElasticoPolicy {
        let start = plan.most_accurate();
        ElasticoPolicy {
            plan,
            current: start,
            last_upscale_ms: f64::NEG_INFINITY,
            low_since_ms: None,
            depth_ewma: 0.0,
            ewma_alpha: 0.15,
        }
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The rung Elastico would run under sustained queue depth `n` —
    /// used by tests and the AQM validation experiment.
    pub fn steady_state_for_depth(&self, depth: usize) -> usize {
        // The deepest (slowest) rung whose upscale threshold tolerates n.
        for idx in (0..self.plan.ladder.len()).rev() {
            if depth as u64 <= self.plan.ladder[idx].upscale_threshold {
                return idx;
            }
        }
        0
    }
}

impl ScalingPolicy for ElasticoPolicy {
    fn decide(&mut self, now_ms: f64, queue_depth: usize) -> usize {
        let depth = queue_depth as u64;
        self.depth_ewma += self.ewma_alpha * (queue_depth as f64 - self.depth_ewma);
        let cur = &self.plan.ladder[self.current];

        // Upscale: instantaneous queue exceeded N↑ of the current rung.
        if depth > cur.upscale_threshold && self.current > 0 {
            if now_ms - self.last_upscale_ms >= self.plan.up_cooldown_ms {
                self.current -= 1;
                self.last_upscale_ms = now_ms;
                self.low_since_ms = None;
                // A spike invalidates the smoothed history as a
                // downscale signal; restart it pessimistically.
                self.depth_ewma = self.depth_ewma.max(queue_depth as f64);
            }
            return self.current;
        }

        // Downscale: smoothed depth within N↓ (Eq. 12: N * s̄(k+1) <=
        // Δ(k+1) - h_s) sustained for the cooldown window t↓.
        if self.current < self.plan.most_accurate() {
            if let Some(thr) = cur.downscale_threshold {
                // Rounded smoothed depth: an EWMA hovering at 0.2 under
                // light load must still satisfy an N↓ = 0 threshold
                // (strict comparison against a fractional EWMA would make
                // the most-accurate rung unreachable).
                if self.depth_ewma.round() <= thr as f64 + 1e-9 {
                    match self.low_since_ms {
                        None => self.low_since_ms = Some(now_ms),
                        Some(t0) => {
                            if now_ms - t0 >= self.plan.down_cooldown_ms {
                                self.current += 1;
                                self.low_since_ms = None;
                            }
                        }
                    }
                } else {
                    // Load rebounded: restart the hysteresis window.
                    self.low_since_ms = None;
                }
            }
        }
        self.current
    }

    fn current(&self) -> usize {
        self.current
    }

    fn name(&self) -> String {
        "Elastico".into()
    }

    /// Adopt re-derived thresholds (the online re-planner's install
    /// hook). The ladder shape must match — the re-planner only retunes
    /// thresholds over the same rungs. The selected rung is kept, the
    /// open hysteresis window (if any) is reset since its threshold
    /// basis changed, and the depth EWMA carries over (it measures load,
    /// not the plan).
    fn replace_plan(&mut self, plan: Plan) -> bool {
        assert_eq!(
            plan.ladder.len(),
            self.plan.ladder.len(),
            "replace_plan must preserve the ladder shape"
        );
        self.current = self.current.min(plan.most_accurate());
        self.plan = plan;
        self.low_since_ms = None;
        true
    }

    /// The band where `decide` provably does nothing: above the
    /// downscale threshold (no window can open) and at or below the
    /// upscale threshold (no step toward fast). Empty (`None`) whenever
    /// timing matters — a hysteresis window is open (its completion and
    /// rebound-reset both need the clock), or the smoothed depth is low
    /// enough that the next observation could open one. In-band skipped
    /// observations all carry depth > N↓, which keeps the rounded EWMA
    /// above the downscale threshold, so skipping them cannot flip the
    /// downscale predicate; the EWMA itself is refreshed by the monitor
    /// tick, which always takes the locked path.
    fn no_switch_band(&self) -> Option<(usize, usize)> {
        if self.low_since_ms.is_some() {
            return None;
        }
        let cur = &self.plan.ladder[self.current];
        let lo = match cur.downscale_threshold {
            Some(thr) if self.current < self.plan.most_accurate() => {
                if self.depth_ewma.round() <= thr as f64 + 1e-9 {
                    // Next low observation would open the window.
                    return None;
                }
                thr as usize + 1
            }
            // Most-accurate rung (or no threshold): downscale impossible.
            _ => 0,
        };
        let hi = if self.current > 0 {
            cur.upscale_threshold as usize
        } else {
            // Fastest rung: no further upscale, any depth is tolerated.
            usize::MAX
        };
        (lo <= hi).then_some((lo, hi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{ConfigPolicy, Plan};

    fn plan3() -> Plan {
        let rung = |label: &str, acc: f64, mean: f64, p95: f64, up: u64, down: Option<u64>| {
            ConfigPolicy {
                label: label.into(),
                config: vec![],
                accuracy: acc,
                mean_ms: mean,
                p95_ms: p95,
                queue_slack_ms: 0.0,
                upscale_threshold: up,
                downscale_threshold: down,
            }
        };
        Plan {
            slo_ms: 300.0,
            slack_buffer_ms: 30.0,
            up_cooldown_ms: 0.0,
            down_cooldown_ms: 5000.0,
            workers: 1,
            batch: 1,
            batch_alpha_ms: 0.0,
            pools: vec![],
            ladder: vec![
                rung("fast", 0.76, 20.0, 30.0, 13, Some(4)),
                rung("medium", 0.82, 45.0, 70.0, 5, Some(1)),
                rung("accurate", 0.85, 90.0, 140.0, 1, None),
            ],
        }
    }

    #[test]
    fn starts_most_accurate() {
        let p = ElasticoPolicy::new(plan3());
        assert_eq!(p.current(), 2);
    }

    #[test]
    fn upscales_immediately_on_deep_queue() {
        let mut p = ElasticoPolicy::new(plan3());
        // Depth 9 > N↑2=1 -> step to medium; > N↑1=5 -> step to fast.
        assert_eq!(p.decide(0.0, 9), 1);
        assert_eq!(p.decide(1.0, 9), 0);
        // Depth 9 <= N↑0=13 -> stays fast.
        assert_eq!(p.decide(2.0, 9), 0);
    }

    /// Drive the policy with periodic observations of constant depth;
    /// returns the rung after the last tick.
    fn drive(p: &mut ElasticoPolicy, from_ms: f64, to_ms: f64, step_ms: f64, depth: usize) -> usize {
        let mut t = from_ms;
        let mut cur = p.current();
        while t <= to_ms {
            cur = p.decide(t, depth);
            t += step_ms;
        }
        cur
    }

    #[test]
    fn downscale_requires_sustained_low_load() {
        let mut p = ElasticoPolicy::new(plan3());
        p.decide(0.0, 20); // -> medium
        p.decide(1.0, 20); // -> fast
        assert_eq!(p.current(), 0);
        // Low queue, but only briefly: no downscale within 2 s (< t↓=5s).
        assert_eq!(drive(&mut p, 10.0, 2000.0, 20.0, 0), 0);
        // Sustained idle: recovers one rung per t↓ window.
        assert_eq!(drive(&mut p, 2020.0, 9000.0, 20.0, 0), 1);
        assert_eq!(drive(&mut p, 9020.0, 16_000.0, 20.0, 0), 2);
    }

    #[test]
    fn rebound_resets_hysteresis_window() {
        let mut p = ElasticoPolicy::new(plan3());
        p.decide(0.0, 20);
        p.decide(1.0, 20); // fast
        // 4 s of idle (window open but t↓ not reached)…
        assert_eq!(drive(&mut p, 10.0, 4000.0, 20.0, 0), 0);
        // …then a rebound burst above N↓0=4 resets the window…
        drive(&mut p, 4020.0, 4400.0, 20.0, 12);
        // …so 3 s more of idle still isn't enough,
        assert_eq!(drive(&mut p, 4420.0, 7400.0, 20.0, 0), 0);
        // but a further full window is.
        assert_eq!(drive(&mut p, 7420.0, 13_500.0, 20.0, 0), 1);
    }

    #[test]
    fn no_oscillation_at_threshold_boundary() {
        // Depth oscillating around N↓0=4 must not flap configurations:
        // at most the single EWMA-mediated downscale may occur.
        let mut p = ElasticoPolicy::new(plan3());
        p.decide(0.0, 20);
        p.decide(1.0, 20); // fast
        let mut switches = 0;
        let mut last = p.current();
        for i in 0..2000 {
            let depth = if i % 2 == 0 { 3 } else { 5 }; // around N↓0=4
            let now = 10.0 + i as f64 * 10.0;
            let cur = p.decide(now, depth);
            if cur != last {
                switches += 1;
                last = cur;
            }
        }
        assert!(switches <= 1, "hysteresis should absorb boundary noise, saw {switches}");
    }

    #[test]
    fn steady_state_mapping() {
        let p = ElasticoPolicy::new(plan3());
        assert_eq!(p.steady_state_for_depth(0), 2);
        assert_eq!(p.steady_state_for_depth(1), 2);
        assert_eq!(p.steady_state_for_depth(3), 1);
        assert_eq!(p.steady_state_for_depth(20), 0);
    }

    #[test]
    fn band_is_sound_against_decide() {
        // Wherever a band is advertised, an in-band decide must be a
        // pure no-op on the selected rung — fuzz the policy through a
        // load ramp and check the contract at every step.
        let mut p = ElasticoPolicy::new(plan3());
        let depths =
            [0, 0, 9, 9, 2, 0, 0, 0, 20, 20, 1, 1, 6, 3, 0, 14, 5, 5, 0, 0];
        let mut t = 0.0;
        for (i, &d) in depths.iter().cycle().take(400).enumerate() {
            t += if i % 7 == 0 { 900.0 } else { 35.0 };
            if let Some((lo, hi)) = p.no_switch_band() {
                assert!(lo <= hi);
                for probe in [lo, (lo + hi.min(lo + 50)) / 2, hi.min(lo + 50)] {
                    let mut clone = p.clone();
                    let before = clone.current();
                    assert_eq!(
                        clone.decide(t, probe),
                        before,
                        "in-band depth {probe} moved the rung at t={t}"
                    );
                    assert_eq!(clone.low_since_ms, p.low_since_ms);
                }
            }
            p.decide(t, d);
        }
    }

    #[test]
    fn band_empty_while_hysteresis_window_open() {
        let mut p = ElasticoPolicy::new(plan3());
        p.decide(0.0, 20); // -> medium
        p.decide(1.0, 20); // -> fast
        assert!(p.no_switch_band().is_some());
        // Sustained low depth drains the EWMA until the downscale window
        // opens: timing now matters, so the fast path must be disabled.
        // (The band must already be gone once the EWMA sits at the
        // threshold, i.e. before the opening observation itself.)
        for i in 0..40 {
            p.decide(10.0 + i as f64, 0);
            if p.low_since_ms.is_some() {
                break;
            }
        }
        assert!(p.low_since_ms.is_some(), "window never opened");
        assert_eq!(p.no_switch_band(), None);
    }

    #[test]
    fn replace_plan_swaps_thresholds_and_resets_hysteresis() {
        let mut p = ElasticoPolicy::new(plan3());
        p.decide(0.0, 20); // -> medium
        p.decide(1.0, 20); // -> fast
        assert_eq!(p.current(), 0);
        // Open a downscale window…
        for i in 0..40 {
            p.decide(10.0 + i as f64, 0);
            if p.low_since_ms.is_some() {
                break;
            }
        }
        assert!(p.low_since_ms.is_some());
        // …then install a re-derived plan that blocks the medium rung
        // (upscale 0, fast loses its downscale threshold).
        let mut replanned = plan3();
        replanned.ladder[1].upscale_threshold = 0;
        replanned.ladder[1].downscale_threshold = None;
        replanned.ladder[0].downscale_threshold = None;
        assert!(p.replace_plan(replanned));
        assert_eq!(p.current(), 0, "replacing the plan does not itself switch");
        assert_eq!(p.low_since_ms, None, "open window reset: its basis changed");
        // The blocked rung is now unreachable: sustained idle at fast
        // no longer downscales.
        assert_eq!(drive(&mut p, 100.0, 30_000.0, 20.0, 0), 0);
    }

    #[test]
    fn band_at_most_accurate_rung_tolerates_low_depth() {
        // At the most-accurate rung no downscale exists: the band starts
        // at depth 0 and is capped by the upscale threshold.
        let p = ElasticoPolicy::new(plan3());
        assert_eq!(p.current(), 2);
        assert_eq!(p.no_switch_band(), Some((0, 1)));
    }
}
