//! The workflow executor side of the serving system.
//!
//! A [`RequestEngine`] executes one request under a ladder rung. The
//! production engine ([`WorkflowEngine`]) resolves the rung to its
//! configuration and drives a live [`Workflow`] over PJRT; [`MockEngine`]
//! replays scripted service times for tests and harness benchmarks.

use anyhow::Result;

use crate::configspace::ConfigSpace;
use crate::planner::Plan;
use crate::workflows::{ExecOutcome, Workflow};

/// Executes one request under ladder rung `idx`.
pub trait RequestEngine {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome>;

    /// Rungs available (= plan ladder length).
    fn rungs(&self) -> usize;
}

/// Production engine: plan rung -> configuration -> live workflow.
pub struct WorkflowEngine<W: Workflow> {
    workflow: W,
    space: ConfigSpace,
    plan: Plan,
}

impl<W: Workflow> WorkflowEngine<W> {
    pub fn new(workflow: W, space: ConfigSpace, plan: Plan) -> Self {
        WorkflowEngine { workflow, space, plan }
    }
}

impl<W: Workflow> RequestEngine for WorkflowEngine<W> {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome> {
        let cfg = &self.plan.ladder[idx].config;
        self.workflow.run(&self.space, cfg)
    }

    fn rungs(&self) -> usize {
        self.plan.ladder.len()
    }
}

/// Scripted engine for tests: per-rung busy-wait service times.
pub struct MockEngine {
    /// Service time per rung (ms).
    pub service_ms: Vec<f64>,
    /// Expected accuracy per rung.
    pub accuracy: Vec<f64>,
}

impl RequestEngine for MockEngine {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome> {
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs_f64(self.service_ms[idx] / 1e3);
        // Busy-wait: emulates CPU-bound inference (sleep would free the
        // core and understate contention).
        while std::time::Instant::now() < deadline {
            std::hint::spin_loop();
        }
        Ok(ExecOutcome { accuracy: self.accuracy[idx], success: None })
    }

    fn rungs(&self) -> usize {
        self.service_ms.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_engine_takes_time() {
        let mut e = MockEngine { service_ms: vec![5.0, 20.0], accuracy: vec![0.7, 0.9] };
        let t0 = std::time::Instant::now();
        let out = e.execute(0).unwrap();
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        assert!(dt >= 4.5, "{dt}");
        assert_eq!(out.accuracy, 0.7);
        assert_eq!(e.rungs(), 2);
    }
}
