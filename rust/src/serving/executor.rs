//! The workflow executor side of the serving system.
//!
//! A [`RequestEngine`] executes requests under a ladder rung, one at a
//! time ([`RequestEngine::execute`]) or as a batch
//! ([`RequestEngine::execute_batch`]) so the per-dispatch fixed costs —
//! rung resolution, engine call setup — are paid once for `n` requests.
//! The production engine ([`WorkflowEngine`]) resolves the rung to its
//! configuration and drives a live [`Workflow`] over PJRT; [`MockEngine`]
//! replays scripted service times for tests and harness benchmarks, with
//! an explicit per-batch fixed cost + per-item marginal cost model
//! (`s̄(B) = α + β·B`) so batching experiments have a ground truth.

use anyhow::Result;

use crate::configspace::ConfigSpace;
use crate::planner::Plan;
use crate::workflows::{ExecOutcome, Workflow};

/// Executes requests under ladder rung `idx`.
///
/// On a heterogeneous fleet the serving loop resolves `idx` *per pool*
/// before calling in: each worker receives the policy rung clamped into
/// its pool's rung band ([`crate::serving::pool::pool_rung`]), so an
/// engine built for an accelerator pool only ever sees its own band's
/// rungs — `idx` is always in `[0, rungs())` regardless of the policy's
/// ladder position. Pool-specific engines are built by handing
/// [`crate::serving::serve_pools`] a factory over the worker's
/// [`crate::serving::PoolSpec`] (e.g. scale a mock's service times by
/// `speed_factor`).
pub trait RequestEngine {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome>;

    /// Execute `n` requests under rung `idx` in one dispatch, returning
    /// one outcome per request (in order). The default pays the full
    /// per-request dispatch cost `n` times (a loop over
    /// [`execute`](RequestEngine::execute)); engines with a real
    /// amortized path override this.
    fn execute_batch(&mut self, idx: usize, n: usize) -> Result<Vec<ExecOutcome>> {
        (0..n.max(1)).map(|_| self.execute(idx)).collect()
    }

    /// Allocation-free [`execute_batch`](RequestEngine::execute_batch):
    /// `out` is cleared and refilled with one outcome per request (in
    /// order). The serving loop reuses one per-worker outcome buffer
    /// across dispatches, so a steady-state batch performs no per-batch
    /// heap allocation. The default delegates to `execute_batch` (an
    /// engine without an amortized path still allocates); the engines
    /// here override it to write straight into `out`.
    fn execute_batch_into(
        &mut self,
        idx: usize,
        n: usize,
        out: &mut Vec<ExecOutcome>,
    ) -> Result<()> {
        out.clear();
        out.extend(self.execute_batch(idx, n)?);
        Ok(())
    }

    /// Rungs available (= plan ladder length).
    fn rungs(&self) -> usize;
}

/// Production engine: plan rung -> configuration -> live workflow.
pub struct WorkflowEngine<W: Workflow> {
    workflow: W,
    space: ConfigSpace,
    plan: Plan,
}

impl<W: Workflow> WorkflowEngine<W> {
    pub fn new(workflow: W, space: ConfigSpace, plan: Plan) -> Self {
        WorkflowEngine { workflow, space, plan }
    }
}

impl<W: Workflow> RequestEngine for WorkflowEngine<W> {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome> {
        let cfg = &self.plan.ladder[idx].config;
        self.workflow.run(&self.space, cfg)
    }

    /// Amortized path: the rung is resolved to its configuration once
    /// per batch, and the workflow runs back-to-back against the same
    /// resolved config — the per-batch fixed cost is the resolution +
    /// dispatch setup; the per-item marginal cost is the workflow
    /// compute itself. (True multi-request PJRT batching lands with the
    /// real `xla` backend; the offline stub executes per item.)
    fn execute_batch(&mut self, idx: usize, n: usize) -> Result<Vec<ExecOutcome>> {
        let mut outs = Vec::with_capacity(n.max(1));
        self.execute_batch_into(idx, n, &mut outs)?;
        Ok(outs)
    }

    fn execute_batch_into(
        &mut self,
        idx: usize,
        n: usize,
        out: &mut Vec<ExecOutcome>,
    ) -> Result<()> {
        let cfg = &self.plan.ladder[idx].config;
        out.clear();
        for _ in 0..n.max(1) {
            out.push(self.workflow.run(&self.space, cfg)?);
        }
        Ok(())
    }

    fn rungs(&self) -> usize {
        self.plan.ladder.len()
    }
}

/// Scripted engine for tests: per-rung busy-wait service times with an
/// explicit batch cost model `s̄(B) = α + β·B`, where `α` =
/// [`dispatch_ms`](MockEngine::dispatch_ms) is the per-batch fixed cost
/// and `β = service_ms - dispatch_ms` the per-item marginal cost.
/// `execute` (and any batch at `dispatch_ms = 0`) reproduces the seed
/// behavior exactly: one request busy-waits `service_ms[idx]`.
pub struct MockEngine {
    /// Single-request service time per rung (ms) — `s̄(1) = α + β`.
    pub service_ms: Vec<f64>,
    /// Expected accuracy per rung.
    pub accuracy: Vec<f64>,
    /// Per-dispatch fixed cost `α` (ms), amortized across a batch.
    /// Clamped into `[0, service_ms[idx]]` at use.
    pub dispatch_ms: f64,
}

impl MockEngine {
    fn spin_ms(ms: f64) {
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs_f64(ms.max(0.0) / 1e3);
        // Busy-wait: emulates CPU-bound inference (sleep would free the
        // core and understate contention).
        while std::time::Instant::now() < deadline {
            std::hint::spin_loop();
        }
    }
}

impl RequestEngine for MockEngine {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome> {
        Self::spin_ms(self.service_ms[idx]);
        Ok(ExecOutcome { accuracy: self.accuracy[idx], success: None })
    }

    /// Batch of `n`: `α + n·β` — the fixed dispatch cost is paid once,
    /// each item adds its marginal cost. With `n = 1` this is exactly
    /// `service_ms[idx]`.
    fn execute_batch(&mut self, idx: usize, n: usize) -> Result<Vec<ExecOutcome>> {
        let mut outs = Vec::with_capacity(n.max(1));
        self.execute_batch_into(idx, n, &mut outs)?;
        Ok(outs)
    }

    fn execute_batch_into(
        &mut self,
        idx: usize,
        n: usize,
        out: &mut Vec<ExecOutcome>,
    ) -> Result<()> {
        let n = n.max(1);
        let s1 = self.service_ms[idx];
        let alpha = self.dispatch_ms.clamp(0.0, s1);
        let beta = s1 - alpha;
        Self::spin_ms(alpha + n as f64 * beta);
        out.clear();
        out.resize(n, ExecOutcome { accuracy: self.accuracy[idx], success: None });
        Ok(())
    }

    fn rungs(&self) -> usize {
        self.service_ms.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_engine_takes_time() {
        let mut e = MockEngine {
            service_ms: vec![5.0, 20.0],
            accuracy: vec![0.7, 0.9],
            dispatch_ms: 0.0,
        };
        let t0 = std::time::Instant::now();
        let out = e.execute(0).unwrap();
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        assert!(dt >= 4.5, "{dt}");
        assert_eq!(out.accuracy, 0.7);
        assert_eq!(e.rungs(), 2);
    }

    #[test]
    fn mock_engine_batch_amortizes_dispatch() {
        // s̄(1) = 20 ms with α = 16 ms fixed: a batch of 4 costs
        // 16 + 4·4 = 32 ms, not 80 ms — and returns 4 outcomes. The
        // upper bound leaves ~28 ms of headroom for CI scheduler noise.
        let mut e = MockEngine {
            service_ms: vec![20.0],
            accuracy: vec![0.7],
            dispatch_ms: 16.0,
        };
        let t0 = std::time::Instant::now();
        let outs = e.execute_batch(0, 4).unwrap();
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(outs.len(), 4);
        assert!(dt >= 30.0, "batch should cost ~32 ms, took {dt}");
        assert!(dt < 60.0, "batch should amortize dispatch, took {dt}");
    }

    #[test]
    fn mock_engine_batch_into_refills_the_callers_buffer() {
        let mut e = MockEngine {
            service_ms: vec![0.0],
            accuracy: vec![0.8],
            dispatch_ms: 0.0,
        };
        let mut outs = Vec::with_capacity(8);
        e.execute_batch_into(0, 4, &mut outs).unwrap();
        assert_eq!(outs.len(), 4);
        assert_eq!(outs[0].accuracy, 0.8);
        let ptr = outs.as_ptr();
        e.execute_batch_into(0, 2, &mut outs).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs.as_ptr(), ptr, "outcome scratch reused, not reallocated");
    }

    #[test]
    fn mock_engine_batch_of_one_is_execute() {
        let mut e = MockEngine {
            service_ms: vec![3.0],
            accuracy: vec![0.8],
            dispatch_ms: 2.0,
        };
        let t0 = std::time::Instant::now();
        let outs = e.execute_batch(0, 1).unwrap();
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(outs.len(), 1);
        assert!(dt >= 2.5, "B=1 batch must cost the full s̄(1), took {dt}");
    }
}
