//! Online re-planning — closing the adaptation loop from live signals.
//!
//! The static plan ([`crate::planner::derive_plan_pools`]) bakes three
//! beliefs into its thresholds: the per-rung service profile measured
//! offline, each pool's `speed_factor`, and an assumed operating
//! utilization ρ̂. When the serving regime *drifts* — hardware degrades,
//! a model server slows down, load shifts — those beliefs go stale and
//! the AQM keeps steering by a map of a road that moved
//! ([`crate::workload::fault::Fault::Drift`] injects exactly this).
//!
//! The [`ReplanEngine`] re-estimates the beliefs online and re-derives
//! the plan against them:
//!
//! 1. **ρ̂** — the fleet utilization estimate: the [`super::monitor::
//!    LoadMonitor`]'s smoothed arrival rate over the fleet's believed
//!    drain capacity at the current rung;
//! 2. **speed / α** — per-pool hardware speed and the per-dispatch
//!    batch cost, fit from live batch completions `(n, batch_ms)` with
//!    the same OLS the offline profiler uses
//!    ([`BatchServiceModel::fit`]): under the executor's batch law
//!    `batch_ms ≈ n·(mean·S − α) + α`, the fit's `alpha + beta` per
//!    rung estimates `mean·S`, so `S = (alpha+beta)/mean_ref`;
//! 3. **thresholds** — [`derive_plan_pools`] re-run under the estimated
//!    speeds and ρ̂ (Erlang-C mode), merged back onto the full ladder
//!    (a rung the drifted beliefs make infeasible becomes escape-only:
//!    `N↑ = 0`, and its faster neighbour loses its downscale threshold
//!    so the policy cannot re-enter it) and swapped into the policy via
//!    [`ScalingPolicy::replace_plan`](crate::serving::policy::
//!    ScalingPolicy::replace_plan);
//! 4. **batch / spill margin** — the dispatch bound adapts to backlog
//!    (`B = depth.clamp(1, b_max)`) and the spill margin ramps up as ρ̂
//!    saturates past `rho_hi` (under saturation cross-pool poaching
//!    thrashes; keeping workers home is worth more).
//!
//! Two hysteresis guards keep the loop from flapping: evaluations run at
//! most once per `interval_ms`, and a re-derivation is installed only
//! when some pool's estimated speed moved at least `min_change`
//! relative to the speeds underlying the installed plan (adaptive batch
//! uses the same relative-change guard).
//!
//! **Reality vs. belief**: the re-planner only updates *beliefs* —
//! policy thresholds, the batch bound, the spill margin. It never
//! touches the executors' service arithmetic or `Topology::speed`;
//! drifted hardware stays drifted, the controller just stops pretending
//! otherwise.
//!
//! Disabled (the default) the executors skip every re-planning branch
//! and are bit-identical to the pre-replan engines (pinned by
//! `tests/replan.rs`).

use std::collections::VecDeque;

use anyhow::Result;

use crate::planner::profiler::{BatchServiceModel, LatencyProfile};
use crate::planner::{derive_plan_pools, AqmParams, ConfigPolicy, Plan, ProfiledConfig, ThresholdMode};
use crate::serving::pool::{pool_rung, PoolSpec};
use crate::util::stats::Ewma;

/// Online re-planning configuration. `Default` is **disabled**: the
/// executors skip every re-planning branch (no monitor, no fitting, no
/// plan swaps) and are bit-identical to the pre-replan engines.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplanConfig {
    /// Master switch; everything below is inert when false.
    pub enabled: bool,
    /// Minimum time between re-plan evaluations (ms) — the outer
    /// hysteresis guard.
    pub interval_ms: f64,
    /// Rate-estimator tick cadence (ms) for the DES's virtual
    /// [`super::monitor::LoadMonitor`] (the live runtime ticks at
    /// `ServeOptions::tick_ms` regardless).
    pub tick_ms: f64,
    /// Minimum relative change in an estimated pool speed (vs. the
    /// speeds underlying the installed plan) before a re-derivation is
    /// installed; also gates adaptive-batch moves.
    pub min_change: f64,
    /// Minimum completion samples a pool needs before its speed
    /// estimate updates (fewer = keep the prior belief).
    pub min_samples: usize,
    /// Adaptive batch cap `B_max`: each evaluation picks
    /// `B = depth.clamp(1, b_max)`. 0 (default) disables adaptive batch
    /// — the executor keeps its configured bound.
    pub b_max: usize,
    /// Fleet utilization ρ̂ above which the spill margin starts ramping.
    pub rho_hi: f64,
    /// Margin added on top of the topology's static spill margin at
    /// full saturation (ρ̂ ≥ 1); linear in between. 0 leaves the margin
    /// alone.
    pub margin_boost: f64,
    /// EWMA weight smoothing successive per-pool speed fits.
    pub speed_alpha: f64,
    /// Completion points retained per (pool, rung) fit buffer — the
    /// estimation window (smaller = faster convergence after a drift,
    /// noisier fits).
    pub window: usize,
    /// The base plan whose beliefs the engine retunes. The DES passes
    /// its plan argument implicitly; the **live** runtime has no plan in
    /// `ServeOptions`, so an enabled live config must attach one via
    /// [`with_plan`](ReplanConfig::with_plan). Never parsed/described.
    pub plan: Option<Plan>,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            enabled: false,
            interval_ms: 2000.0,
            tick_ms: 100.0,
            min_change: 0.15,
            min_samples: 20,
            b_max: 0,
            rho_hi: 0.8,
            margin_boost: 0.0,
            speed_alpha: 0.3,
            window: 64,
            plan: None,
        }
    }
}

impl ReplanConfig {
    /// Parse a CLI spec: `off` (or empty) keeps the disabled default;
    /// `on[,key=value,...]` enables with overrides. Keys: `interval_ms`,
    /// `tick_ms`, `min_change`, `min_samples`, `bmax`, `rho_hi`,
    /// `margin_boost`, `speed_alpha`, `window`. Unknown keys are errors,
    /// not silently ignored.
    pub fn parse(s: &str) -> Result<ReplanConfig> {
        let mut cfg = ReplanConfig::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part {
                "on" | "enabled" => cfg.enabled = true,
                "off" | "disabled" => cfg.enabled = false,
                _ => {
                    let Some((key, value)) = part.split_once('=') else {
                        anyhow::bail!("replan option {part:?} wants key=value");
                    };
                    let num = || -> Result<f64> {
                        value.parse().map_err(|_| {
                            anyhow::anyhow!("bad replan value {value:?} for {key:?}")
                        })
                    };
                    match key {
                        "interval_ms" => cfg.interval_ms = num()?.max(1.0),
                        "tick_ms" => cfg.tick_ms = num()?.max(1.0),
                        "min_change" => cfg.min_change = num()?.max(0.0),
                        "min_samples" => cfg.min_samples = num()?.max(1.0) as usize,
                        "bmax" => cfg.b_max = num()?.max(0.0) as usize,
                        "rho_hi" => cfg.rho_hi = num()?.clamp(0.0, 1.0),
                        "margin_boost" => cfg.margin_boost = num()?.max(0.0),
                        "speed_alpha" => cfg.speed_alpha = num()?.clamp(1e-6, 1.0),
                        "window" => cfg.window = num()?.max(2.0) as usize,
                        other => anyhow::bail!("unknown replan key {other:?}"),
                    }
                }
            }
        }
        Ok(cfg)
    }

    /// One-line rendering of the knobs (reports/CSV), inverse of
    /// [`parse`](ReplanConfig::parse) up to the attached plan.
    pub fn describe(&self) -> String {
        if !self.enabled {
            return "off".into();
        }
        format!(
            "on,interval_ms={},tick_ms={},min_change={},min_samples={},bmax={},rho_hi={},margin_boost={},speed_alpha={},window={}",
            self.interval_ms,
            self.tick_ms,
            self.min_change,
            self.min_samples,
            self.b_max,
            self.rho_hi,
            self.margin_boost,
            self.speed_alpha,
            self.window,
        )
    }

    /// Attach the base plan the live runtime retunes (the DES gets its
    /// plan as an argument and ignores this).
    pub fn with_plan(mut self, plan: Plan) -> ReplanConfig {
        self.plan = Some(plan);
        self
    }
}

/// One evaluation's verdict: the knobs the executor should run with
/// from now on. `plan` is `Some` only when the drift guard fired and a
/// re-derivation should be installed.
#[derive(Clone, Debug)]
pub struct ReplanUpdate {
    /// A re-derived full-ladder plan to swap into the policy, when the
    /// estimated speeds moved at least `min_change`.
    pub plan: Option<Plan>,
    /// The batch bound to dispatch with (unchanged unless `b_max > 0`).
    pub batch: usize,
    /// The effective spill margin (base margin + saturation ramp).
    pub spill_margin: f64,
    /// The fleet utilization estimate this evaluation computed.
    pub rho_hat: f64,
}

/// The online re-planner: pure estimation + derivation, driven by
/// either clock (the DES's virtual time or the live monitor thread).
/// Completions stream in via [`on_completion`](ReplanEngine::
/// on_completion); [`step`](ReplanEngine::step) gates on the evaluation
/// interval and returns the knobs to apply.
pub struct ReplanEngine {
    cfg: ReplanConfig,
    /// The base plan whose ladder shape (and belief fields) every
    /// re-derivation preserves.
    base: Plan,
    /// The executing topology's pools — the belief basis for speeds.
    pools: Vec<PoolSpec>,
    /// The topology's static spill margin (the ramp's floor).
    base_margin: f64,
    n_rungs: usize,
    /// Per-(pool, exec rung) completion windows of `(batch_n, batch_ms)`.
    points: Vec<VecDeque<(usize, f64)>>,
    /// Smoothed per-pool speed estimates (seeded by the first fit).
    speed_hat: Vec<Ewma>,
    /// The speeds underlying the currently installed plan — the
    /// reference the `min_change` drift guard compares against.
    applied_speed: Vec<f64>,
    /// Smoothed per-dispatch batch cost α estimate (ms), from fits with
    /// at least two distinct batch sizes.
    alpha_hat: Ewma,
    /// Next evaluation time (ms).
    next_eval_ms: f64,
    cur_batch: usize,
    /// Latest fleet utilization estimate.
    pub rho_hat: f64,
    /// Re-derivations proposed (a `ReplanUpdate` with `plan: Some`).
    pub replans: u64,
}

impl ReplanEngine {
    /// `batch` is the executor's configured dispatch bound (the
    /// adaptive-batch starting point); `base_margin` the topology's
    /// static spill margin.
    pub fn new(
        cfg: ReplanConfig,
        base: Plan,
        pools: Vec<PoolSpec>,
        batch: usize,
        base_margin: f64,
    ) -> ReplanEngine {
        let n_rungs = base.ladder.len();
        let n_pools = pools.len();
        let speed_alpha = cfg.speed_alpha;
        ReplanEngine {
            next_eval_ms: cfg.interval_ms,
            points: (0..n_pools * n_rungs).map(|_| VecDeque::new()).collect(),
            speed_hat: (0..n_pools).map(|_| Ewma::new(speed_alpha)).collect(),
            applied_speed: pools.iter().map(|p| p.speed_factor).collect(),
            alpha_hat: Ewma::new(speed_alpha),
            cur_batch: batch.max(1),
            rho_hat: 0.0,
            replans: 0,
            cfg,
            base,
            pools,
            base_margin,
            n_rungs,
        }
    }

    /// Record one batch completion: `n` requests executed at `rung` by
    /// `pool` in `batch_ms` wall milliseconds (queueing excluded). The
    /// per-(pool, rung) window is bounded; old points age out, which is
    /// what lets the fit follow a drift.
    pub fn on_completion(&mut self, pool: usize, rung: usize, n: usize, batch_ms: f64) {
        if pool >= self.pools.len() || rung >= self.n_rungs || n == 0 {
            return;
        }
        if !batch_ms.is_finite() || batch_ms < 0.0 {
            return;
        }
        let buf = &mut self.points[pool * self.n_rungs + rung];
        if buf.len() >= self.cfg.window {
            buf.pop_front();
        }
        buf.push_back((n, batch_ms));
    }

    /// The current belief about pool `p`'s speed factor: the smoothed
    /// fit when one exists, else the topology's static factor.
    pub fn speed_of(&self, p: usize) -> f64 {
        self.speed_hat[p]
            .get()
            .unwrap_or(self.pools[p].speed_factor)
    }

    /// Run one evaluation if the interval elapsed. `rate_qps` is the
    /// monitor's smoothed arrival rate, `depth` the fleet's queued
    /// backlog, `rung` the current policy rung (capacity is computed at
    /// the rung each pool would execute for it). Returns `None` between
    /// evaluations.
    pub fn step(
        &mut self,
        now_ms: f64,
        rate_qps: f64,
        depth: usize,
        rung: usize,
    ) -> Option<ReplanUpdate> {
        if !self.cfg.enabled || now_ms < self.next_eval_ms {
            return None;
        }
        self.next_eval_ms = now_ms + self.cfg.interval_ms;

        // 1. Fit per-pool speed (and α) from the completion windows.
        for p in 0..self.pools.len() {
            let mut weighted = 0.0;
            let mut weight = 0.0;
            let mut samples = 0usize;
            for r in 0..self.n_rungs {
                let buf = &self.points[p * self.n_rungs + r];
                if buf.is_empty() {
                    continue;
                }
                let mean_ref = self.base.ladder[r].mean_ms;
                if mean_ref <= 0.0 {
                    continue;
                }
                let pts: Vec<(usize, f64)> = buf.iter().copied().collect();
                let distinct = {
                    let mut sizes: Vec<usize> = pts.iter().map(|q| q.0).collect();
                    sizes.sort_unstable();
                    sizes.dedup();
                    sizes.len()
                };
                let fit = BatchServiceModel::fit(&pts);
                // batch_ms ≈ n·(mean·S − α) + α, so alpha+beta ≈ mean·S.
                let s = (fit.alpha_ms + fit.beta_ms) / mean_ref;
                if s.is_finite() && s > 0.0 {
                    weighted += s * pts.len() as f64;
                    weight += pts.len() as f64;
                    samples += pts.len();
                }
                if distinct >= 2 {
                    self.alpha_hat.push(fit.alpha_ms);
                }
            }
            if samples >= self.cfg.min_samples && weight > 0.0 {
                self.speed_hat[p].push(weighted / weight);
            }
        }
        let speeds: Vec<f64> = (0..self.pools.len()).map(|p| self.speed_of(p)).collect();

        // 2. Fleet utilization ρ̂ = rate / believed drain capacity at
        // the current rung (each pool executes its band-clamped rung).
        let mut capacity = 0.0;
        for (p, spec) in self.pools.iter().enumerate() {
            let r = pool_rung(&self.pools, p, rung, self.n_rungs);
            let mean = self.base.ladder[r].mean_ms * speeds[p];
            capacity += spec.workers.max(1) as f64 * 1000.0 / mean.max(1e-9);
        }
        self.rho_hat = rate_qps / capacity.max(1e-9);

        // 3. Adaptive batch: B tracks the backlog up to the cap, moving
        // only past the relative-change guard (so a one-request jitter
        // never re-tunes the dispatch path).
        if self.cfg.b_max > 0 {
            let want = depth.clamp(1, self.cfg.b_max);
            let rel = (want as f64 - self.cur_batch as f64).abs() / self.cur_batch.max(1) as f64;
            if rel >= self.cfg.min_change {
                self.cur_batch = want;
            }
        }

        // 4. Spill margin ramp: linear from the base margin at
        // ρ̂ = rho_hi to base + boost at ρ̂ ≥ 1.
        let sat = ((self.rho_hat - self.cfg.rho_hi) / (1.0 - self.cfg.rho_hi).max(1e-9))
            .clamp(0.0, 1.0);
        let margin = self.base_margin + self.cfg.margin_boost * sat;

        // 5. Re-derive only when the speed beliefs actually drifted.
        let drifted = (0..self.pools.len()).any(|p| {
            (speeds[p] - self.applied_speed[p]).abs() / self.applied_speed[p].max(1e-9)
                >= self.cfg.min_change
        });
        let plan = if drifted {
            self.applied_speed = speeds.clone();
            self.replans += 1;
            Some(self.derive(&speeds))
        } else {
            None
        };
        Some(ReplanUpdate {
            plan,
            batch: self.cur_batch,
            spill_margin: margin,
            rho_hat: self.rho_hat,
        })
    }

    /// Re-run the AQM derivation against the estimated speeds and ρ̂,
    /// then merge the (possibly shorter) derived ladder back onto the
    /// base ladder shape — [`ScalingPolicy::replace_plan`](crate::
    /// serving::policy::ScalingPolicy::replace_plan) requires the same
    /// rung count, and Elastico steps ±1, so a dropped (infeasible)
    /// rung becomes escape-only: its own `N↑ = 0` pushes any backlog
    /// off it, and its faster neighbour loses `N↓` so the policy cannot
    /// step back into it.
    fn derive(&self, speeds: &[f64]) -> Plan {
        let front: Vec<ProfiledConfig> = self
            .base
            .ladder
            .iter()
            .map(|c| ProfiledConfig {
                config: c.config.clone(),
                label: c.label.clone(),
                accuracy: c.accuracy,
                latency: LatencyProfile {
                    mean_ms: c.mean_ms,
                    p50_ms: c.mean_ms,
                    p95_ms: c.p95_ms,
                    runs: 1,
                },
            })
            .collect();
        let est_pools: Vec<PoolSpec> = self
            .pools
            .iter()
            .zip(speeds)
            .map(|(p, &s)| PoolSpec { speed_factor: s, ..p.clone() })
            .collect();
        let params = AqmParams {
            slo_ms: self.base.slo_ms,
            slack_buffer_ms: self.base.slack_buffer_ms,
            up_cooldown_ms: self.base.up_cooldown_ms,
            down_cooldown_ms: self.base.down_cooldown_ms,
            workers: self.base.workers.max(1),
            batch: self.cur_batch,
            batch_alpha_ms: self.alpha_hat.get().unwrap_or(self.base.batch_alpha_ms),
            thresholds: ThresholdMode::ErlangC,
            target_rho: self.rho_hat.clamp(0.05, 0.95),
        };
        let derived = derive_plan_pools(&front, params, &est_pools);

        // Ladder-length-preserving merge by label.
        let mut ladder: Vec<ConfigPolicy> = self
            .base
            .ladder
            .iter()
            .map(|c| match derived.ladder.iter().find(|d| d.label == c.label) {
                Some(d) => d.clone(),
                None => ConfigPolicy {
                    upscale_threshold: 0,
                    downscale_threshold: None,
                    queue_slack_ms: 0.0,
                    ..c.clone()
                },
            })
            .collect();
        for k in 0..ladder.len() {
            let infeasible =
                !derived.ladder.iter().any(|d| d.label == self.base.ladder[k].label);
            if infeasible && k > 0 {
                ladder[k - 1].downscale_threshold = None;
            }
        }
        Plan {
            slo_ms: derived.slo_ms,
            slack_buffer_ms: derived.slack_buffer_ms,
            up_cooldown_ms: derived.up_cooldown_ms,
            down_cooldown_ms: derived.down_cooldown_ms,
            workers: derived.workers,
            batch: derived.batch,
            batch_alpha_ms: derived.batch_alpha_ms,
            pools: est_pools,
            ladder,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{derive_plan, AqmParams, LatencyProfile, ProfiledConfig};

    fn front2() -> Vec<ProfiledConfig> {
        let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
            config: vec![],
            label: label.into(),
            accuracy: acc,
            latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
        };
        vec![mk("fast", 0.76, 20.0, 28.0), mk("accurate", 0.85, 90.0, 120.0)]
    }

    fn base_plan() -> Plan {
        derive_plan(&front2(), AqmParams::for_slo_workers(300.0, 2))
    }

    fn engine(cfg: ReplanConfig) -> ReplanEngine {
        ReplanEngine::new(cfg, base_plan(), vec![PoolSpec::uniform(2)], 1, 0.0)
    }

    fn on() -> ReplanConfig {
        ReplanConfig { enabled: true, ..ReplanConfig::default() }
    }

    #[test]
    fn disabled_config_is_inert() {
        let cfg = ReplanConfig::default();
        assert!(!cfg.enabled);
        let mut e = engine(cfg);
        for i in 0..100 {
            e.on_completion(0, 1, 1, 95.0);
            assert!(e.step(i as f64 * 1000.0, 10.0, 3, 1).is_none());
        }
        assert_eq!(e.replans, 0);
    }

    #[test]
    fn parse_roundtrips_the_knobs() {
        assert_eq!(ReplanConfig::parse("").unwrap(), ReplanConfig::default());
        assert_eq!(ReplanConfig::parse("off").unwrap(), ReplanConfig::default());
        let cfg = ReplanConfig::parse(
            "on,interval_ms=500,tick_ms=50,min_change=0.2,min_samples=8,bmax=16,rho_hi=0.7,margin_boost=2,speed_alpha=0.5,window=32",
        )
        .unwrap();
        assert!(cfg.enabled);
        assert_eq!(cfg.interval_ms, 500.0);
        assert_eq!(cfg.tick_ms, 50.0);
        assert_eq!(cfg.min_change, 0.2);
        assert_eq!(cfg.min_samples, 8);
        assert_eq!(cfg.b_max, 16);
        assert_eq!(cfg.rho_hi, 0.7);
        assert_eq!(cfg.margin_boost, 2.0);
        assert_eq!(cfg.speed_alpha, 0.5);
        assert_eq!(cfg.window, 32);
        // describe() is parse()'s inverse for an enabled config.
        assert_eq!(ReplanConfig::parse(&cfg.describe()).unwrap(), cfg);
        assert_eq!(ReplanConfig::default().describe(), "off");
        assert!(ReplanConfig::parse("on,bogus=1").is_err());
        assert!(ReplanConfig::parse("on,interval_ms").is_err());
    }

    #[test]
    fn steady_completions_keep_the_plan() {
        // Completions matching the base beliefs (speed 1): no drift, no
        // re-derivation — only the periodic knob refresh.
        let mut e = engine(ReplanConfig { min_samples: 10, ..on() });
        let mut now = 0.0;
        for _ in 0..5 {
            for _ in 0..20 {
                e.on_completion(0, 1, 1, 90.0);
            }
            now += 2000.0;
            let upd = e.step(now, 10.0, 2, 1).expect("interval elapsed");
            assert!(upd.plan.is_none(), "no drift, no plan swap");
            assert_eq!(upd.batch, 1);
            assert_eq!(upd.spill_margin, 0.0);
        }
        assert_eq!(e.replans, 0);
        assert!((e.speed_of(0) - 1.0).abs() < 0.05, "speed {}", e.speed_of(0));
    }

    #[test]
    fn drifted_completions_trigger_a_rederivation_that_blocks_the_rung() {
        // Service times 4x the profile: the accurate rung's inflated
        // p95 (480 ms) blows the 300 ms SLO — the re-derived ladder
        // must make it escape-only and block re-entry from fast.
        let mut e = engine(ReplanConfig { min_samples: 10, ..on() });
        let mut now = 0.0;
        let mut swapped = None;
        for _ in 0..8 {
            for _ in 0..20 {
                e.on_completion(0, 1, 1, 360.0); // 90 ms rung at 4x
            }
            now += 2000.0;
            if let Some(upd) = e.step(now, 8.0, 3, 1) {
                if let Some(p) = upd.plan {
                    swapped = Some(p);
                }
            }
        }
        let plan = swapped.expect("a 4x drift must trigger a re-derivation");
        assert!(e.speed_of(0) > 2.0, "fitted speed {}", e.speed_of(0));
        assert_eq!(plan.ladder.len(), 2, "ladder shape preserved");
        assert_eq!(plan.ladder[1].upscale_threshold, 0, "infeasible rung escapes");
        assert_eq!(plan.ladder[1].downscale_threshold, None);
        assert_eq!(
            plan.ladder[0].downscale_threshold, None,
            "re-entry into the infeasible rung is blocked"
        );
        assert!(e.replans >= 1);
        // ρ̂ reflects the drifted capacity: 2 workers at ~360 ms ≈
        // 5.6 qps against 8 qps offered — saturated.
        assert!(e.rho_hat > 1.0, "rho_hat {}", e.rho_hat);
    }

    #[test]
    fn interval_and_min_change_hysteresis_hold() {
        let mut e = engine(ReplanConfig { min_samples: 5, ..on() });
        // Before the first interval elapses: no evaluation at all.
        assert!(e.step(100.0, 10.0, 1, 1).is_none());
        assert!(e.step(1999.0, 10.0, 1, 1).is_none());
        // A drift below min_change (10% < 15%) evaluates but keeps the
        // plan.
        for _ in 0..30 {
            e.on_completion(0, 1, 1, 99.0); // 1.1x
        }
        let upd = e.step(2000.0, 10.0, 1, 1).expect("interval elapsed");
        assert!(upd.plan.is_none(), "sub-threshold drift must not re-plan");
        // Immediately after an evaluation the next one is gated again.
        assert!(e.step(2001.0, 10.0, 1, 1).is_none());
    }

    #[test]
    fn adaptive_batch_tracks_depth_and_margin_ramps_with_rho() {
        let mut e = ReplanEngine::new(
            ReplanConfig { b_max: 8, margin_boost: 3.0, rho_hi: 0.5, min_samples: 5, ..on() },
            base_plan(),
            vec![PoolSpec::uniform(2)],
            1,
            1.0,
        );
        for _ in 0..10 {
            e.on_completion(0, 1, 1, 90.0);
        }
        // Deep backlog: B rises to the cap; light load: B falls back.
        let upd = e.step(2000.0, 40.0, 50, 1).unwrap();
        assert_eq!(upd.batch, 8);
        // 40 qps against ~22 qps capacity: saturated, margin at full
        // boost above the base margin of 1.
        assert!(upd.rho_hat > 1.0);
        assert_eq!(upd.spill_margin, 4.0);
        let upd = e.step(4000.0, 2.0, 1, 1).unwrap();
        assert_eq!(upd.batch, 1);
        assert_eq!(upd.spill_margin, 1.0, "relaxed load restores the base margin");
    }

    #[test]
    fn batched_completions_recover_alpha() {
        // Batches obeying batch_ms = n·(mean·S − α) + α with α = 30,
        // S = 1: the fit should recover α and a speed near 1.
        let mut e = engine(ReplanConfig { min_samples: 6, ..on() });
        for n in [1usize, 4, 8, 1, 4, 8, 1, 4, 8] {
            let ms = n as f64 * (90.0 - 30.0) + 30.0;
            e.on_completion(0, 1, n, ms);
        }
        e.step(2000.0, 5.0, 1, 1).unwrap();
        assert!((e.speed_of(0) - 1.0).abs() < 0.05, "speed {}", e.speed_of(0));
        let alpha = e.alpha_hat.get().unwrap();
        assert!((alpha - 30.0).abs() < 1.0, "alpha {alpha}");
    }

    #[test]
    fn out_of_range_completions_are_ignored() {
        let mut e = engine(on());
        e.on_completion(9, 0, 1, 10.0); // unknown pool
        e.on_completion(0, 9, 1, 10.0); // unknown rung
        e.on_completion(0, 0, 0, 10.0); // empty batch
        e.on_completion(0, 0, 1, f64::NAN); // junk timing
        e.on_completion(0, 0, 1, -5.0);
        assert!(e.points.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn window_bounds_the_fit_buffer() {
        let mut e = engine(ReplanConfig { window: 4, ..on() });
        for i in 0..10 {
            e.on_completion(0, 0, 1, 20.0 + i as f64);
        }
        assert_eq!(e.points[0].len(), 4);
        assert_eq!(e.points[0].front().unwrap().1, 26.0, "oldest points age out");
    }
}
