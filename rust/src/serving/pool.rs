//! Named worker pools — the heterogeneous-fleet topology of the serving
//! runtime (and of the DES that mirrors it).
//!
//! Real fixed fleets are rarely uniform: production deployments mix fast
//! CPU workers with slower, more accurate accelerator workers. A
//! [`PoolSpec`] names one such pool and carries
//!
//! * `workers` — executor threads (live) / servers (DES) in the pool;
//! * `engine_rung_offset` — the first ladder rung of the pool's **rung
//!   band**: pools partition the Pareto ladder into contiguous bands
//!   (pool `p` owns rungs `[offset_p, offset_{p+1})`, the last band
//!   running to the end of the ladder), and a pool always executes
//!   within its own band ([`pool_rung`] clamps the policy rung into it);
//! * `speed_factor` — service-time multiplier relative to the reference
//!   hardware the ladder was profiled on (`2.5` = this pool runs every
//!   rung 2.5x slower). The DES scales its sampled service times by it
//!   and the Planner scales the pool's rungs when deriving per-pool AQM
//!   thresholds; on the live path it is advisory (real compute cannot be
//!   rescaled) but is handed to the engine factory so harnesses can
//!   build pool-appropriate engines.
//!
//! **Rung-aware routing**: an arrival routes to the pool whose band
//! contains the current policy rung ([`pool_of_rung`]) and round-robins
//! over that pool's shards; when the policy switches rungs across a band
//! boundary, new load moves *between pools* instead of only up/down one
//! shared ladder. Work stealing stays within a pool; a pool's workers
//! spill into other pools' shards only once every shard of their own
//! pool is dry (see [`crate::serving::queue::ShardedQueue`]).
//!
//! A single [`PoolSpec::uniform`] pool (`speed_factor = 1`, offset 0) is
//! the homogeneous k-worker runtime exactly: every rung maps to pool 0,
//! the band clamp is the identity, and no spill path exists — pinned
//! record-for-record against the sharded k-worker DES by
//! `sim::tests::pooled_single_uniform_pool_reproduces_sharded_des_exactly`.

use anyhow::{bail, Result};

/// One named worker pool of the serving fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolSpec {
    /// Display name (reports, CSV headers, CLI).
    pub name: String,
    /// Executor threads (live) / servers (DES) in this pool.
    pub workers: usize,
    /// First ladder rung of this pool's band (see the module docs).
    pub engine_rung_offset: usize,
    /// Service-time multiplier vs the profiled reference hardware
    /// (1.0 = reference speed, 2.5 = 2.5x slower per request).
    pub speed_factor: f64,
}

impl PoolSpec {
    pub fn new(
        name: impl Into<String>,
        workers: usize,
        engine_rung_offset: usize,
        speed_factor: f64,
    ) -> PoolSpec {
        PoolSpec {
            name: name.into(),
            workers: workers.max(1),
            engine_rung_offset,
            speed_factor,
        }
    }

    /// The homogeneous topology: one reference-speed pool owning the
    /// whole ladder — exactly the pre-pool k-worker runtime.
    pub fn uniform(workers: usize) -> PoolSpec {
        PoolSpec::new("all", workers, 0, 1.0)
    }

    /// Reference-speed, whole-ladder pool (offset 0, speed 1)?
    pub fn is_reference(&self) -> bool {
        self.engine_rung_offset == 0 && self.speed_factor == 1.0
    }
}

/// Validate a pool topology: non-empty, every pool ≥ 1 worker with a
/// positive speed factor, offsets strictly increasing from 0 (bands
/// partition the ladder).
pub fn validate_pools(pools: &[PoolSpec]) -> Result<()> {
    if pools.is_empty() {
        bail!("pool topology must name at least one pool");
    }
    if pools[0].engine_rung_offset != 0 {
        bail!(
            "first pool ({}) must start at rung offset 0, got {}",
            pools[0].name,
            pools[0].engine_rung_offset
        );
    }
    for (i, p) in pools.iter().enumerate() {
        if p.workers == 0 {
            bail!("pool {} has no workers", p.name);
        }
        if p.speed_factor.is_nan() || p.speed_factor <= 0.0 {
            bail!("pool {} has non-positive speed factor {}", p.name, p.speed_factor);
        }
        if i > 0 && p.engine_rung_offset <= pools[i - 1].engine_rung_offset {
            bail!(
                "pool rung offsets must be strictly increasing: {} ({}) after {} ({})",
                p.name,
                p.engine_rung_offset,
                pools[i - 1].name,
                pools[i - 1].engine_rung_offset
            );
        }
    }
    Ok(())
}

/// Total workers across the fleet.
pub fn total_workers(pools: &[PoolSpec]) -> usize {
    pools.iter().map(|p| p.workers.max(1)).sum::<usize>().max(1)
}

/// Aggregate service capacity relative to `workers` reference-speed
/// executors: `Σ workers_p / speed_p`. Used to scale experiment load so
/// the per-worker operating point is preserved on heterogeneous fleets.
pub fn capacity_factor(pools: &[PoolSpec]) -> f64 {
    pools
        .iter()
        .map(|p| p.workers.max(1) as f64 / p.speed_factor.max(1e-9))
        .sum()
}

/// The pool whose rung band contains `rung`: the last pool whose offset
/// is ≤ `rung` (offsets are strictly increasing from 0, so this is
/// always defined). Rung-aware routing sends new arrivals here.
pub fn pool_of_rung(pools: &[PoolSpec], rung: usize) -> usize {
    let mut owner = 0;
    for (i, p) in pools.iter().enumerate() {
        if p.engine_rung_offset <= rung {
            owner = i;
        }
    }
    owner
}

/// The rung pool `pool` executes when the policy sits at `policy_rung`
/// on a ladder of `n_rungs`: the policy rung clamped into the pool's
/// band. A pool resolves *its own* engine config — a spilled request
/// executes at the spilling pool's band, not the router's. With a single
/// whole-ladder pool this is the identity.
pub fn pool_rung(pools: &[PoolSpec], pool: usize, policy_rung: usize, n_rungs: usize) -> usize {
    let n = n_rungs.max(1);
    let lo = pools[pool].engine_rung_offset.min(n - 1);
    let hi = if pool + 1 < pools.len() {
        pools[pool + 1].engine_rung_offset.min(n)
    } else {
        n
    };
    let hi = hi.max(lo + 1); // bands clipped by a short ladder stay non-empty
    policy_rung.clamp(lo, hi - 1)
}

/// Parse a CLI pool topology: comma-separated
/// `name:workers:speed[:rung_offset]` entries, e.g.
/// `fast:4:1.0,accurate:2:2.5`. When the offset is omitted, pool `i`
/// starts its band at rung `i` (each extra pool one rung deeper —
/// sensible for the common fast-pool + accurate-pool split).
pub fn parse_pools(s: &str) -> Result<Vec<PoolSpec>> {
    let mut pools: Vec<PoolSpec> = Vec::new();
    for entry in s.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let i = pools.len();
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() < 3 || parts.len() > 4 {
            bail!(
                "pool spec `{entry}` must be name:workers:speed[:rung_offset]"
            );
        }
        let workers: usize = parts[1]
            .parse()
            .map_err(|_| anyhow::anyhow!("pool `{entry}`: bad worker count {}", parts[1]))?;
        if workers == 0 {
            bail!("pool `{entry}` has no workers");
        }
        let speed: f64 = parts[2]
            .parse()
            .map_err(|_| anyhow::anyhow!("pool `{entry}`: bad speed factor {}", parts[2]))?;
        let offset: usize = match parts.get(3) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("pool `{entry}`: bad rung offset {v}"))?,
            None => i,
        };
        pools.push(PoolSpec::new(parts[0], workers, offset, speed));
    }
    validate_pools(&pools)?;
    Ok(pools)
}

/// One-line display of a topology (`fast:4@1x+accurate:2@2.5x`).
pub fn describe_pools(pools: &[PoolSpec]) -> String {
    pools
        .iter()
        .map(|p| format!("{}:{}@{}x", p.name, p.workers, p.speed_factor))
        .collect::<Vec<_>>()
        .join("+")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_issue_example() {
        let pools = parse_pools("fast:4:1.0,accurate:2:2.5").unwrap();
        assert_eq!(pools.len(), 2);
        assert_eq!(pools[0], PoolSpec::new("fast", 4, 0, 1.0));
        assert_eq!(pools[1], PoolSpec::new("accurate", 2, 1, 2.5));
        assert_eq!(total_workers(&pools), 6);
        assert!((capacity_factor(&pools) - (4.0 + 2.0 / 2.5)).abs() < 1e-12);
    }

    #[test]
    fn parse_explicit_offsets_and_rejects_bad_specs() {
        let pools = parse_pools("cpu:2:1.0:0,tpu:1:3.0:2").unwrap();
        assert_eq!(pools[1].engine_rung_offset, 2);
        assert!(parse_pools("x:0:1.0").is_err(), "zero workers");
        assert!(parse_pools("x:2:0.0").is_err(), "zero speed");
        assert!(parse_pools("x:2:1.0:1").is_err(), "first offset must be 0");
        assert!(parse_pools("a:2:1.0,b:2:1.0:0").is_err(), "offsets must increase");
        assert!(parse_pools("justname").is_err(), "missing fields");
    }

    #[test]
    fn rung_bands_partition_the_ladder() {
        let pools = parse_pools("fast:4:1.0,mid:2:1.5:2,slow:1:3.0:4").unwrap();
        // Bands: fast [0,2), mid [2,4), slow [4,..).
        assert_eq!(pool_of_rung(&pools, 0), 0);
        assert_eq!(pool_of_rung(&pools, 1), 0);
        assert_eq!(pool_of_rung(&pools, 2), 1);
        assert_eq!(pool_of_rung(&pools, 3), 1);
        assert_eq!(pool_of_rung(&pools, 4), 2);
        assert_eq!(pool_of_rung(&pools, 9), 2);
        // Each pool clamps the policy rung into its own band.
        assert_eq!(pool_rung(&pools, 0, 5, 6), 1);
        assert_eq!(pool_rung(&pools, 1, 5, 6), 3);
        assert_eq!(pool_rung(&pools, 2, 0, 6), 4);
        assert_eq!(pool_rung(&pools, 2, 5, 6), 5);
    }

    #[test]
    fn uniform_pool_is_the_identity_topology() {
        let pools = vec![PoolSpec::uniform(4)];
        validate_pools(&pools).unwrap();
        assert!(pools[0].is_reference());
        for r in 0..8 {
            assert_eq!(pool_of_rung(&pools, r), 0);
            assert_eq!(pool_rung(&pools, 0, r, 8), r);
        }
        assert_eq!(total_workers(&pools), 4);
        assert!((capacity_factor(&pools) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn short_ladder_clips_bands_but_keeps_them_non_empty() {
        // A 1-rung ladder with a 2-pool topology: both pools execute
        // rung 0 and routing always targets the first pool.
        let pools = parse_pools("fast:2:1.0,slow:2:2.0").unwrap();
        assert_eq!(pool_of_rung(&pools, 0), 0);
        assert_eq!(pool_rung(&pools, 0, 0, 1), 0);
        assert_eq!(pool_rung(&pools, 1, 0, 1), 0);
    }
}
