//! The load monitor (paper §III-B): tracks queue depth and the arrival
//! rate (EWMA over tick windows). Queue depth is the AQM's control
//! signal; the arrival-rate estimate feeds reports and diagnostics.
//!
//! The arrival counter lives outside the mutex: `on_arrival` is one
//! relaxed atomic increment, so the injector's hot path never contends
//! with the tick thread — only the (periodic, off-path) `tick` takes
//! the EWMA lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Ewma;

/// Ticks closer together than this (ms) push no sample: a duplicate or
/// near-coincident tick would otherwise divide by a near-zero window
/// and inject an astronomically large instantaneous rate into the EWMA.
pub const MIN_TICK_DT_MS: f64 = 1.0;

/// Historical tick cadence (ms) assumed by [`LoadMonitor::new`] /
/// [`LoadMonitor::with_pools`]; callers with another cadence use
/// [`LoadMonitor::with_pools_period`].
pub const DEFAULT_TICK_MS: f64 = 100.0;

struct MonitorState {
    last_total: u64,
    /// `None` until the first tick: the first observed tick only opens
    /// the window (recording the clock and counter), pushing no sample
    /// — so a serve that starts at a non-zero wall offset never
    /// measures a bogus `[0, first_tick]` window.
    last_tick_ms: Option<f64>,
    rate_qps: Ewma,
}

/// Thread-safe load monitor; arrival recording is lock-free.
pub struct LoadMonitor {
    arrivals_total: AtomicU64,
    /// Per-pool arrival counters (empty on a single-pool monitor built
    /// with [`new`](LoadMonitor::new)); same lock-free discipline as the
    /// total, so rung-aware routing diagnostics cost one extra relaxed
    /// increment.
    pool_arrivals: Vec<AtomicU64>,
    /// EWMA smoothing factor at the nominal tick period.
    alpha: f64,
    /// Nominal tick period τ (ms): a tick covering `dt` is blended with
    /// the time-corrected weight `1 − (1 − α)^(dt/τ)`, so irregular
    /// tick spacing no longer biases the estimate. At `dt == τ` the
    /// weight is exactly `α` (bit-identical to the historical fixed-α
    /// update).
    nominal_tick_ms: f64,
    state: Mutex<MonitorState>,
}

impl LoadMonitor {
    pub fn new(alpha: f64) -> LoadMonitor {
        LoadMonitor::with_pools(alpha, 0)
    }

    /// A monitor that additionally tracks per-pool arrival counts for a
    /// `pools`-pool fleet, at the historical [`DEFAULT_TICK_MS`] cadence.
    pub fn with_pools(alpha: f64, pools: usize) -> LoadMonitor {
        LoadMonitor::with_pools_period(alpha, pools, DEFAULT_TICK_MS)
    }

    /// A pooled monitor whose nominal tick period is `nominal_tick_ms`
    /// (the cadence the caller intends to call [`tick`](Self::tick) at).
    pub fn with_pools_period(alpha: f64, pools: usize, nominal_tick_ms: f64) -> LoadMonitor {
        assert!(nominal_tick_ms > 0.0, "nominal tick period must be positive");
        LoadMonitor {
            arrivals_total: AtomicU64::new(0),
            pool_arrivals: (0..pools).map(|_| AtomicU64::new(0)).collect(),
            alpha,
            nominal_tick_ms,
            state: Mutex::new(MonitorState {
                last_total: 0,
                last_tick_ms: None,
                rate_qps: Ewma::new(alpha),
            }),
        }
    }

    /// Record one arrival (called by the injector): a plain atomic
    /// increment, no lock.
    pub fn on_arrival(&self) {
        self.arrivals_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one arrival routed to `pool` (lock-free; the pool counter
    /// is skipped when the monitor was not built with pools).
    pub fn on_arrival_pool(&self, pool: usize) {
        self.arrivals_total.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.pool_arrivals.get(pool) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Arrivals routed to `pool` so far (0 for unknown pools).
    pub fn pool_arrivals_total(&self, pool: usize) -> u64 {
        self.pool_arrivals
            .get(pool)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Tick the rate estimator; returns the EWMA arrival rate (qps).
    ///
    /// The first tick only opens the measurement window (no sample); a
    /// tick under [`MIN_TICK_DT_MS`] after the previous one returns the
    /// current estimate untouched, leaving the window open so its
    /// arrivals attribute to the next full window; otherwise the
    /// instantaneous rate over `dt` is blended with the time-corrected
    /// weight `1 − (1 − α)^(dt/τ)` — exactly `α` when `dt == τ`.
    pub fn tick(&self, now_ms: f64) -> f64 {
        let mut s = self.state.lock().unwrap();
        let total = self.arrivals_total.load(Ordering::Relaxed);
        let Some(last) = s.last_tick_ms else {
            s.last_total = total;
            s.last_tick_ms = Some(now_ms);
            return s.rate_qps.get().unwrap_or(0.0);
        };
        let dt = now_ms - last;
        if dt < MIN_TICK_DT_MS {
            return s.rate_qps.get().unwrap_or(0.0);
        }
        let newly = (total - s.last_total) as f64;
        s.last_total = total;
        s.last_tick_ms = Some(now_ms);
        let inst = newly / (dt / 1000.0);
        let w = if dt == self.nominal_tick_ms {
            self.alpha // float-exact pin at the nominal period
        } else {
            1.0 - (1.0 - self.alpha).powf(dt / self.nominal_tick_ms)
        };
        s.rate_qps.push_weighted(inst, w)
    }

    /// Latest smoothed arrival-rate estimate.
    pub fn rate_qps(&self) -> f64 {
        self.state.lock().unwrap().rate_qps.get().unwrap_or(0.0)
    }

    pub fn arrivals_total(&self) -> u64 {
        self.arrivals_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_steady_rate() {
        let m = LoadMonitor::new(0.3);
        // 10 arrivals per 100 ms tick = 100 qps.
        let mut now = 0.0;
        for _ in 0..50 {
            for _ in 0..10 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now);
        }
        let qps = m.rate_qps();
        assert!((qps - 100.0).abs() < 5.0, "qps {qps}");
        assert_eq!(m.arrivals_total(), 500);
    }

    #[test]
    fn pool_counters_split_the_total() {
        let m = LoadMonitor::with_pools(0.3, 2);
        for _ in 0..7 {
            m.on_arrival_pool(0);
        }
        for _ in 0..3 {
            m.on_arrival_pool(1);
        }
        assert_eq!(m.arrivals_total(), 10);
        assert_eq!(m.pool_arrivals_total(0), 7);
        assert_eq!(m.pool_arrivals_total(1), 3);
        assert_eq!(m.pool_arrivals_total(9), 0, "unknown pool reads 0");
        // A pool-less monitor still counts the total on the pooled path.
        let plain = LoadMonitor::new(0.3);
        plain.on_arrival_pool(0);
        assert_eq!(plain.arrivals_total(), 1);
        assert_eq!(plain.pool_arrivals_total(0), 0);
    }

    #[test]
    fn duplicate_tick_does_not_spike_the_estimate() {
        let m = LoadMonitor::new(0.3);
        let mut now = 0.0;
        for _ in 0..20 {
            for _ in 0..10 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now);
        }
        let before = m.rate_qps();
        assert!((before - 100.0).abs() < 5.0, "qps {before}");
        // A duplicate and a near-coincident tick: under the old
        // dt.max(1e-6) clamp these pushed ~1e9-qps samples; now they
        // must leave the estimate untouched.
        assert_eq!(m.tick(now), before, "exact duplicate tick is a no-op");
        m.on_arrival();
        assert_eq!(m.tick(now + 0.5), before, "sub-floor tick is a no-op");
        // The deferred arrival lands in the next full window instead of
        // being lost: 11 arrivals over the next 100 ms reads 110 qps.
        for _ in 0..10 {
            m.on_arrival();
        }
        let after = m.tick(now + 100.0);
        assert!(after > before && after < 120.0, "qps {after}");
    }

    #[test]
    fn first_tick_at_nonzero_offset_opens_the_window() {
        // Serve "starts" at t = 5000 ms: the old estimator measured the
        // bogus [0, 5000] window and smeared 10 arrivals over 5 s
        // (2 qps); the fixed one pushes no sample on the first tick.
        let m = LoadMonitor::new(0.3);
        for _ in 0..10 {
            m.on_arrival();
        }
        m.tick(5000.0);
        assert_eq!(m.rate_qps(), 0.0, "first tick seeds, no sample");
        // The first *real* window starts at the first tick.
        for _ in 0..10 {
            m.on_arrival();
        }
        let qps = m.tick(5100.0);
        assert!((qps - 100.0).abs() < 1e-9, "qps {qps}");
    }

    #[test]
    fn irregular_tick_spacing_is_time_corrected() {
        // Same 100-qps truth observed through regular 100 ms ticks and
        // through alternating 50/150 ms ticks: the time-corrected
        // weight keeps both estimates equal at equal elapsed time.
        let regular = LoadMonitor::new(0.3);
        let jittered = LoadMonitor::new(0.3);
        let mut now = 0.0;
        regular.tick(0.0);
        jittered.tick(0.0);
        for i in 0..40 {
            for _ in 0..10 {
                regular.on_arrival();
            }
            now += 100.0;
            regular.tick(now);
            // Jittered twin: a 50 ms window carrying 5 arrivals, then a
            // 150 ms window carrying 15, realigning with the regular
            // clock every 200 ms.
            let (a, t) = if i % 2 == 0 { (5, now - 50.0) } else { (15, now) };
            for _ in 0..a {
                jittered.on_arrival();
            }
            jittered.tick(t);
            if i % 2 == 1 {
                // realigned at the shared 200 ms boundary
                assert!(
                    (jittered.rate_qps() - 100.0).abs() < 5.0,
                    "jittered qps {}",
                    jittered.rate_qps()
                );
            }
        }
        assert!((regular.rate_qps() - 100.0).abs() < 1.0);
        assert!((jittered.rate_qps() - 100.0).abs() < 5.0);
    }

    #[test]
    fn nominal_period_weight_is_exactly_alpha() {
        // At dt == τ the time-corrected weight must be bit-identical to
        // the historical fixed-α update, so existing figures don't move.
        let m = LoadMonitor::with_pools_period(0.3, 0, 100.0);
        let mut reference = crate::util::stats::Ewma::new(0.3);
        let mut now = 0.0;
        m.tick(now);
        for i in 0..30 {
            let n = 3 + (i % 7);
            for _ in 0..n {
                m.on_arrival();
            }
            now += 100.0;
            let got = m.tick(now);
            let want = reference.push(n as f64 / 0.1);
            assert_eq!(got, want, "tick {i}: {got} vs {want}");
        }
    }

    #[test]
    fn tracks_rate_changes() {
        let m = LoadMonitor::new(0.5);
        let mut now = 0.0;
        for _ in 0..20 {
            m.on_arrival();
            now += 100.0;
            m.tick(now); // 10 qps
        }
        let low = m.rate_qps();
        for _ in 0..20 {
            for _ in 0..8 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now); // 80 qps
        }
        assert!(m.rate_qps() > low * 3.0);
    }
}
