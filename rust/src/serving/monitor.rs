//! The load monitor (paper §III-B): tracks queue depth and the arrival
//! rate (EWMA over tick windows). Queue depth is the AQM's control
//! signal; the arrival-rate estimate feeds reports and diagnostics.
//!
//! The arrival counter lives outside the mutex: `on_arrival` is one
//! relaxed atomic increment, so the injector's hot path never contends
//! with the tick thread — only the (periodic, off-path) `tick` takes
//! the EWMA lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Ewma;

struct MonitorState {
    last_total: u64,
    last_tick_ms: f64,
    rate_qps: Ewma,
}

/// Thread-safe load monitor; arrival recording is lock-free.
pub struct LoadMonitor {
    arrivals_total: AtomicU64,
    /// Per-pool arrival counters (empty on a single-pool monitor built
    /// with [`new`](LoadMonitor::new)); same lock-free discipline as the
    /// total, so rung-aware routing diagnostics cost one extra relaxed
    /// increment.
    pool_arrivals: Vec<AtomicU64>,
    state: Mutex<MonitorState>,
}

impl LoadMonitor {
    pub fn new(alpha: f64) -> LoadMonitor {
        LoadMonitor::with_pools(alpha, 0)
    }

    /// A monitor that additionally tracks per-pool arrival counts for a
    /// `pools`-pool fleet.
    pub fn with_pools(alpha: f64, pools: usize) -> LoadMonitor {
        LoadMonitor {
            arrivals_total: AtomicU64::new(0),
            pool_arrivals: (0..pools).map(|_| AtomicU64::new(0)).collect(),
            state: Mutex::new(MonitorState {
                last_total: 0,
                last_tick_ms: 0.0,
                rate_qps: Ewma::new(alpha),
            }),
        }
    }

    /// Record one arrival (called by the injector): a plain atomic
    /// increment, no lock.
    pub fn on_arrival(&self) {
        self.arrivals_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one arrival routed to `pool` (lock-free; the pool counter
    /// is skipped when the monitor was not built with pools).
    pub fn on_arrival_pool(&self, pool: usize) {
        self.arrivals_total.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.pool_arrivals.get(pool) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Arrivals routed to `pool` so far (0 for unknown pools).
    pub fn pool_arrivals_total(&self, pool: usize) -> u64 {
        self.pool_arrivals
            .get(pool)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Tick the rate estimator; returns the EWMA arrival rate (qps).
    pub fn tick(&self, now_ms: f64) -> f64 {
        let mut s = self.state.lock().unwrap();
        let total = self.arrivals_total.load(Ordering::Relaxed);
        let dt = (now_ms - s.last_tick_ms).max(1e-6);
        let newly = (total - s.last_total) as f64;
        s.last_total = total;
        s.last_tick_ms = now_ms;
        let inst = newly / (dt / 1000.0);
        s.rate_qps.push(inst)
    }

    /// Latest smoothed arrival-rate estimate.
    pub fn rate_qps(&self) -> f64 {
        self.state.lock().unwrap().rate_qps.get().unwrap_or(0.0)
    }

    pub fn arrivals_total(&self) -> u64 {
        self.arrivals_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_steady_rate() {
        let m = LoadMonitor::new(0.3);
        // 10 arrivals per 100 ms tick = 100 qps.
        let mut now = 0.0;
        for _ in 0..50 {
            for _ in 0..10 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now);
        }
        let qps = m.rate_qps();
        assert!((qps - 100.0).abs() < 5.0, "qps {qps}");
        assert_eq!(m.arrivals_total(), 500);
    }

    #[test]
    fn pool_counters_split_the_total() {
        let m = LoadMonitor::with_pools(0.3, 2);
        for _ in 0..7 {
            m.on_arrival_pool(0);
        }
        for _ in 0..3 {
            m.on_arrival_pool(1);
        }
        assert_eq!(m.arrivals_total(), 10);
        assert_eq!(m.pool_arrivals_total(0), 7);
        assert_eq!(m.pool_arrivals_total(1), 3);
        assert_eq!(m.pool_arrivals_total(9), 0, "unknown pool reads 0");
        // A pool-less monitor still counts the total on the pooled path.
        let plain = LoadMonitor::new(0.3);
        plain.on_arrival_pool(0);
        assert_eq!(plain.arrivals_total(), 1);
        assert_eq!(plain.pool_arrivals_total(0), 0);
    }

    #[test]
    fn tracks_rate_changes() {
        let m = LoadMonitor::new(0.5);
        let mut now = 0.0;
        for _ in 0..20 {
            m.on_arrival();
            now += 100.0;
            m.tick(now); // 10 qps
        }
        let low = m.rate_qps();
        for _ in 0..20 {
            for _ in 0..8 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now); // 80 qps
        }
        assert!(m.rate_qps() > low * 3.0);
    }
}
