//! The load monitor (paper §III-B): tracks queue depth and the arrival
//! rate (EWMA over tick windows). Queue depth is the AQM's control
//! signal; the arrival-rate estimate feeds reports and diagnostics.
//!
//! The arrival counter lives outside the mutex: `on_arrival` is one
//! relaxed atomic increment, so the injector's hot path never contends
//! with the tick thread — only the (periodic, off-path) `tick` takes
//! the EWMA lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Ewma;

struct MonitorState {
    last_total: u64,
    last_tick_ms: f64,
    rate_qps: Ewma,
}

/// Thread-safe load monitor; arrival recording is lock-free.
pub struct LoadMonitor {
    arrivals_total: AtomicU64,
    state: Mutex<MonitorState>,
}

impl LoadMonitor {
    pub fn new(alpha: f64) -> LoadMonitor {
        LoadMonitor {
            arrivals_total: AtomicU64::new(0),
            state: Mutex::new(MonitorState {
                last_total: 0,
                last_tick_ms: 0.0,
                rate_qps: Ewma::new(alpha),
            }),
        }
    }

    /// Record one arrival (called by the injector): a plain atomic
    /// increment, no lock.
    pub fn on_arrival(&self) {
        self.arrivals_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Tick the rate estimator; returns the EWMA arrival rate (qps).
    pub fn tick(&self, now_ms: f64) -> f64 {
        let mut s = self.state.lock().unwrap();
        let total = self.arrivals_total.load(Ordering::Relaxed);
        let dt = (now_ms - s.last_tick_ms).max(1e-6);
        let newly = (total - s.last_total) as f64;
        s.last_total = total;
        s.last_tick_ms = now_ms;
        let inst = newly / (dt / 1000.0);
        s.rate_qps.push(inst)
    }

    /// Latest smoothed arrival-rate estimate.
    pub fn rate_qps(&self) -> f64 {
        self.state.lock().unwrap().rate_qps.get().unwrap_or(0.0)
    }

    pub fn arrivals_total(&self) -> u64 {
        self.arrivals_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_steady_rate() {
        let m = LoadMonitor::new(0.3);
        // 10 arrivals per 100 ms tick = 100 qps.
        let mut now = 0.0;
        for _ in 0..50 {
            for _ in 0..10 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now);
        }
        let qps = m.rate_qps();
        assert!((qps - 100.0).abs() < 5.0, "qps {qps}");
        assert_eq!(m.arrivals_total(), 500);
    }

    #[test]
    fn tracks_rate_changes() {
        let m = LoadMonitor::new(0.5);
        let mut now = 0.0;
        for _ in 0..20 {
            m.on_arrival();
            now += 100.0;
            m.tick(now); // 10 qps
        }
        let low = m.rate_qps();
        for _ in 0..20 {
            for _ in 0..8 {
                m.on_arrival();
            }
            now += 100.0;
            m.tick(now); // 80 qps
        }
        assert!(m.rate_qps() > low * 3.0);
    }
}
