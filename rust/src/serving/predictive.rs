//! Predictive adaptation — the paper's §VIII future-work extension.
//!
//! The reactive AQM switches *after* queue depth crosses a threshold; by
//! then several requests already carry the extra wait. This controller
//! additionally tracks the short-horizon arrival-rate trend (EWMA slope)
//! and switches *anticipatorily*: if the projected arrival rate over the
//! next horizon exceeds the current rung's sustainable service rate, it
//! upscales before the queue builds.
//!
//! It degrades gracefully to plain Elastico behavior when the trend
//! estimate is flat (the thresholds still bound everything — prediction
//! only moves the switch earlier, never later).

use super::elastico::ElasticoPolicy;
use super::policy::ScalingPolicy;
use crate::planner::Plan;
use crate::util::stats::Ewma;

/// Elastico + arrival-trend anticipation.
pub struct PredictivePolicy {
    inner: ElasticoPolicy,
    /// Smoothed inter-observation arrival rate (events/ms).
    rate: Ewma,
    rate_prev: Option<f64>,
    /// Smoothed rate slope (events/ms²).
    slope: Ewma,
    last_obs_ms: f64,
    last_depth: usize,
    started: bool,
    /// Prediction horizon (ms): how far ahead to project the rate.
    pub horizon_ms: f64,
    /// Safety factor on the sustainable rate (1.0 = exactly ρ=1).
    pub target_utilization: f64,
}

impl PredictivePolicy {
    pub fn new(plan: Plan) -> PredictivePolicy {
        PredictivePolicy {
            inner: ElasticoPolicy::new(plan),
            rate: Ewma::new(0.2),
            rate_prev: None,
            slope: Ewma::new(0.2),
            last_obs_ms: 0.0,
            last_depth: 0,
            started: false,
            horizon_ms: 2_000.0,
            target_utilization: 0.85,
        }
    }

    /// Projected arrival rate (requests/ms) `horizon_ms` from now.
    fn projected_rate(&self) -> f64 {
        let r = self.rate.get().unwrap_or(0.0);
        let s = self.slope.get().unwrap_or(0.0);
        (r + s * self.horizon_ms).max(0.0)
    }
}

impl ScalingPolicy for PredictivePolicy {
    fn decide(&mut self, now_ms: f64, queue_depth: usize) -> usize {
        // Rate estimation from depth deltas + elapsed time: arrivals seen
        // by this observer = depth increase (departures are observed as
        // decreases and clamp at 0 contribution). The first observation
        // only anchors the clock — no meaningful dt exists yet.
        if !self.started {
            self.started = true;
            self.last_obs_ms = now_ms;
            self.last_depth = queue_depth;
            return self.inner.decide(now_ms, queue_depth);
        }
        let dt = (now_ms - self.last_obs_ms).max(1e-3);
        let newly = queue_depth.saturating_sub(self.last_depth) as f64;
        self.last_obs_ms = now_ms;
        self.last_depth = queue_depth;
        let inst_rate = newly / dt;
        let r = self.rate.push(inst_rate);
        if let Some(p0) = self.rate_prev {
            self.slope.push((r - p0) / dt);
        }
        self.rate_prev = Some(r);

        // Reactive layer first (also updates hysteresis state).
        let reactive = self.inner.decide(now_ms, queue_depth);

        // Anticipatory layer: if the projected rate exceeds what the
        // current rung can sustain, upscale one rung early.
        let plan = self.inner.plan();
        if reactive > 0 {
            // Sustainable rate across the worker pool: k·ρ_target·μ.
            let k = plan.workers.max(1) as f64;
            let svc_rate = k * self.target_utilization / plan.ladder[reactive].mean_ms;
            // Guard against slope noise: anticipate only when the smoothed
            // rate is already a substantial fraction of capacity AND the
            // projection exceeds it.
            let rate_now = self.rate.get().unwrap_or(0.0);
            if rate_now > 0.5 * svc_rate && self.projected_rate() > svc_rate {
                // Force one rung toward fast through the inner policy by
                // reporting a depth just above its threshold.
                let depth_over =
                    plan.ladder[reactive].upscale_threshold as usize + 1;
                return self.inner.decide(now_ms, depth_over);
            }
        }
        reactive
    }

    fn current(&self) -> usize {
        self.inner.current()
    }

    fn name(&self) -> String {
        "Predictive".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{derive_plan, AqmParams, LatencyProfile, ProfiledConfig};

    fn plan() -> Plan {
        let mk = |label: &str, acc: f64, mean: f64| ProfiledConfig {
            config: vec![],
            label: label.into(),
            accuracy: acc,
            latency: LatencyProfile {
                mean_ms: mean,
                p50_ms: mean,
                p95_ms: mean * 1.2,
                runs: 10,
            },
        };
        derive_plan(
            &[mk("fast", 0.76, 10.0), mk("accurate", 0.85, 60.0)],
            AqmParams::for_slo(400.0),
        )
    }

    #[test]
    fn starts_accurate_and_stays_under_light_load() {
        let mut p = PredictivePolicy::new(plan());
        for i in 0..200 {
            let cur = p.decide(i as f64 * 100.0, if i % 7 == 0 { 1 } else { 0 });
            assert_eq!(cur, 1, "light load must stay accurate");
        }
    }

    #[test]
    fn rising_rate_triggers_early_upscale() {
        let mut p = PredictivePolicy::new(plan());
        // Accelerating arrivals: depth grows 0,1,2,4,6,... while still
        // below the reactive threshold — prediction should fire first.
        let mut t = 0.0;
        let mut upscaled_at_depth = None;
        for step in 0..60 {
            t += 20.0;
            let depth = (step * step) / 120; // slow quadratic ramp
            let cur = p.decide(t, depth);
            if cur == 0 && upscaled_at_depth.is_none() {
                upscaled_at_depth = Some(depth);
            }
        }
        let reactive_thr = plan().ladder[1].upscale_threshold as usize;
        let d = upscaled_at_depth.expect("never upscaled");
        assert!(
            d <= reactive_thr + 1,
            "predictive upscale at depth {d} vs reactive threshold {reactive_thr}"
        );
    }

    #[test]
    fn worker_pool_raises_the_anticipation_bar() {
        // The same gentle ramp that triggers a predictive upscale on one
        // worker is comfortably sustainable on eight: an 8-worker plan
        // must not anticipate (its thresholds and k·μ are 8x higher).
        let mk = |label: &str, acc: f64, mean: f64| ProfiledConfig {
            config: vec![],
            label: label.into(),
            accuracy: acc,
            latency: LatencyProfile {
                mean_ms: mean,
                p50_ms: mean,
                p95_ms: mean * 1.2,
                runs: 10,
            },
        };
        let front = [mk("fast", 0.76, 10.0), mk("accurate", 0.85, 60.0)];
        let plan8 = derive_plan(&front, AqmParams::for_slo_workers(400.0, 8));
        let mut p = PredictivePolicy::new(plan8);
        let mut t = 0.0;
        for step in 0..60 {
            t += 20.0;
            let depth = (step * step) / 120; // same ramp as the k=1 test
            let cur = p.decide(t, depth);
            assert_eq!(cur, 1, "8-worker pool upscaled at depth {depth}");
        }
    }

    #[test]
    fn spikes_still_handled_reactively() {
        let mut p = PredictivePolicy::new(plan());
        p.decide(0.0, 0);
        let cur = p.decide(10.0, 50); // instant deep queue
        assert_eq!(cur, 0);
    }
}
