//! Failure injection: a [`FaultPlan`] describes *when* capacity
//! degrades, and both executors apply it identically — the live server
//! (`serving/server.rs`: workers stop dequeuing, stretch their service
//! wall-clock, or the injector tightens admission) and the DES engine
//! (`sim/engine.rs`: server slots retire, speed factors stretch, the
//! admission branch rejects). Every fault is a pure function of run
//! time, so a live run and a simulation of the same plan degrade at the
//! same (virtual) instants.
//!
//! Three fault shapes (the Salesforce production-study failure modes):
//!
//! * [`Fault::PoolDark`] — a whole pool stops serving at `at_s`; its
//!   backlog is either absorbed by other pools' spill-when-dry or
//!   counted rejected, so `served + rejected == arrivals` still holds;
//! * [`Fault::Slowdown`] — a pool's service times stretch ×`factor`
//!   over a window (thermal throttling, noisy neighbor);
//! * [`Fault::QueueSqueeze`] — the admission bound tightens to
//!   `capacity` over a window (an upstream proxy shrinking buffers).

use anyhow::{bail, Context, Result};

/// One injected fault. Times are seconds from run start (the same
/// clock as arrival timestamps).
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Pool `pool` stops dequeuing at `at_s` (workers crash / go dark).
    PoolDark { pool: usize, at_s: f64 },
    /// Pool `pool` serves ×`factor` slower during `[from_s, to_s)`.
    Slowdown { pool: usize, factor: f64, from_s: f64, to_s: f64 },
    /// Total queue admission bound drops to `capacity` during
    /// `[from_s, to_s)`.
    QueueSqueeze { capacity: usize, from_s: f64, to_s: f64 },
}

/// A set of faults applied to one run. `Default` is the empty plan
/// (no behavioral change at all — pinned by the engine tests).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// The empty plan.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Builder: add one fault.
    pub fn with(mut self, fault: Fault) -> FaultPlan {
        self.faults.push(fault);
        self
    }

    /// Earliest dark time of `pool` in milliseconds, if any.
    pub fn dark_at_ms(&self, pool: usize) -> Option<f64> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::PoolDark { pool: p, at_s } if *p == pool => Some(at_s * 1000.0),
                _ => None,
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Does any fault take a pool dark?
    pub fn any_dark(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::PoolDark { .. }))
    }

    /// Service-time stretch factor of `pool` at `t_ms` (product of the
    /// active slowdown windows; 1.0 outside them).
    pub fn slowdown_at_ms(&self, pool: usize, t_ms: f64) -> f64 {
        let mut factor = 1.0;
        for f in &self.faults {
            if let Fault::Slowdown { pool: p, factor: x, from_s, to_s } = f {
                if *p == pool && t_ms >= from_s * 1000.0 && t_ms < to_s * 1000.0 {
                    factor *= x;
                }
            }
        }
        factor
    }

    /// Tightest active admission bound at `t_ms`, if a squeeze window
    /// is open.
    pub fn capacity_at_ms(&self, t_ms: f64) -> Option<usize> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::QueueSqueeze { capacity, from_s, to_s }
                    if t_ms >= from_s * 1000.0 && t_ms < to_s * 1000.0 =>
                {
                    Some(*capacity)
                }
                _ => None,
            })
            .min()
    }

    /// Parse a comma-separated fault list:
    ///
    /// * `dark:<pool>@<t>` — pool dark at `t` seconds;
    /// * `slow:<pool>x<factor>@<from>-<to>` — slowdown window;
    /// * `squeeze:<capacity>@<from>-<to>` — admission squeeze window.
    ///
    /// Example: `dark:1@60,slow:0x2.5@30-90,squeeze:64@100-140`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, rest) = part
                .split_once(':')
                .with_context(|| format!("fault {part:?}: expected kind:spec"))?;
            match kind {
                "dark" => {
                    let (pool, at) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected dark:pool@t"))?;
                    plan.faults.push(Fault::PoolDark {
                        pool: pool.parse().with_context(|| format!("bad pool in {part:?}"))?,
                        at_s: at.parse().with_context(|| format!("bad time in {part:?}"))?,
                    });
                }
                "slow" => {
                    let (head, window) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected slow:pxf@a-b"))?;
                    let (pool, factor) = head
                        .split_once('x')
                        .with_context(|| format!("fault {part:?}: expected pool x factor"))?;
                    let (from, to) = window
                        .split_once('-')
                        .with_context(|| format!("fault {part:?}: expected window a-b"))?;
                    plan.faults.push(Fault::Slowdown {
                        pool: pool.parse().with_context(|| format!("bad pool in {part:?}"))?,
                        factor: factor
                            .parse()
                            .with_context(|| format!("bad factor in {part:?}"))?,
                        from_s: from.parse().with_context(|| format!("bad from in {part:?}"))?,
                        to_s: to.parse().with_context(|| format!("bad to in {part:?}"))?,
                    });
                }
                "squeeze" => {
                    let (cap, window) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected squeeze:c@a-b"))?;
                    let (from, to) = window
                        .split_once('-')
                        .with_context(|| format!("fault {part:?}: expected window a-b"))?;
                    plan.faults.push(Fault::QueueSqueeze {
                        capacity: cap
                            .parse()
                            .with_context(|| format!("bad capacity in {part:?}"))?,
                        from_s: from.parse().with_context(|| format!("bad from in {part:?}"))?,
                        to_s: to.parse().with_context(|| format!("bad to in {part:?}"))?,
                    });
                }
                other => bail!("unknown fault kind {other:?} in {part:?}"),
            }
        }
        Ok(plan)
    }

    /// One-line human description (experiment headers, cell tables).
    pub fn describe(&self) -> String {
        if self.faults.is_empty() {
            return "none".into();
        }
        let parts: Vec<String> = self
            .faults
            .iter()
            .map(|f| match f {
                Fault::PoolDark { pool, at_s } => format!("dark:{pool}@{at_s}"),
                Fault::Slowdown { pool, factor, from_s, to_s } => {
                    format!("slow:{pool}x{factor}@{from_s}-{to_s}")
                }
                Fault::QueueSqueeze { capacity, from_s, to_s } => {
                    format!("squeeze:{capacity}@{from_s}-{to_s}")
                }
            })
            .collect();
        parts.join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        assert!(!plan.any_dark());
        assert_eq!(plan.dark_at_ms(0), None);
        assert_eq!(plan.slowdown_at_ms(0, 1e6), 1.0);
        assert_eq!(plan.capacity_at_ms(1e6), None);
    }

    #[test]
    fn queries_respect_windows_and_pools() {
        let plan = FaultPlan::none()
            .with(Fault::PoolDark { pool: 1, at_s: 60.0 })
            .with(Fault::Slowdown { pool: 0, factor: 2.5, from_s: 30.0, to_s: 90.0 })
            .with(Fault::QueueSqueeze { capacity: 64, from_s: 100.0, to_s: 140.0 });
        assert!(plan.any_dark());
        assert_eq!(plan.dark_at_ms(1), Some(60_000.0));
        assert_eq!(plan.dark_at_ms(0), None);
        assert_eq!(plan.slowdown_at_ms(0, 29_999.0), 1.0);
        assert_eq!(plan.slowdown_at_ms(0, 45_000.0), 2.5);
        assert_eq!(plan.slowdown_at_ms(1, 45_000.0), 1.0);
        assert_eq!(plan.slowdown_at_ms(0, 90_000.0), 1.0);
        assert_eq!(plan.capacity_at_ms(99_999.0), None);
        assert_eq!(plan.capacity_at_ms(120_000.0), Some(64));
    }

    #[test]
    fn overlapping_slowdowns_compound_and_squeezes_tighten() {
        let plan = FaultPlan::none()
            .with(Fault::Slowdown { pool: 0, factor: 2.0, from_s: 0.0, to_s: 50.0 })
            .with(Fault::Slowdown { pool: 0, factor: 1.5, from_s: 20.0, to_s: 80.0 })
            .with(Fault::QueueSqueeze { capacity: 100, from_s: 0.0, to_s: 50.0 })
            .with(Fault::QueueSqueeze { capacity: 8, from_s: 10.0, to_s: 20.0 });
        assert_eq!(plan.slowdown_at_ms(0, 30_000.0), 3.0);
        assert_eq!(plan.capacity_at_ms(15_000.0), Some(8));
        assert_eq!(plan.capacity_at_ms(25_000.0), Some(100));
    }

    #[test]
    fn parse_roundtrips_describe() {
        let text = "dark:1@60,slow:0x2.5@30-90,squeeze:64@100-140";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.describe(), text);
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("dark:1").is_err());
        assert!(FaultPlan::parse("nova:1@2").is_err());
        assert!(FaultPlan::parse("slow:0@30-90").is_err());
        assert!(FaultPlan::parse("squeeze:x@1-2").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }
}
