//! Failure injection: a [`FaultPlan`] describes *when* capacity
//! degrades, and both executors apply it identically — the live server
//! (`serving/server.rs`: workers stop dequeuing, stretch their service
//! wall-clock, or the injector tightens admission) and the DES engine
//! (`sim/engine.rs`: server slots retire, speed factors stretch, the
//! admission branch rejects). Every fault is a pure function of run
//! time, so a live run and a simulation of the same plan degrade at the
//! same (virtual) instants.
//!
//! Five fault shapes (the Salesforce production-study failure modes):
//!
//! * [`Fault::PoolDark`] — a whole pool stops serving at `at_s`,
//!   optionally recovering at `until_s` (the windowed `dark:1@24-60`
//!   grammar); open-ended darkness (`dark:1@24`, `until_s: None`) keeps
//!   the historical semantics bit-for-bit: the backlog is either
//!   absorbed by other pools' spill-when-dry or counted rejected, so
//!   `served + rejected + failed == arrivals` still holds;
//! * [`Fault::Slowdown`] — a pool's service times stretch ×`factor`
//!   over a window (thermal throttling, noisy neighbor);
//! * [`Fault::QueueSqueeze`] — the admission bound tightens to
//!   `capacity` over a window (an upstream proxy shrinking buffers);
//! * [`Fault::EngineFlaky`] — a pool's engine fails a deterministic
//!   pseudo-random `rate` fraction of requests arriving inside the
//!   window (`flaky:1x0.2@20-40`). The per-request coin is a pure hash
//!   of (request id, attempt), so the live executor and the DES fail
//!   the *same* requests — the driver for retry / circuit-breaker
//!   tests without a real failing backend;
//! * [`Fault::Drift`] — a *persistent* service-time shift
//!   (`drift:0x2@60` — pool 0 serves ×2 slower from t = 60 s on,
//!   optionally ending with `@60-120`). Mechanically identical to a
//!   slowdown (the same multiplier at the same executor sites), but
//!   semantically the regime change the online re-planner is built to
//!   adapt to: hardware degradation, a model swap, a datacenter
//!   migration — reality drifting away from the offline profile — where
//!   [`Fault::Slowdown`] models a transient a static plan should ride
//!   out.

use anyhow::{bail, Context, Result};

/// One injected fault. Times are seconds from run start (the same
/// clock as arrival timestamps).
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Pool `pool` stops dequeuing at `at_s` (workers crash / go dark)
    /// and — when `until_s` is set — recovers at `until_s`.
    PoolDark { pool: usize, at_s: f64, until_s: Option<f64> },
    /// Pool `pool` serves ×`factor` slower during `[from_s, to_s)`.
    Slowdown { pool: usize, factor: f64, from_s: f64, to_s: f64 },
    /// Total queue admission bound drops to `capacity` during
    /// `[from_s, to_s)`.
    QueueSqueeze { capacity: usize, from_s: f64, to_s: f64 },
    /// Pool `pool`'s engine fails a `rate` fraction of the requests
    /// that *arrived* during `[from_s, to_s)` (window keyed on arrival
    /// time so live and DES agree deterministically; the coin is
    /// [`FaultPlan::flaky_fails`]).
    EngineFlaky { pool: usize, rate: f64, from_s: f64, to_s: f64 },
    /// Pool `pool`'s service times shift ×`factor` from `from_s` on —
    /// persistently when `to_s` is `None` (the common case: reality
    /// drifted and is not coming back), or over `[from_s, to_s)`.
    Drift { pool: usize, factor: f64, from_s: f64, to_s: Option<f64> },
}

/// A set of faults applied to one run. `Default` is the empty plan
/// (no behavioral change at all — pinned by the engine tests).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

/// SplitMix64 — the per-request flaky coin's mixer. A pure function, so
/// the same (id, attempt) flips the same coin in every executor.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// The empty plan.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Builder: add one fault.
    pub fn with(mut self, fault: Fault) -> FaultPlan {
        self.faults.push(fault);
        self
    }

    /// Earliest dark time of `pool` in milliseconds, if any.
    pub fn dark_at_ms(&self, pool: usize) -> Option<f64> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::PoolDark { pool: p, at_s, .. } if *p == pool => Some(at_s * 1000.0),
                _ => None,
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Recovery time (ms) of the earliest dark window of `pool`:
    /// `Some(t)` for a windowed fault, `Some(∞)` for open-ended
    /// darkness, `None` when the pool never goes dark.
    pub fn dark_until_ms(&self, pool: usize) -> Option<f64> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::PoolDark { pool: p, at_s, until_s } if *p == pool => {
                    Some((at_s * 1000.0, until_s.map_or(f64::INFINITY, |u| u * 1000.0)))
                }
                _ => None,
            })
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .map(|(_, u)| u)
    }

    /// Is `pool` inside a dark window at `t_ms`? (Open-ended darkness
    /// never ends.)
    pub fn is_dark_at_ms(&self, pool: usize, t_ms: f64) -> bool {
        self.faults.iter().any(|f| match f {
            Fault::PoolDark { pool: p, at_s, until_s } => {
                *p == pool && t_ms >= at_s * 1000.0 && until_s.is_none_or(|u| t_ms < u * 1000.0)
            }
            _ => false,
        })
    }

    /// Does any fault take a pool dark?
    pub fn any_dark(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::PoolDark { .. }))
    }

    /// Does any fault take a pool dark *forever* (no recovery window)?
    /// Only open-ended darkness can strand backlog unreachably.
    pub fn any_dark_forever(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::PoolDark { until_s: None, .. }))
    }

    /// Service-time stretch factor of `pool` at `t_ms` (product of the
    /// active slowdown windows; 1.0 outside them).
    pub fn slowdown_at_ms(&self, pool: usize, t_ms: f64) -> f64 {
        let mut factor = 1.0;
        for f in &self.faults {
            if let Fault::Slowdown { pool: p, factor: x, from_s, to_s } = f {
                if *p == pool && t_ms >= from_s * 1000.0 && t_ms < to_s * 1000.0 {
                    factor *= x;
                }
            }
        }
        factor
    }

    /// Service-time drift factor of `pool` at `t_ms` (product of the
    /// active drift shifts; 1.0 outside them). Applied at exactly the
    /// same executor sites as [`slowdown_at_ms`](Self::slowdown_at_ms)
    /// — the two compose multiplicatively.
    pub fn drift_at_ms(&self, pool: usize, t_ms: f64) -> f64 {
        let mut factor = 1.0;
        for f in &self.faults {
            if let Fault::Drift { pool: p, factor: x, from_s, to_s } = f {
                if *p == pool
                    && t_ms >= from_s * 1000.0
                    && to_s.is_none_or(|u| t_ms < u * 1000.0)
                {
                    factor *= x;
                }
            }
        }
        factor
    }

    /// Does any fault drift service times?
    pub fn any_drift(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::Drift { .. }))
    }

    /// Tightest active admission bound at `t_ms`, if a squeeze window
    /// is open.
    pub fn capacity_at_ms(&self, t_ms: f64) -> Option<usize> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::QueueSqueeze { capacity, from_s, to_s }
                    if t_ms >= from_s * 1000.0 && t_ms < to_s * 1000.0 =>
                {
                    Some(*capacity)
                }
                _ => None,
            })
            .min()
    }

    /// Does any fault inject engine flakiness?
    pub fn any_flaky(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::EngineFlaky { .. }))
    }

    /// The deterministic flaky coin: does attempt `attempt` of request
    /// `id` — which arrived at `arrival_ms` and is executing on `pool`
    /// — fail?
    ///
    /// The window is keyed on the request's *arrival* time (identical
    /// in both executors — dispatch wall-clock is not), and the coin is
    /// a pure [`splitmix64`] hash of `(id, attempt)`, so the live
    /// server and the DES fail exactly the same attempts. Retries flip
    /// a fresh coin (attempt increments), so a bounded-retry policy
    /// recovers a `1 - rateⁿ` fraction of the window's failures.
    pub fn flaky_fails(&self, pool: usize, id: u64, attempt: u32, arrival_ms: f64) -> bool {
        for f in &self.faults {
            if let Fault::EngineFlaky { pool: p, rate, from_s, to_s } = f {
                if *p == pool && arrival_ms >= from_s * 1000.0 && arrival_ms < to_s * 1000.0 {
                    let h = splitmix64(id ^ ((attempt as u64) << 48) ^ 0xc0ff_ee00_dead_beef);
                    let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
                    if unit < *rate {
                        return true;
                    }
                }
            }
        }
        false
    }

    /// Parse a comma-separated fault list:
    ///
    /// * `dark:<pool>@<t>` — pool dark at `t` seconds (open-ended);
    /// * `dark:<pool>@<t>-<u>` — pool dark over `[t, u)` (recovers);
    /// * `slow:<pool>x<factor>@<from>-<to>` — slowdown window;
    /// * `squeeze:<capacity>@<from>-<to>` — admission squeeze window;
    /// * `flaky:<pool>x<rate>@<from>-<to>` — engine error window;
    /// * `drift:<pool>x<factor>@<from>[-<to>]` — persistent (or
    ///   windowed) service-time shift.
    ///
    /// Example: `dark:1@20-60,slow:0x2.5@30-90,drift:0x2@60`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, rest) = part
                .split_once(':')
                .with_context(|| format!("fault {part:?}: expected kind:spec"))?;
            match kind {
                "dark" => {
                    let (pool, at) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected dark:pool@t[-u]"))?;
                    let secs = |v: &str| -> Result<f64> {
                        v.parse().with_context(|| format!("bad time in {part:?}"))
                    };
                    let (at_s, until_s) = match at.split_once('-') {
                        Some((from, to)) => (secs(from)?, Some(secs(to)?)),
                        None => (secs(at)?, None),
                    };
                    if let Some(u) = until_s {
                        anyhow::ensure!(
                            u > at_s,
                            "fault {part:?}: recovery {u} must be after dark {at_s}"
                        );
                    }
                    plan.faults.push(Fault::PoolDark {
                        pool: pool.parse().with_context(|| format!("bad pool in {part:?}"))?,
                        at_s,
                        until_s,
                    });
                }
                "slow" => {
                    let (head, window) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected slow:pxf@a-b"))?;
                    let (pool, factor) = head
                        .split_once('x')
                        .with_context(|| format!("fault {part:?}: expected pool x factor"))?;
                    let (from, to) = window
                        .split_once('-')
                        .with_context(|| format!("fault {part:?}: expected window a-b"))?;
                    plan.faults.push(Fault::Slowdown {
                        pool: pool.parse().with_context(|| format!("bad pool in {part:?}"))?,
                        factor: factor
                            .parse()
                            .with_context(|| format!("bad factor in {part:?}"))?,
                        from_s: from.parse().with_context(|| format!("bad from in {part:?}"))?,
                        to_s: to.parse().with_context(|| format!("bad to in {part:?}"))?,
                    });
                }
                "squeeze" => {
                    let (cap, window) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected squeeze:c@a-b"))?;
                    let (from, to) = window
                        .split_once('-')
                        .with_context(|| format!("fault {part:?}: expected window a-b"))?;
                    plan.faults.push(Fault::QueueSqueeze {
                        capacity: cap
                            .parse()
                            .with_context(|| format!("bad capacity in {part:?}"))?,
                        from_s: from.parse().with_context(|| format!("bad from in {part:?}"))?,
                        to_s: to.parse().with_context(|| format!("bad to in {part:?}"))?,
                    });
                }
                "flaky" => {
                    let (head, window) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected flaky:pxr@a-b"))?;
                    let (pool, rate) = head
                        .split_once('x')
                        .with_context(|| format!("fault {part:?}: expected pool x rate"))?;
                    let rate: f64 = rate.parse().with_context(|| format!("bad rate in {part:?}"))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&rate),
                        "fault {part:?}: rate {rate} outside [0, 1]"
                    );
                    let (from, to) = window
                        .split_once('-')
                        .with_context(|| format!("fault {part:?}: expected window a-b"))?;
                    plan.faults.push(Fault::EngineFlaky {
                        pool: pool.parse().with_context(|| format!("bad pool in {part:?}"))?,
                        rate,
                        from_s: from.parse().with_context(|| format!("bad from in {part:?}"))?,
                        to_s: to.parse().with_context(|| format!("bad to in {part:?}"))?,
                    });
                }
                "drift" => {
                    let (head, window) = rest
                        .split_once('@')
                        .with_context(|| format!("fault {part:?}: expected drift:pxf@t[-u]"))?;
                    let (pool, factor) = head
                        .split_once('x')
                        .with_context(|| format!("fault {part:?}: expected pool x factor"))?;
                    let factor: f64 =
                        factor.parse().with_context(|| format!("bad factor in {part:?}"))?;
                    anyhow::ensure!(factor > 0.0, "fault {part:?}: factor must be positive");
                    let secs = |v: &str| -> Result<f64> {
                        v.parse().with_context(|| format!("bad time in {part:?}"))
                    };
                    let (from_s, to_s) = match window.split_once('-') {
                        Some((from, to)) => (secs(from)?, Some(secs(to)?)),
                        None => (secs(window)?, None),
                    };
                    if let Some(u) = to_s {
                        anyhow::ensure!(
                            u > from_s,
                            "fault {part:?}: drift end {u} must be after start {from_s}"
                        );
                    }
                    plan.faults.push(Fault::Drift {
                        pool: pool.parse().with_context(|| format!("bad pool in {part:?}"))?,
                        factor,
                        from_s,
                        to_s,
                    });
                }
                other => bail!("unknown fault kind {other:?} in {part:?}"),
            }
        }
        Ok(plan)
    }

    /// One-line human description (experiment headers, cell tables).
    pub fn describe(&self) -> String {
        if self.faults.is_empty() {
            return "none".into();
        }
        let parts: Vec<String> = self
            .faults
            .iter()
            .map(|f| match f {
                Fault::PoolDark { pool, at_s, until_s: None } => format!("dark:{pool}@{at_s}"),
                Fault::PoolDark { pool, at_s, until_s: Some(u) } => {
                    format!("dark:{pool}@{at_s}-{u}")
                }
                Fault::Slowdown { pool, factor, from_s, to_s } => {
                    format!("slow:{pool}x{factor}@{from_s}-{to_s}")
                }
                Fault::QueueSqueeze { capacity, from_s, to_s } => {
                    format!("squeeze:{capacity}@{from_s}-{to_s}")
                }
                Fault::EngineFlaky { pool, rate, from_s, to_s } => {
                    format!("flaky:{pool}x{rate}@{from_s}-{to_s}")
                }
                Fault::Drift { pool, factor, from_s, to_s: None } => {
                    format!("drift:{pool}x{factor}@{from_s}")
                }
                Fault::Drift { pool, factor, from_s, to_s: Some(u) } => {
                    format!("drift:{pool}x{factor}@{from_s}-{u}")
                }
            })
            .collect();
        parts.join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        assert!(!plan.any_dark());
        assert!(!plan.any_dark_forever());
        assert!(!plan.any_flaky());
        assert_eq!(plan.dark_at_ms(0), None);
        assert_eq!(plan.dark_until_ms(0), None);
        assert!(!plan.is_dark_at_ms(0, 1e6));
        assert_eq!(plan.slowdown_at_ms(0, 1e6), 1.0);
        assert_eq!(plan.drift_at_ms(0, 1e6), 1.0);
        assert!(!plan.any_drift());
        assert_eq!(plan.capacity_at_ms(1e6), None);
        assert!(!plan.flaky_fails(0, 7, 0, 1e6));
    }

    #[test]
    fn drift_shifts_persist_and_compose_with_slowdowns() {
        let plan = FaultPlan::none()
            .with(Fault::Drift { pool: 0, factor: 2.0, from_s: 60.0, to_s: None })
            .with(Fault::Drift { pool: 1, factor: 1.5, from_s: 10.0, to_s: Some(20.0) })
            .with(Fault::Slowdown { pool: 0, factor: 3.0, from_s: 70.0, to_s: 80.0 });
        assert!(plan.any_drift());
        // Open-ended drift: off before from_s, on forever after.
        assert_eq!(plan.drift_at_ms(0, 59_999.0), 1.0);
        assert_eq!(plan.drift_at_ms(0, 60_000.0), 2.0);
        assert_eq!(plan.drift_at_ms(0, 1e12), 2.0, "drift never recovers");
        // Windowed drift closes like a slowdown.
        assert_eq!(plan.drift_at_ms(1, 15_000.0), 1.5);
        assert_eq!(plan.drift_at_ms(1, 20_000.0), 1.0);
        // Other pools untouched; drift and slowdown compose at the
        // shared executor site (product of the two factors).
        assert_eq!(plan.drift_at_ms(1, 65_000.0), 1.0);
        let combined = plan.drift_at_ms(0, 75_000.0) * plan.slowdown_at_ms(0, 75_000.0);
        assert_eq!(combined, 6.0);
    }

    #[test]
    fn drift_parse_roundtrips_describe() {
        let text = "drift:0x2@60,drift:1x1.5@10-20";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(
            plan.faults,
            vec![
                Fault::Drift { pool: 0, factor: 2.0, from_s: 60.0, to_s: None },
                Fault::Drift { pool: 1, factor: 1.5, from_s: 10.0, to_s: Some(20.0) },
            ]
        );
        assert_eq!(plan.describe(), text);
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
        assert!(FaultPlan::parse("drift:0@60").is_err(), "missing factor");
        assert!(FaultPlan::parse("drift:0x0@60").is_err(), "factor must be positive");
        assert!(FaultPlan::parse("drift:0x2@60-30").is_err(), "end before start");
        assert!(FaultPlan::parse("drift:0x2").is_err(), "missing time");
    }

    #[test]
    fn queries_respect_windows_and_pools() {
        let plan = FaultPlan::none()
            .with(Fault::PoolDark { pool: 1, at_s: 60.0, until_s: None })
            .with(Fault::Slowdown { pool: 0, factor: 2.5, from_s: 30.0, to_s: 90.0 })
            .with(Fault::QueueSqueeze { capacity: 64, from_s: 100.0, to_s: 140.0 });
        assert!(plan.any_dark());
        assert!(plan.any_dark_forever());
        assert_eq!(plan.dark_at_ms(1), Some(60_000.0));
        assert_eq!(plan.dark_at_ms(0), None);
        assert_eq!(plan.dark_until_ms(1), Some(f64::INFINITY));
        assert_eq!(plan.slowdown_at_ms(0, 29_999.0), 1.0);
        assert_eq!(plan.slowdown_at_ms(0, 45_000.0), 2.5);
        assert_eq!(plan.slowdown_at_ms(1, 45_000.0), 1.0);
        assert_eq!(plan.slowdown_at_ms(0, 90_000.0), 1.0);
        assert_eq!(plan.capacity_at_ms(99_999.0), None);
        assert_eq!(plan.capacity_at_ms(120_000.0), Some(64));
    }

    #[test]
    fn dark_windows_open_and_close() {
        let plan =
            FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 20.0, until_s: Some(60.0) });
        assert!(plan.any_dark());
        assert!(!plan.any_dark_forever(), "a windowed fault recovers");
        assert_eq!(plan.dark_at_ms(1), Some(20_000.0));
        assert_eq!(plan.dark_until_ms(1), Some(60_000.0));
        assert!(!plan.is_dark_at_ms(1, 19_999.0));
        assert!(plan.is_dark_at_ms(1, 20_000.0));
        assert!(plan.is_dark_at_ms(1, 59_999.0));
        assert!(!plan.is_dark_at_ms(1, 60_000.0), "recovered at the window end");
        assert!(!plan.is_dark_at_ms(0, 30_000.0), "other pools unaffected");
        // Open-ended darkness never ends (the pinned PR-6 behavior).
        let forever =
            FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 20.0, until_s: None });
        assert!(forever.is_dark_at_ms(1, 1e12));
        assert!(forever.any_dark_forever());
    }

    #[test]
    fn overlapping_slowdowns_compound_and_squeezes_tighten() {
        let plan = FaultPlan::none()
            .with(Fault::Slowdown { pool: 0, factor: 2.0, from_s: 0.0, to_s: 50.0 })
            .with(Fault::Slowdown { pool: 0, factor: 1.5, from_s: 20.0, to_s: 80.0 })
            .with(Fault::QueueSqueeze { capacity: 100, from_s: 0.0, to_s: 50.0 })
            .with(Fault::QueueSqueeze { capacity: 8, from_s: 10.0, to_s: 20.0 });
        assert_eq!(plan.slowdown_at_ms(0, 30_000.0), 3.0);
        assert_eq!(plan.capacity_at_ms(15_000.0), Some(8));
        assert_eq!(plan.capacity_at_ms(25_000.0), Some(100));
    }

    #[test]
    fn flaky_coin_is_deterministic_and_windowed() {
        let plan = FaultPlan::none().with(Fault::EngineFlaky {
            pool: 0,
            rate: 0.4,
            from_s: 20.0,
            to_s: 40.0,
        });
        assert!(plan.any_flaky());
        // Deterministic: the same (id, attempt) always flips the same way.
        for id in 0..200u64 {
            assert_eq!(plan.flaky_fails(0, id, 0, 30_000.0), plan.flaky_fails(0, id, 0, 30_000.0));
        }
        // Outside the arrival window, and on other pools: never fails.
        assert!((0..200).all(|id| !plan.flaky_fails(0, id, 0, 19_999.0)));
        assert!((0..200).all(|id| !plan.flaky_fails(0, id, 0, 40_000.0)));
        assert!((0..200).all(|id| !plan.flaky_fails(1, id, 0, 30_000.0)));
        // The empirical rate is near the configured one.
        let fails = (0..2000u64).filter(|&id| plan.flaky_fails(0, id, 0, 30_000.0)).count();
        let frac = fails as f64 / 2000.0;
        assert!((frac - 0.4).abs() < 0.05, "empirical flaky rate {frac} vs 0.4");
        // A retry flips a fresh coin: some first-attempt failures pass.
        let recovered = (0..2000u64)
            .filter(|&id| plan.flaky_fails(0, id, 0, 30_000.0))
            .filter(|&id| !plan.flaky_fails(0, id, 1, 30_000.0))
            .count();
        assert!(recovered > 0, "retries must be able to recover flaky failures");
    }

    #[test]
    fn parse_roundtrips_describe() {
        let text = "dark:1@60,slow:0x2.5@30-90,squeeze:64@100-140";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.describe(), text);
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
        // The open-ended form parses exactly as before the windowed
        // grammar existed (pinned).
        assert_eq!(
            FaultPlan::parse("dark:1@24").unwrap().faults,
            vec![Fault::PoolDark { pool: 1, at_s: 24.0, until_s: None }]
        );
        // Windowed dark and flaky round-trip too.
        let chaos = "dark:1@24-60,flaky:0x0.2@20-40";
        let plan = FaultPlan::parse(chaos).unwrap();
        assert_eq!(
            plan.faults,
            vec![
                Fault::PoolDark { pool: 1, at_s: 24.0, until_s: Some(60.0) },
                Fault::EngineFlaky { pool: 0, rate: 0.2, from_s: 20.0, to_s: 40.0 },
            ]
        );
        assert_eq!(plan.describe(), chaos);
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("dark:1").is_err());
        assert!(FaultPlan::parse("nova:1@2").is_err());
        assert!(FaultPlan::parse("slow:0@30-90").is_err());
        assert!(FaultPlan::parse("squeeze:x@1-2").is_err());
        assert!(FaultPlan::parse("dark:1@60-20").is_err(), "recovery before dark");
        assert!(FaultPlan::parse("flaky:0x1.5@1-2").is_err(), "rate outside [0,1]");
        assert!(FaultPlan::parse("flaky:0@1-2").is_err(), "missing rate");
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }
}
