//! Arrival-trace persistence: save generated workloads and replay
//! recorded ones (CSV, one arrival timestamp in seconds per line).
//!
//! Lets a live run and a simulation consume bit-identical arrivals, and
//! lets users bring production traces instead of synthetic patterns.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Write arrivals (seconds, ascending) as a one-column CSV.
pub fn save_trace(path: &Path, arrivals: &[f64]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "arrival_s")?;
    for t in arrivals {
        writeln!(w, "{t:.6}")?;
    }
    Ok(())
}

/// Load an arrival trace; validates monotonicity.
pub fn load_trace(path: &Path) -> Result<Vec<f64>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut out = Vec::new();
    for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || (i == 0 && line == "arrival_s") {
            continue;
        }
        let t: f64 = line
            .parse()
            .with_context(|| format!("{path:?}:{}: bad arrival {line:?}", i + 1))?;
        if let Some(&prev) = out.last() {
            if t < prev {
                bail!("{path:?}:{}: arrivals must be non-decreasing", i + 1);
            }
        }
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_arrivals, Pattern, WorkloadSpec};

    #[test]
    fn roundtrip() {
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 10.0,
            duration_s: 20.0,
            pattern: Pattern::paper_bursty(),
            seed: 4,
        });
        let path = std::env::temp_dir().join("compass_trace_test.csv");
        save_trace(&path, &arrivals).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(loaded.len(), arrivals.len());
        for (a, b) in loaded.iter().zip(&arrivals) {
            assert!((a - b).abs() < 1e-5);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_unsorted() {
        let path = std::env::temp_dir().join("compass_trace_bad.csv");
        std::fs::write(&path, "arrival_s\n1.0\n0.5\n").unwrap();
        assert!(load_trace(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("compass_trace_bad2.csv");
        std::fs::write(&path, "arrival_s\nnot-a-number\n").unwrap();
        assert!(load_trace(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
