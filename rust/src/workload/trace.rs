//! Trace persistence: record and replay both *inputs* (arrival traces)
//! and *outputs* (per-request logs) of a run.
//!
//! Arrival traces are one-column CSVs (`arrival_s`), written with full
//! round-trip float precision so save → load → simulate is bit-identical
//! to the generating run (pinned by `roundtrip_is_exact`). Request logs
//! are the dataset-rows shape — one row per served request with
//! arrival/start/finish, the rung and pool that served it, latency,
//! outcome, and (since the overload plane) the SLO class and its
//! relative deadline — so a sweep cell can be archived and re-analyzed
//! (or its arrivals replayed through a different policy) without
//! rerunning it. Legacy 9-column logs still load, with the class
//! columns defaulted.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::RequestRecord;
use crate::serving::{OverloadConfig, Topology};
use crate::util::csv::CsvWriter;

/// Write arrivals (seconds, ascending) as a one-column CSV. Floats are
/// written with `Display` (shortest decimal that round-trips), so
/// loading reproduces the exact same bits.
pub fn save_trace(path: &Path, arrivals: &[f64]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "arrival_s")?;
    for t in arrivals {
        writeln!(w, "{t}")?;
    }
    Ok(())
}

/// Load an arrival trace; validates monotonicity.
pub fn load_trace(path: &Path) -> Result<Vec<f64>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut out = Vec::new();
    for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || (i == 0 && line == "arrival_s") {
            continue;
        }
        let t: f64 = line
            .parse()
            .with_context(|| format!("{path:?}:{}: bad arrival {line:?}", i + 1))?;
        if let Some(&prev) = out.last() {
            if t < prev {
                bail!("{path:?}:{}: arrivals must be non-decreasing", i + 1);
            }
        }
        out.push(t);
    }
    Ok(out)
}

/// One row of a request log: a [`RequestRecord`] plus the pool that the
/// serving rung routed to (derived from the run's topology at save
/// time, so the log is self-contained) and the request's SLO class
/// (derived from the overload config the run executed under).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestLogRow {
    pub id: u64,
    pub arrival_ms: f64,
    pub start_ms: f64,
    pub finish_ms: f64,
    pub rung: usize,
    pub pool: usize,
    pub latency_ms: f64,
    pub accuracy: f64,
    /// `"ok"` / `"fail"` for live runs with sampled answers, `"na"` for
    /// simulations.
    pub outcome: String,
    /// SLO class name (`"-"` for classless runs and legacy 9-column
    /// logs).
    pub class: String,
    /// The class's relative deadline, ms after arrival (0 = none).
    pub deadline_ms: f64,
}

impl RequestLogRow {
    /// Convert a run record into a log row under `topo`'s routing
    /// (classless: the overload columns take their legacy defaults).
    pub fn from_record(r: &RequestRecord, topo: &Topology) -> RequestLogRow {
        RequestLogRow::from_record_overload(r, topo, &OverloadConfig::default())
    }

    /// Convert a run record into a log row under `topo`'s routing and
    /// `ov`'s class assignment.
    pub fn from_record_overload(
        r: &RequestRecord,
        topo: &Topology,
        ov: &OverloadConfig,
    ) -> RequestLogRow {
        RequestLogRow {
            id: r.id,
            arrival_ms: r.arrival_ms,
            start_ms: r.start_ms,
            finish_ms: r.finish_ms,
            rung: r.config_idx,
            pool: topo.pool_for_rung(r.config_idx),
            latency_ms: r.finish_ms - r.arrival_ms,
            accuracy: r.accuracy,
            outcome: match r.success {
                Some(true) => "ok".into(),
                Some(false) => "fail".into(),
                None => "na".into(),
            },
            class: ov.class_name(r.id).to_string(),
            deadline_ms: ov.class_deadline_ms(r.id),
        }
    }

    /// Back to a [`RequestRecord`] (the pool column is re-derivable from
    /// a topology, so it is dropped).
    pub fn to_record(&self) -> RequestRecord {
        RequestRecord {
            id: self.id,
            arrival_ms: self.arrival_ms,
            start_ms: self.start_ms,
            finish_ms: self.finish_ms,
            config_idx: self.rung,
            accuracy: self.accuracy,
            success: match self.outcome.as_str() {
                "ok" => Some(true),
                "fail" => Some(false),
                _ => None,
            },
        }
    }
}

/// The legacy 9-column request-log header (pre-overload fixtures);
/// still loadable, with the overload columns defaulted.
const LOG_HEADER: [&str; 9] = [
    "id",
    "arrival_ms",
    "start_ms",
    "finish_ms",
    "rung",
    "pool",
    "latency_ms",
    "accuracy",
    "outcome",
];

/// The current request-log header: the legacy columns plus the SLO
/// class and its relative deadline.
const LOG_HEADER_V2: [&str; 11] = [
    "id",
    "arrival_ms",
    "start_ms",
    "finish_ms",
    "rung",
    "pool",
    "latency_ms",
    "accuracy",
    "outcome",
    "class",
    "deadline_ms",
];

/// Write a full request log (one row per served request, full float
/// precision) for the records of a classless run — the overload
/// columns carry their legacy defaults (`"-"`, 0).
pub fn save_request_log(path: &Path, records: &[RequestRecord], topo: &Topology) -> Result<()> {
    save_request_log_overload(path, records, topo, &OverloadConfig::default())
}

/// Write a full request log with the SLO class columns filled from
/// `ov`'s deterministic class assignment.
pub fn save_request_log_overload(
    path: &Path,
    records: &[RequestRecord],
    topo: &Topology,
    ov: &OverloadConfig,
) -> Result<()> {
    let mut w = CsvWriter::create(path, &LOG_HEADER_V2)?;
    for r in records {
        let row = RequestLogRow::from_record_overload(r, topo, ov);
        w.row(&[
            row.id.to_string(),
            row.arrival_ms.to_string(),
            row.start_ms.to_string(),
            row.finish_ms.to_string(),
            row.rung.to_string(),
            row.pool.to_string(),
            row.latency_ms.to_string(),
            row.accuracy.to_string(),
            row.outcome.clone(),
            row.class.clone(),
            row.deadline_ms.to_string(),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// Load a request log saved by [`save_request_log`] — either the
/// current 11-column schema or a legacy 9-column fixture, whose rows
/// load with the default class (`"-"`) and no deadline.
pub fn load_request_log(path: &Path) -> Result<Vec<RequestLogRow>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut out = Vec::new();
    let mut legacy = false;
    for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split(',').collect();
        if i == 0 {
            if cols == LOG_HEADER_V2 {
                legacy = false;
            } else if cols == LOG_HEADER {
                legacy = true;
            } else {
                bail!("{path:?}: unexpected request-log header {line:?}");
            }
            continue;
        }
        let want = if legacy { LOG_HEADER.len() } else { LOG_HEADER_V2.len() };
        if cols.len() != want {
            bail!("{path:?}:{}: expected {want} columns", i + 1);
        }
        let f = |j: usize| -> Result<f64> {
            cols[j]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad float {:?}", i + 1, cols[j]))
        };
        out.push(RequestLogRow {
            id: cols[0]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad id {:?}", i + 1, cols[0]))?,
            arrival_ms: f(1)?,
            start_ms: f(2)?,
            finish_ms: f(3)?,
            rung: cols[4]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad rung {:?}", i + 1, cols[4]))?,
            pool: cols[5]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad pool {:?}", i + 1, cols[5]))?,
            latency_ms: f(6)?,
            accuracy: f(7)?,
            outcome: cols[8].to_string(),
            class: if legacy { "-".to_string() } else { cols[9].to_string() },
            deadline_ms: if legacy { 0.0 } else { f(10)? },
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_arrivals, Pattern, WorkloadSpec};

    #[test]
    fn roundtrip_is_exact() {
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 10.0,
            duration_s: 20.0,
            pattern: Pattern::paper_bursty(),
            seed: 4,
        });
        let path = std::env::temp_dir().join("compass_trace_test.csv");
        save_trace(&path, &arrivals).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(loaded.len(), arrivals.len());
        for (a, b) in loaded.iter().zip(&arrivals) {
            assert_eq!(a.to_bits(), b.to_bits(), "trace float must round-trip exactly");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_unsorted() {
        let path = std::env::temp_dir().join("compass_trace_bad.csv");
        std::fs::write(&path, "arrival_s\n1.0\n0.5\n").unwrap();
        assert!(load_trace(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("compass_trace_bad2.csv");
        std::fs::write(&path, "arrival_s\nnot-a-number\n").unwrap();
        assert!(load_trace(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn request_log_roundtrips_exactly() {
        let topo = Topology::uniform(2, 2);
        let records = vec![
            RequestRecord {
                id: 0,
                arrival_ms: 1.0 / 3.0,
                start_ms: 0.4000000000000001,
                finish_ms: 7.7,
                config_idx: 2,
                accuracy: 0.913,
                success: None,
            },
            RequestRecord {
                id: 1,
                arrival_ms: 2.25,
                start_ms: 2.25,
                finish_ms: 9.0,
                config_idx: 0,
                accuracy: 0.55,
                success: Some(true),
            },
        ];
        let path = std::env::temp_dir().join("compass_reqlog_test.csv");
        save_request_log(&path, &records, &topo).unwrap();
        let rows = load_request_log(&path).unwrap();
        assert_eq!(rows.len(), records.len());
        for (row, rec) in rows.iter().zip(&records) {
            assert_eq!(&row.to_record(), rec);
            assert_eq!(row.pool, topo.pool_for_rung(rec.config_idx));
            assert_eq!(row.latency_ms.to_bits(), (rec.finish_ms - rec.arrival_ms).to_bits());
            assert_eq!(row.class, "-", "classless run: default class");
            assert_eq!(row.deadline_ms, 0.0);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn classed_request_log_roundtrips_exactly() {
        let topo = Topology::uniform(2, 2);
        let ov = OverloadConfig::enabled();
        // Awkward floats on purpose: thirds, sevenths, subnormal-ish
        // offsets — the row must survive save → load bit-for-bit.
        let records: Vec<RequestRecord> = (0..64u64)
            .map(|id| RequestRecord {
                id,
                arrival_ms: id as f64 / 3.0,
                start_ms: id as f64 / 3.0 + 1.0 / 7.0,
                finish_ms: id as f64 / 3.0 + 1.0 / 7.0 + 0.1 * (id % 9) as f64,
                config_idx: (id % 2) as usize,
                accuracy: 0.5 + (id % 13) as f64 / 26.0,
                success: match id % 3 {
                    0 => Some(true),
                    1 => Some(false),
                    _ => None,
                },
            })
            .collect();
        let path = std::env::temp_dir().join("compass_reqlog_classed.csv");
        save_request_log_overload(&path, &records, &topo, &ov).unwrap();
        let rows = load_request_log(&path).unwrap();
        assert_eq!(rows.len(), records.len());
        for (row, rec) in rows.iter().zip(&records) {
            let want = RequestLogRow::from_record_overload(rec, &topo, &ov);
            assert_eq!(row, &want, "every column round-trips exactly");
            assert_eq!(row.class, ov.class_name(rec.id));
            assert_eq!(row.deadline_ms.to_bits(), ov.class_deadline_ms(rec.id).to_bits());
            assert_eq!(&row.to_record(), rec);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_nine_column_log_loads_with_defaults() {
        let path = std::env::temp_dir().join("compass_reqlog_legacy.csv");
        std::fs::write(
            &path,
            "id,arrival_ms,start_ms,finish_ms,rung,pool,latency_ms,accuracy,outcome\n\
             3,1.5,2.5,9.25,1,0,7.75,0.9,ok\n",
        )
        .unwrap();
        let rows = load_request_log(&path).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].id, 3);
        assert_eq!(rows[0].rung, 1);
        assert_eq!(rows[0].outcome, "ok");
        assert_eq!(rows[0].class, "-", "legacy rows default the class");
        assert_eq!(rows[0].deadline_ms, 0.0, "legacy rows carry no deadline");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn request_log_rejects_bad_header() {
        let path = std::env::temp_dir().join("compass_reqlog_bad.csv");
        std::fs::write(&path, "id,arrival_ms\n1,2.0\n").unwrap();
        assert!(load_request_log(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
