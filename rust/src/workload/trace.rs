//! Trace persistence: record and replay both *inputs* (arrival traces)
//! and *outputs* (per-request logs) of a run.
//!
//! Arrival traces are one-column CSVs (`arrival_s`), written with full
//! round-trip float precision so save → load → simulate is bit-identical
//! to the generating run (pinned by `roundtrip_is_exact`). Request logs
//! are the dataset-rows shape — one row per served request with
//! arrival/start/finish, the rung and pool that served it, latency, and
//! outcome — so a sweep cell can be archived and re-analyzed (or its
//! arrivals replayed through a different policy) without rerunning it.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::RequestRecord;
use crate::serving::Topology;
use crate::util::csv::CsvWriter;

/// Write arrivals (seconds, ascending) as a one-column CSV. Floats are
/// written with `Display` (shortest decimal that round-trips), so
/// loading reproduces the exact same bits.
pub fn save_trace(path: &Path, arrivals: &[f64]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "arrival_s")?;
    for t in arrivals {
        writeln!(w, "{t}")?;
    }
    Ok(())
}

/// Load an arrival trace; validates monotonicity.
pub fn load_trace(path: &Path) -> Result<Vec<f64>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut out = Vec::new();
    for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || (i == 0 && line == "arrival_s") {
            continue;
        }
        let t: f64 = line
            .parse()
            .with_context(|| format!("{path:?}:{}: bad arrival {line:?}", i + 1))?;
        if let Some(&prev) = out.last() {
            if t < prev {
                bail!("{path:?}:{}: arrivals must be non-decreasing", i + 1);
            }
        }
        out.push(t);
    }
    Ok(out)
}

/// One row of a request log: a [`RequestRecord`] plus the pool that the
/// serving rung routed to (derived from the run's topology at save
/// time, so the log is self-contained).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestLogRow {
    pub id: u64,
    pub arrival_ms: f64,
    pub start_ms: f64,
    pub finish_ms: f64,
    pub rung: usize,
    pub pool: usize,
    pub latency_ms: f64,
    pub accuracy: f64,
    /// `"ok"` / `"fail"` for live runs with sampled answers, `"na"` for
    /// simulations.
    pub outcome: String,
}

impl RequestLogRow {
    /// Convert a run record into a log row under `topo`'s routing.
    pub fn from_record(r: &RequestRecord, topo: &Topology) -> RequestLogRow {
        RequestLogRow {
            id: r.id,
            arrival_ms: r.arrival_ms,
            start_ms: r.start_ms,
            finish_ms: r.finish_ms,
            rung: r.config_idx,
            pool: topo.pool_for_rung(r.config_idx),
            latency_ms: r.finish_ms - r.arrival_ms,
            accuracy: r.accuracy,
            outcome: match r.success {
                Some(true) => "ok".into(),
                Some(false) => "fail".into(),
                None => "na".into(),
            },
        }
    }

    /// Back to a [`RequestRecord`] (the pool column is re-derivable from
    /// a topology, so it is dropped).
    pub fn to_record(&self) -> RequestRecord {
        RequestRecord {
            id: self.id,
            arrival_ms: self.arrival_ms,
            start_ms: self.start_ms,
            finish_ms: self.finish_ms,
            config_idx: self.rung,
            accuracy: self.accuracy,
            success: match self.outcome.as_str() {
                "ok" => Some(true),
                "fail" => Some(false),
                _ => None,
            },
        }
    }
}

const LOG_HEADER: [&str; 9] = [
    "id",
    "arrival_ms",
    "start_ms",
    "finish_ms",
    "rung",
    "pool",
    "latency_ms",
    "accuracy",
    "outcome",
];

/// Write a full request log (one row per served request, full float
/// precision) for the records of a live or simulated run.
pub fn save_request_log(path: &Path, records: &[RequestRecord], topo: &Topology) -> Result<()> {
    let mut w = CsvWriter::create(path, &LOG_HEADER)?;
    for r in records {
        let row = RequestLogRow::from_record(r, topo);
        w.row(&[
            row.id.to_string(),
            row.arrival_ms.to_string(),
            row.start_ms.to_string(),
            row.finish_ms.to_string(),
            row.rung.to_string(),
            row.pool.to_string(),
            row.latency_ms.to_string(),
            row.accuracy.to_string(),
            row.outcome.clone(),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// Load a request log saved by [`save_request_log`].
pub fn load_request_log(path: &Path) -> Result<Vec<RequestLogRow>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut out = Vec::new();
    for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split(',').collect();
        if i == 0 {
            if cols != LOG_HEADER {
                bail!("{path:?}: unexpected request-log header {line:?}");
            }
            continue;
        }
        if cols.len() != LOG_HEADER.len() {
            bail!("{path:?}:{}: expected {} columns", i + 1, LOG_HEADER.len());
        }
        let f = |j: usize| -> Result<f64> {
            cols[j]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad float {:?}", i + 1, cols[j]))
        };
        out.push(RequestLogRow {
            id: cols[0]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad id {:?}", i + 1, cols[0]))?,
            arrival_ms: f(1)?,
            start_ms: f(2)?,
            finish_ms: f(3)?,
            rung: cols[4]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad rung {:?}", i + 1, cols[4]))?,
            pool: cols[5]
                .parse()
                .with_context(|| format!("{path:?}:{}: bad pool {:?}", i + 1, cols[5]))?,
            latency_ms: f(6)?,
            accuracy: f(7)?,
            outcome: cols[8].to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_arrivals, Pattern, WorkloadSpec};

    #[test]
    fn roundtrip_is_exact() {
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 10.0,
            duration_s: 20.0,
            pattern: Pattern::paper_bursty(),
            seed: 4,
        });
        let path = std::env::temp_dir().join("compass_trace_test.csv");
        save_trace(&path, &arrivals).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(loaded.len(), arrivals.len());
        for (a, b) in loaded.iter().zip(&arrivals) {
            assert_eq!(a.to_bits(), b.to_bits(), "trace float must round-trip exactly");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_unsorted() {
        let path = std::env::temp_dir().join("compass_trace_bad.csv");
        std::fs::write(&path, "arrival_s\n1.0\n0.5\n").unwrap();
        assert!(load_trace(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("compass_trace_bad2.csv");
        std::fs::write(&path, "arrival_s\nnot-a-number\n").unwrap();
        assert!(load_trace(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn request_log_roundtrips_exactly() {
        let topo = Topology::uniform(2, 2);
        let records = vec![
            RequestRecord {
                id: 0,
                arrival_ms: 1.0 / 3.0,
                start_ms: 0.4000000000000001,
                finish_ms: 7.7,
                config_idx: 2,
                accuracy: 0.913,
                success: None,
            },
            RequestRecord {
                id: 1,
                arrival_ms: 2.25,
                start_ms: 2.25,
                finish_ms: 9.0,
                config_idx: 0,
                accuracy: 0.55,
                success: Some(true),
            },
        ];
        let path = std::env::temp_dir().join("compass_reqlog_test.csv");
        save_request_log(&path, &records, &topo).unwrap();
        let rows = load_request_log(&path).unwrap();
        assert_eq!(rows.len(), records.len());
        for (row, rec) in rows.iter().zip(&records) {
            assert_eq!(&row.to_record(), rec);
            assert_eq!(row.pool, topo.pool_for_rung(rec.config_idx));
            assert_eq!(row.latency_ms.to_bits(), (rec.finish_ms - rec.arrival_ms).to_bits());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn request_log_rejects_bad_header() {
        let path = std::env::temp_dir().join("compass_reqlog_bad.csv");
        std::fs::write(&path, "id,arrival_ms\n1,2.0\n").unwrap();
        assert!(load_request_log(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
