//! Composable scenario generators: an algebra of arrival-rate shapes
//! (`sum` / `scale` / `shift` over constant, diurnal, flash-crowd, MMPP
//! and correlated-surge primitives) compiled to a pure rate function
//! λ(t) and sampled via thinning — deterministically from a seed.
//!
//! A [`ScenarioSpec`] is the scenario-matrix analogue of
//! [`WorkloadSpec`](super::WorkloadSpec): the same thinning loop over
//! the same `util::rng` stream, so a [`Generator::Legacy`] wrapper
//! reproduces [`generate_arrivals`](super::generate_arrivals)
//! bit-for-bit, and any scenario's arrivals can be fed unchanged to
//! both the live `serve()` injector and the DES
//! `simulate_topology` (both consume `&[f64]` seconds).
//!
//! Stochastic shapes (MMPP state paths, surge windows) are
//! *materialized once* at compile time from the spec seed, so λ(t) is a
//! pure function of time and the thinning envelope is exact.

use crate::util::Rng;

use super::{Pattern, RateFn, WorkloadSpec};

/// A composable arrival-rate shape. Build with the variant literals or
/// the [`sum`](Generator::sum) / [`scale`](Generator::scale) /
/// [`shift`](Generator::shift) combinators.
#[derive(Clone, Debug)]
pub enum Generator {
    /// Constant `qps`.
    Constant { qps: f64 },
    /// Sinusoidal day-cycle around `qps`:
    /// `qps · (1 + amplitude · sin(2π (t − phase_s) / period_s))`,
    /// clamped at ≥ 0.
    Diurnal { qps: f64, amplitude: f64, period_s: f64, phase_s: f64 },
    /// Baseline `qps` with one flash crowd: a linear ramp to
    /// `peak_factor·qps` over `[at_s − ramp_s, at_s]`, a hold of
    /// `hold_s`, and a symmetric linear decay back to baseline.
    FlashCrowd { qps: f64, peak_factor: f64, at_s: f64, ramp_s: f64, hold_s: f64 },
    /// Markov-modulated Poisson process: the rate cycles through the
    /// `qps` states with exponential dwell times of the matching
    /// `mean_dwell_s` entry (state path materialized once per seed).
    Mmpp { qps: Vec<f64>, mean_dwell_s: Vec<f64> },
    /// `sources` independent clients at `qps_per_source` each, whose
    /// surges are *correlated*: shared surge windows (length uniform in
    /// `surge_s`, exponential gaps of mean `mean_gap_s`) during which
    /// every source multiplies its rate by `peak_factor` at once.
    CorrelatedSurge {
        sources: usize,
        qps_per_source: f64,
        peak_factor: f64,
        mean_gap_s: f64,
        surge_s: (f64, f64),
    },
    /// A seed-era [`Pattern`] at `base_qps` — compiles through the same
    /// [`RateFn`] as [`generate_arrivals`](super::generate_arrivals),
    /// so the bridge is bit-identical (pinned by test).
    Legacy { base_qps: f64, pattern: Pattern },
    /// Superposition: λ(t) = Σ λᵢ(t).
    Sum(Vec<Generator>),
    /// λ(t) scaled by a constant factor.
    Scale { factor: f64, inner: Box<Generator> },
    /// λ(t) delayed by `by_s` seconds (zero rate before the shift).
    Shift { by_s: f64, inner: Box<Generator> },
}

impl Generator {
    /// Superpose several generators.
    pub fn sum(parts: Vec<Generator>) -> Generator {
        Generator::Sum(parts)
    }

    /// Scale this generator's rate by `factor`.
    pub fn scale(self, factor: f64) -> Generator {
        Generator::Scale { factor, inner: Box::new(self) }
    }

    /// Delay this generator's onset by `by_s` seconds.
    pub fn shift(self, by_s: f64) -> Generator {
        Generator::Shift { by_s, inner: Box::new(self) }
    }

    /// Compile to a pure rate function over `[0, duration_s)`.
    /// Stochastic shapes draw their state paths from a master stream
    /// derived from `seed` in deterministic traversal order, so the
    /// same (generator, duration, seed) always yields the same λ(t).
    pub fn compile(&self, duration_s: f64, seed: u64) -> CompiledRate {
        let mut rng = Rng::new(seed ^ 0x5CE0_A71C);
        let node = build(self, duration_s, seed, &mut rng);
        CompiledRate { duration_s, node }
    }
}

/// A compiled, pure λ(t) — the thinning target of
/// [`ScenarioSpec::arrivals`].
pub struct CompiledRate {
    duration_s: f64,
    node: Node,
}

enum Node {
    Constant { qps: f64 },
    Diurnal { qps: f64, amplitude: f64, period_s: f64, phase_s: f64 },
    FlashCrowd { qps: f64, peak_factor: f64, at_s: f64, ramp_s: f64, hold_s: f64 },
    /// Materialized piecewise-constant rate: `base` outside the
    /// `(start, end, rate)` spans, the span's absolute rate inside.
    Piecewise { base: f64, spans: Vec<(f64, f64, f64)> },
    Legacy(RateFn),
    Sum(Vec<Node>),
    Scale { factor: f64, inner: Box<Node> },
    Shift { by_s: f64, inner: Box<Node> },
}

fn build(g: &Generator, duration_s: f64, seed: u64, rng: &mut Rng) -> Node {
    match g {
        Generator::Constant { qps } => Node::Constant { qps: *qps },
        Generator::Diurnal { qps, amplitude, period_s, phase_s } => Node::Diurnal {
            qps: *qps,
            amplitude: *amplitude,
            period_s: *period_s,
            phase_s: *phase_s,
        },
        Generator::FlashCrowd { qps, peak_factor, at_s, ramp_s, hold_s } => {
            Node::FlashCrowd {
                qps: *qps,
                peak_factor: *peak_factor,
                at_s: *at_s,
                ramp_s: *ramp_s,
                hold_s: *hold_s,
            }
        }
        Generator::Mmpp { qps, mean_dwell_s } => {
            assert!(!qps.is_empty(), "Mmpp needs at least one state");
            assert_eq!(qps.len(), mean_dwell_s.len(), "Mmpp state/dwell mismatch");
            // Materialize the alternating state path once; spans cover
            // the whole run so the base rate outside them is never used.
            let mut spans = Vec::new();
            let mut t = 0.0;
            let mut state = 0usize;
            while t < duration_s {
                let dwell = rng.exponential(1.0 / mean_dwell_s[state].max(1e-9));
                let end = (t + dwell).min(duration_s);
                spans.push((t, end, qps[state]));
                t = end;
                state = (state + 1) % qps.len();
            }
            Node::Piecewise { base: 0.0, spans }
        }
        Generator::CorrelatedSurge {
            sources,
            qps_per_source,
            peak_factor,
            mean_gap_s,
            surge_s,
        } => {
            // One shared window sequence — every source surges at once,
            // which is the whole point (independent surges average out;
            // correlated ones multiply the aggregate).
            let base = *sources as f64 * qps_per_source;
            let mut spans = Vec::new();
            let mut t = rng.exponential(1.0 / mean_gap_s.max(1e-9));
            while t < duration_s {
                let len = rng.range_f64(surge_s.0, surge_s.1);
                let end = (t + len).min(duration_s);
                spans.push((t, end, base * peak_factor));
                t = end + rng.exponential(1.0 / mean_gap_s.max(1e-9));
            }
            Node::Piecewise { base, spans }
        }
        Generator::Legacy { base_qps, pattern } => Node::Legacy(RateFn::compile(&WorkloadSpec {
            base_qps: *base_qps,
            duration_s,
            pattern: pattern.clone(),
            seed,
        })),
        Generator::Sum(parts) => {
            Node::Sum(parts.iter().map(|g| build(g, duration_s, seed, rng)).collect())
        }
        Generator::Scale { factor, inner } => Node::Scale {
            factor: *factor,
            inner: Box::new(build(inner, duration_s, seed, rng)),
        },
        Generator::Shift { by_s, inner } => Node::Shift {
            by_s: *by_s,
            inner: Box::new(build(inner, duration_s, seed, rng)),
        },
    }
}

impl CompiledRate {
    /// Instantaneous arrival rate at `t` seconds.
    pub fn rate(&self, t: f64) -> f64 {
        rate_of(&self.node, t)
    }

    /// An exact upper envelope of λ(t) over the run (thinning bound).
    pub fn rate_max(&self) -> f64 {
        max_of(&self.node)
    }

    pub fn duration_s(&self) -> f64 {
        self.duration_s
    }
}

fn rate_of(node: &Node, t: f64) -> f64 {
    match node {
        Node::Constant { qps } => *qps,
        Node::Diurnal { qps, amplitude, period_s, phase_s } => {
            let phase = 2.0 * std::f64::consts::PI * (t - phase_s) / period_s;
            (qps * (1.0 + amplitude * phase.sin())).max(0.0)
        }
        Node::FlashCrowd { qps, peak_factor, at_s, ramp_s, hold_s } => {
            let peak = peak_factor.max(1.0);
            let factor = if t < at_s - ramp_s || t >= at_s + hold_s + ramp_s {
                1.0
            } else if t < *at_s {
                // Linear ramp up.
                1.0 + (peak - 1.0) * (1.0 - (at_s - t) / ramp_s.max(1e-9))
            } else if t < at_s + hold_s {
                peak
            } else {
                // Linear decay back to baseline.
                peak - (peak - 1.0) * (t - at_s - hold_s) / ramp_s.max(1e-9)
            };
            qps * factor
        }
        Node::Piecewise { base, spans } => spans
            .iter()
            .find(|(s, e, _)| t >= *s && t < *e)
            .map(|(_, _, r)| *r)
            .unwrap_or(*base),
        Node::Legacy(rate) => rate.rate(t),
        Node::Sum(parts) => parts.iter().map(|n| rate_of(n, t)).sum(),
        Node::Scale { factor, inner } => factor * rate_of(inner, t),
        Node::Shift { by_s, inner } => {
            if t < *by_s {
                0.0
            } else {
                rate_of(inner, t - by_s)
            }
        }
    }
}

fn max_of(node: &Node) -> f64 {
    match node {
        Node::Constant { qps } => *qps,
        Node::Diurnal { qps, amplitude, .. } => qps * (1.0 + amplitude.abs()),
        Node::FlashCrowd { qps, peak_factor, .. } => qps * peak_factor.max(1.0),
        Node::Piecewise { base, spans } => {
            spans.iter().map(|(_, _, r)| *r).fold(*base, f64::max)
        }
        Node::Legacy(rate) => rate.rate_max(),
        Node::Sum(parts) => parts.iter().map(max_of).sum(),
        Node::Scale { factor, inner } => factor * max_of(inner),
        Node::Shift { inner, .. } => max_of(inner),
    }
}

/// A complete scenario: a generator shape, a run length, and the seed
/// that determines both the materialized rate path and the thinning
/// stream.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub generator: Generator,
    pub duration_s: f64,
    pub seed: u64,
}

impl ScenarioSpec {
    /// Generate arrival times (seconds, ascending) via thinning — the
    /// exact loop of [`generate_arrivals`](super::generate_arrivals)
    /// over the compiled rate, so a [`Generator::Legacy`] spec is
    /// bit-identical to the seed generator.
    pub fn arrivals(&self) -> Vec<f64> {
        let rate = self.generator.compile(self.duration_s, self.seed);
        let lam_max = rate.rate_max();
        let mut out = Vec::new();
        if lam_max <= 0.0 {
            return out;
        }
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        while t < self.duration_s {
            t += rng.exponential(lam_max);
            if t >= self.duration_s {
                break;
            }
            if rng.uniform() < rate.rate(t) / lam_max {
                out.push(t);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// SLO-class assignment (overload plane)
// ---------------------------------------------------------------------

/// The SLO class of request `id` under a weighted mix: a splitmix64
/// finalizer hashes the id to a unit uniform, and a cumulative-weight
/// walk picks the class. A *pure function of the id* — no state, no rng
/// stream — so the live executor, the DES and post-hoc log analysis all
/// assign identical classes to the same arrival sequence, and the
/// arrival stream itself is untouched (the overload plane stays
/// bit-transparent when disabled). Weights need not sum to 1; they are
/// normalized here. Empty or degenerate weights yield class 0.
pub fn class_of_id(id: u64, weights: &[f64]) -> usize {
    if weights.len() < 2 {
        return 0;
    }
    let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    if total <= 0.0 {
        return 0;
    }
    // splitmix64 finalizer: a high-quality bijective mix of the id.
    let mut x = id.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    let u = (x >> 11) as f64 / (1u64 << 53) as f64;
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w.max(0.0) / total;
        if u < acc {
            return i;
        }
    }
    weights.len() - 1
}

// ---------------------------------------------------------------------
// Statistical signatures (cookbook + tests)
// ---------------------------------------------------------------------

/// Mean arrival rate of a trace over a run of `duration_s`.
pub fn empirical_qps(arrivals: &[f64], duration_s: f64) -> f64 {
    if duration_s <= 0.0 {
        return 0.0;
    }
    arrivals.len() as f64 / duration_s
}

/// Coefficient of variation of the inter-arrival times (1 for Poisson,
/// > 1 for bursty/MMPP traffic, < 1 for smoothed traffic).
pub fn interarrival_cv(arrivals: &[f64]) -> f64 {
    let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
    if gaps.len() < 2 {
        return 0.0;
    }
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    if mean <= 0.0 {
        return 0.0;
    }
    let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
    var.sqrt() / mean
}

/// Goh–Barabási burstiness index `B = (σ − μ) / (σ + μ)` of the
/// inter-arrival times: −1 periodic, 0 Poisson, → 1 maximally bursty.
pub fn burstiness_index(arrivals: &[f64]) -> f64 {
    let cv = interarrival_cv(arrivals);
    if cv <= 0.0 {
        return -1.0;
    }
    (cv - 1.0) / (cv + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generate_arrivals;

    #[test]
    fn legacy_bridge_is_bit_identical() {
        for pattern in [Pattern::Steady, Pattern::paper_spike(), Pattern::paper_bursty()] {
            let spec = WorkloadSpec {
                base_qps: 6.0,
                duration_s: 120.0,
                pattern: pattern.clone(),
                seed: 11,
            };
            let seed_way = generate_arrivals(&spec);
            let algebra_way = ScenarioSpec {
                generator: Generator::Legacy { base_qps: 6.0, pattern },
                duration_s: 120.0,
                seed: 11,
            }
            .arrivals();
            assert_eq!(seed_way, algebra_way);
        }
    }

    #[test]
    fn sum_superposes_and_scale_scales() {
        let g = Generator::sum(vec![
            Generator::Constant { qps: 3.0 },
            Generator::Constant { qps: 2.0 }.scale(2.0),
        ]);
        let rate = g.compile(100.0, 1);
        assert!((rate.rate(50.0) - 7.0).abs() < 1e-12);
        assert!((rate.rate_max() - 7.0).abs() < 1e-12);
        let arrivals = ScenarioSpec { generator: g, duration_s: 400.0, seed: 9 }.arrivals();
        let qps = empirical_qps(&arrivals, 400.0);
        assert!((qps - 7.0).abs() < 0.6, "qps {qps}");
    }

    #[test]
    fn shift_delays_onset() {
        let g = Generator::Constant { qps: 8.0 }.shift(30.0);
        let rate = g.compile(60.0, 1);
        assert_eq!(rate.rate(10.0), 0.0);
        assert!((rate.rate(45.0) - 8.0).abs() < 1e-12);
        let arrivals = ScenarioSpec { generator: g, duration_s: 60.0, seed: 2 }.arrivals();
        assert!(!arrivals.is_empty());
        assert!(arrivals.iter().all(|&t| t >= 30.0));
    }

    #[test]
    fn flash_crowd_peak_window_is_heavier() {
        let g = Generator::FlashCrowd {
            qps: 4.0,
            peak_factor: 6.0,
            at_s: 100.0,
            ramp_s: 10.0,
            hold_s: 40.0,
        };
        let rate = g.compile(300.0, 1);
        assert!((rate.rate(20.0) - 4.0).abs() < 1e-12);
        assert!((rate.rate(120.0) - 24.0).abs() < 1e-12);
        assert!((rate.rate_max() - 24.0).abs() < 1e-12);
        let arrivals = ScenarioSpec { generator: g, duration_s: 300.0, seed: 5 }.arrivals();
        let in_hold = arrivals.iter().filter(|&&t| (100.0..140.0).contains(&t)).count();
        let before = arrivals.iter().filter(|&&t| (20.0..60.0).contains(&t)).count();
        assert!(in_hold as f64 > 3.0 * before as f64, "hold {in_hold} before {before}");
    }

    #[test]
    fn mmpp_materializes_states_deterministically() {
        let g = Generator::Mmpp { qps: vec![2.0, 12.0], mean_dwell_s: vec![15.0, 5.0] };
        let a = ScenarioSpec { generator: g.clone(), duration_s: 200.0, seed: 3 }.arrivals();
        let b = ScenarioSpec { generator: g.clone(), duration_s: 200.0, seed: 3 }.arrivals();
        assert_eq!(a, b);
        // The compiled rate visits both states.
        let rate = g.compile(200.0, 3);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..2000 {
            seen.insert(rate.rate(i as f64 * 0.1).to_bits());
        }
        assert!(seen.len() >= 2, "MMPP never left its first state");
    }

    #[test]
    fn correlated_surge_windows_are_shared() {
        let g = Generator::CorrelatedSurge {
            sources: 4,
            qps_per_source: 1.5,
            peak_factor: 5.0,
            mean_gap_s: 20.0,
            surge_s: (5.0, 10.0),
        };
        let rate = g.compile(300.0, 7);
        // Base 6 qps, surges jump the *aggregate* to 30 qps.
        assert!((rate.rate_max() - 30.0).abs() < 1e-9);
        let surged = (0..3000).any(|i| rate.rate(i as f64 * 0.1) > 29.0);
        assert!(surged, "no surge window materialized in 300 s");
    }

    #[test]
    fn zero_rate_yields_no_arrivals() {
        let g = Generator::Constant { qps: 0.0 };
        let arrivals = ScenarioSpec { generator: g, duration_s: 50.0, seed: 1 }.arrivals();
        assert!(arrivals.is_empty());
    }

    #[test]
    fn class_assignment_is_pure_and_tracks_the_weights() {
        let weights = [0.2, 0.5, 0.3];
        let n = 200_000u64;
        let mut counts = [0usize; 3];
        for id in 0..n {
            let c = class_of_id(id, &weights);
            assert_eq!(c, class_of_id(id, &weights), "pure function of the id");
            counts[c] += 1;
        }
        for (c, want) in weights.iter().enumerate() {
            let got = counts[c] as f64 / n as f64;
            assert!((got - want).abs() < 0.01, "class {c}: {got} vs {want}");
        }
        // Degenerate mixes collapse to class 0.
        assert_eq!(class_of_id(7, &[]), 0);
        assert_eq!(class_of_id(7, &[1.0]), 0);
        assert_eq!(class_of_id(7, &[0.0, 0.0]), 0);
    }

    #[test]
    fn burstiness_signatures_order_as_expected() {
        let steady = ScenarioSpec {
            generator: Generator::Constant { qps: 6.0 },
            duration_s: 600.0,
            seed: 21,
        }
        .arrivals();
        let bursty = ScenarioSpec {
            generator: Generator::Mmpp { qps: vec![1.0, 18.0], mean_dwell_s: vec![20.0, 6.0] },
            duration_s: 600.0,
            seed: 21,
        }
        .arrivals();
        let cv_steady = interarrival_cv(&steady);
        let cv_bursty = interarrival_cv(&bursty);
        assert!((cv_steady - 1.0).abs() < 0.15, "Poisson CV {cv_steady}");
        assert!(cv_bursty > cv_steady + 0.2, "MMPP CV {cv_bursty} vs {cv_steady}");
        assert!(burstiness_index(&bursty) > burstiness_index(&steady));
    }
}
