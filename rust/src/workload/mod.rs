//! Workload generation (paper §VI-C): Poisson arrivals modulated by the
//! evaluation's load patterns.
//!
//! * **Steady** — constant base rate;
//! * **Spike** — sustained 4x increase during the middle third of the
//!   run;
//! * **Bursty** — random 2–5x bursts lasting 5–15 s throughout;
//! * **Diurnal** — sinusoidal day-cycle (extension used by ablations).
//!
//! Arrival times are drawn from a non-homogeneous Poisson process via
//! thinning, deterministically from the spec's seed.
//!
//! The legacy [`WorkloadSpec`] + [`Pattern`] pair above is the paper's
//! fixed evaluation set. The scenario layer generalizes it:
//!
//! * [`gen`] — a composable [`Generator`] algebra (constant, diurnal,
//!   flash crowd, MMPP, correlated surges, `sum`/`scale`/`shift`) that
//!   compiles to a rate function and materializes arrivals through the
//!   same thinning loop, so both executors consume bit-identical
//!   arrival vectors;
//! * [`fault`] — [`FaultPlan`] failure injection (pool dark, slowdown
//!   windows, queue squeeze) applied identically live and in the DES;
//! * [`trace`] — arrival-trace and request-log record/replay.

pub mod fault;
pub mod gen;
pub mod trace;

pub use fault::{Fault, FaultPlan};
pub use gen::{burstiness_index, empirical_qps, interarrival_cv, Generator, ScenarioSpec};

use crate::util::Rng;

/// Load pattern shapes. Factors multiply the base rate.
#[derive(Clone, Debug)]
pub enum Pattern {
    Steady,
    /// `factor`x load between `start_frac` and `end_frac` of the run.
    Spike { factor: f64, start_frac: f64, end_frac: f64 },
    /// Random bursts: factor in `factor`, duration in `burst_s`, spaced
    /// by exponential gaps with mean `mean_gap_s`.
    Bursty { factor: (f64, f64), burst_s: (f64, f64), mean_gap_s: f64 },
    /// `1 + amplitude * sin(2π t / period_s)` (clamped at >= 0.05).
    Diurnal { amplitude: f64, period_s: f64 },
}

impl Pattern {
    /// The paper's spike pattern: 4x during the middle third.
    pub fn paper_spike() -> Pattern {
        Pattern::Spike { factor: 4.0, start_frac: 1.0 / 3.0, end_frac: 2.0 / 3.0 }
    }

    /// The paper's bursty pattern: 2–5x bursts of 5–15 s.
    pub fn paper_bursty() -> Pattern {
        Pattern::Bursty { factor: (2.0, 5.0), burst_s: (5.0, 15.0), mean_gap_s: 12.0 }
    }
}

/// A complete workload specification.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub base_qps: f64,
    pub duration_s: f64,
    pub pattern: Pattern,
    pub seed: u64,
}

/// Piecewise rate function λ(t) compiled from a spec (burst intervals are
/// materialized once so λ is a pure function of time).
pub struct RateFn {
    base: f64,
    duration_s: f64,
    kind: RateKind,
}

enum RateKind {
    Steady,
    Spike { factor: f64, start_s: f64, end_s: f64 },
    Bursty { bursts: Vec<(f64, f64, f64)> }, // (start, end, factor)
    Diurnal { amplitude: f64, period_s: f64 },
}

impl RateFn {
    pub fn compile(spec: &WorkloadSpec) -> RateFn {
        let kind = match &spec.pattern {
            Pattern::Steady => RateKind::Steady,
            Pattern::Spike { factor, start_frac, end_frac } => RateKind::Spike {
                factor: *factor,
                start_s: start_frac * spec.duration_s,
                end_s: end_frac * spec.duration_s,
            },
            Pattern::Bursty { factor, burst_s, mean_gap_s } => {
                let mut rng = Rng::new(spec.seed ^ 0xB0B5);
                let mut bursts = Vec::new();
                let mut t = rng.exponential(1.0 / mean_gap_s);
                while t < spec.duration_s {
                    let len = rng.range_f64(burst_s.0, burst_s.1);
                    let f = rng.range_f64(factor.0, factor.1);
                    bursts.push((t, (t + len).min(spec.duration_s), f));
                    t += len + rng.exponential(1.0 / mean_gap_s);
                }
                RateKind::Bursty { bursts }
            }
            Pattern::Diurnal { amplitude, period_s } => {
                RateKind::Diurnal { amplitude: *amplitude, period_s: *period_s }
            }
        };
        RateFn { base: spec.base_qps, duration_s: spec.duration_s, kind }
    }

    /// Instantaneous arrival rate at time `t` seconds.
    pub fn rate(&self, t: f64) -> f64 {
        let factor = match &self.kind {
            RateKind::Steady => 1.0,
            RateKind::Spike { factor, start_s, end_s } => {
                if t >= *start_s && t < *end_s {
                    *factor
                } else {
                    1.0
                }
            }
            RateKind::Bursty { bursts } => bursts
                .iter()
                .find(|(s, e, _)| t >= *s && t < *e)
                .map(|(_, _, f)| *f)
                .unwrap_or(1.0),
            RateKind::Diurnal { amplitude, period_s } => {
                (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin())
                    .max(0.05)
            }
        };
        self.base * factor
    }

    /// Max rate over the run (thinning envelope).
    pub fn rate_max(&self) -> f64 {
        let factor = match &self.kind {
            RateKind::Steady => 1.0,
            RateKind::Spike { factor, .. } => *factor,
            RateKind::Bursty { bursts } => bursts
                .iter()
                .map(|(_, _, f)| *f)
                .fold(1.0, f64::max),
            RateKind::Diurnal { amplitude, .. } => 1.0 + amplitude,
        };
        self.base * factor
    }

    pub fn duration_s(&self) -> f64 {
        self.duration_s
    }
}

/// Generate arrival times (seconds, ascending) for a spec via thinning.
pub fn generate_arrivals(spec: &WorkloadSpec) -> Vec<f64> {
    let rate = RateFn::compile(spec);
    let mut rng = Rng::new(spec.seed);
    let lam_max = rate.rate_max();
    let mut t = 0.0;
    let mut out = Vec::new();
    while t < spec.duration_s {
        t += rng.exponential(lam_max);
        if t >= spec.duration_s {
            break;
        }
        if rng.uniform() < rate.rate(t) / lam_max {
            out.push(t);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(pattern: Pattern) -> WorkloadSpec {
        WorkloadSpec { base_qps: 5.0, duration_s: 300.0, pattern, seed: 42 }
    }

    #[test]
    fn steady_rate_matches_base() {
        let arrivals = generate_arrivals(&spec(Pattern::Steady));
        let qps = arrivals.len() as f64 / 300.0;
        assert!((qps - 5.0).abs() < 0.5, "qps {qps}");
        for w in arrivals.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn spike_middle_third_is_heavier() {
        let arrivals = generate_arrivals(&spec(Pattern::paper_spike()));
        let third = 300.0 / 3.0;
        let mid = arrivals
            .iter()
            .filter(|&&t| t >= third && t < 2.0 * third)
            .count() as f64;
        let outside = arrivals.len() as f64 - mid;
        // Middle third carries 4x rate: expect mid ≈ 4/(4+2) of total.
        let frac = mid / (mid + outside);
        assert!((frac - 4.0 / 6.0).abs() < 0.08, "frac {frac}");
    }

    #[test]
    fn bursty_exceeds_base_sometimes() {
        let s = spec(Pattern::paper_bursty());
        let rate = RateFn::compile(&s);
        let has_burst = (0..3000).any(|i| rate.rate(i as f64 * 0.1) > 5.0 * 1.5);
        assert!(has_burst);
        assert!(rate.rate_max() <= 5.0 * 5.0 + 1e-9);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = generate_arrivals(&spec(Pattern::paper_bursty()));
        let b = generate_arrivals(&spec(Pattern::paper_bursty()));
        assert_eq!(a, b);
    }

    #[test]
    fn diurnal_oscillates() {
        let s = spec(Pattern::Diurnal { amplitude: 0.5, period_s: 100.0 });
        let rate = RateFn::compile(&s);
        assert!(rate.rate(25.0) > rate.rate(75.0));
    }
}
