//! COMPASS-V: feasible configuration search (paper §IV, Algorithm 1).
//!
//! The queue-driven loop pops one candidate at a time:
//!
//! * **progressive evaluation** with Wilson early stopping decides
//!   feasibility cheaply for clear-cut configurations;
//! * **feasible** configurations trigger *lateral expansion* — their
//!   grid-adjacent neighbors are enqueued (breadth-first boundary
//!   tracing; the paper's completeness analysis assumes all neighbors
//!   are explored at each expansion step);
//! * **infeasible** configurations trigger *hill-climbing* — the IDW
//!   gradient (Eq. 3) picks the most promising axis step toward the
//!   feasible region.
//!
//! Termination: every configuration is evaluated at most once and the
//! space is finite, so the loop ends after at most `|C|` iterations with
//! worst case `O(|C| * B_max)` samples (paper §IV-C).

use std::collections::{HashSet, VecDeque};

use super::budget::{progressive_evaluate_asym, BudgetSchedule};
use super::gradient::{idw_gradient, Observation};
use super::lhs::lhs_sample;
use super::trace::TracePoint;
use super::Evaluator;
use crate::configspace::{Config, ConfigSpace};
use crate::util::Rng;

/// Tunables for COMPASS-V (defaults follow the paper's setup).
#[derive(Clone, Debug)]
pub struct CompassVParams {
    /// Latin Hypercube seed count.
    pub n_init: usize,
    /// Progressive budget schedule.
    pub schedule: BudgetSchedule,
    /// Wilson critical value for the feasible decision (1.96 = 95%).
    pub z: f64,
    /// Stricter critical value for the infeasible decision: discarding a
    /// configuration is the unrecoverable error for recall, so borderline
    /// configurations escalate to the full budget instead.
    pub z_infeasible: f64,
    /// Near-miss margin: infeasible configurations with estimate within
    /// this margin of τ still trigger lateral expansion, so noise islands
    /// just across the boundary stay reachable.
    pub near_miss_margin: f64,
    /// Neighbors used for IDW gradient estimation.
    pub knn: usize,
    /// IDW power `p` in `w = d^-p`.
    pub idw_power: f64,
    /// Hill-climbing steps proposed per infeasible configuration.
    pub climb_width: usize,
    /// RNG seed (sampling on ties / LHS).
    pub seed: u64,
}

impl Default for CompassVParams {
    fn default() -> Self {
        CompassVParams {
            n_init: 16,
            schedule: BudgetSchedule::rag(),
            z: 1.96,
            z_infeasible: 2.81,
            near_miss_margin: 0.07,
            knn: 5,
            idw_power: 2.0,
            climb_width: 2,
            seed: 0xC0FFEE,
        }
    }
}

/// Output of a COMPASS-V run.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// The discovered feasible set `F` with accuracy estimates.
    pub feasible: Vec<(Config, f64)>,
    /// Number of configurations evaluated.
    pub evaluated: usize,
    /// Total accuracy-evaluation samples consumed.
    pub samples_used: u64,
    /// Anytime curve: (samples consumed, feasible found).
    pub trace: Vec<TracePoint>,
}

impl SearchResult {
    /// Savings vs the exhaustive baseline `|C| * B_max` (paper Fig. 4).
    pub fn savings_vs_exhaustive(&self, n_configs: usize, b_max: u32) -> f64 {
        let exhaustive = n_configs as u64 * b_max as u64;
        1.0 - self.samples_used as f64 / exhaustive as f64
    }
}

/// The COMPASS-V search driver.
pub struct CompassV {
    params: CompassVParams,
}

impl CompassV {
    pub fn new(params: CompassVParams) -> Self {
        CompassV { params }
    }

    /// Run Algorithm 1 over `space` at threshold `tau`.
    pub fn run<E: Evaluator + ?Sized>(
        &self,
        space: &ConfigSpace,
        tau: f64,
        evaluator: &mut E,
    ) -> SearchResult {
        let p = &self.params;
        let mut rng = Rng::new(p.seed);

        let mut queue: VecDeque<Config> = VecDeque::new();
        let mut queued: HashSet<usize> = HashSet::new();
        // Alg. 1 line 2: diverse LHS seeding.
        for cfg in lhs_sample(space, p.n_init, &mut rng) {
            queued.insert(space.flat_id(&cfg));
            queue.push_back(cfg);
        }

        let mut feasible: Vec<(Config, f64)> = Vec::new();
        let mut evaluated: Vec<Observation> = Vec::new();
        let mut samples_used: u64 = 0;
        let mut trace = vec![TracePoint { samples: 0, found: 0 }];

        while let Some(cfg) = queue.pop_front() {
            // Lines 5-10: progressive evaluation with early stopping.
            let out = progressive_evaluate_asym(
                evaluator, space, &cfg, tau, &p.schedule, p.z, p.z_infeasible,
            );
            samples_used += out.samples as u64;
            let coords = space.normalize(&cfg);
            evaluated.push((coords.clone(), out.acc));

            if out.feasible || out.acc >= tau - p.near_miss_margin {
                // Lines 13-14: record + lateral expansion (BFS boundary).
                // Near-misses expand too: a noise island just across the
                // boundary must stay reachable for 100% recall.
                if out.feasible {
                    feasible.push((cfg.clone(), out.acc));
                }
                for n in space.neighbors_step(&cfg) {
                    if queued.insert(space.flat_id(&n)) {
                        queue.push_back(n);
                    }
                }
            } else {
                // Lines 16-17: estimate gradient, climb toward feasibility.
                let grad =
                    idw_gradient(&coords, out.acc, &evaluated, p.knn, p.idw_power);
                let steps =
                    hill_climb_steps(space, &cfg, &grad, p.climb_width, &mut rng);
                for n in steps {
                    if queued.insert(space.flat_id(&n)) {
                        queue.push_back(n);
                    }
                }
            }
            trace.push(TracePoint { samples: samples_used, found: feasible.len() });
        }

        SearchResult {
            feasible,
            evaluated: evaluated.len(),
            samples_used,
            trace,
        }
    }
}

/// Propose up to `width` one-step moves ranked by predicted accuracy gain
/// `grad_i * step_i` (ascending the estimated accuracy surface). Falls
/// back to a random valid neighbor when the gradient is uninformative.
fn hill_climb_steps(
    space: &ConfigSpace,
    cfg: &Config,
    grad: &[f64],
    width: usize,
    rng: &mut Rng,
) -> Vec<Config> {
    // Candidate: (predicted gain, neighbor).
    let mut cands: Vec<(f64, Config)> = Vec::new();
    for axis in 0..space.dims() {
        for delta in [-1i64, 1] {
            let ni = cfg[axis] as i64 + delta;
            if ni < 0 || ni >= space.params[axis].len() as i64 {
                continue;
            }
            let mut n = cfg.clone();
            n[axis] = ni as usize;
            if !space.valid(&n) {
                continue;
            }
            let gain = grad[axis] * delta as f64 * space.step(axis);
            cands.push((gain, n));
        }
    }
    if cands.is_empty() {
        return vec![];
    }
    let informative = cands.iter().any(|(g, _)| *g > 1e-12);
    if informative {
        cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        cands
            .into_iter()
            .take(width)
            .filter(|(g, _)| *g > 0.0)
            .map(|(_, c)| c)
            .collect()
    } else {
        // No usable gradient yet: random exploratory step.
        let i = rng.choice_index(cands.len());
        vec![cands.swap_remove(i).1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};
    use crate::util::Rng;

    /// Deterministic synthetic landscape: acc rises with both axes.
    struct Slope {
        rng: Rng,
    }

    impl Slope {
        fn p(space: &ConfigSpace, cfg: &Config) -> f64 {
            let z = space.normalize(cfg);
            (0.15 + 0.5 * z[0] + 0.35 * z[1]).min(0.99)
        }
    }

    impl Evaluator for Slope {
        fn sample(&mut self, space: &ConfigSpace, cfg: &Config, n: u32) -> u32 {
            let p = Slope::p(space, cfg);
            (0..n).filter(|_| self.rng.bernoulli(p)).count() as u32
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new(
            "grid",
            vec![
                ParamDef::discrete("x", (0..10).collect()),
                ParamDef::discrete("y", (0..10).collect()),
            ],
            vec![],
        )
    }

    #[test]
    fn finds_feasible_region_with_full_recall() {
        let s = space();
        let tau = 0.70;
        let mut eval = Slope { rng: Rng::new(42) };
        let result = CompassV::new(CompassVParams::default()).run(&s, tau, &mut eval);

        // Ground truth from the true landscape (margin excludes borderline
        // configs whose Bernoulli estimate may legitimately flip).
        let gt: Vec<Config> = s
            .enumerate_valid()
            .into_iter()
            .filter(|c| Slope::p(&s, c) >= tau + 0.05)
            .collect();
        let found: std::collections::HashSet<usize> =
            result.feasible.iter().map(|(c, _)| s.flat_id(c)).collect();
        for c in &gt {
            assert!(
                found.contains(&s.flat_id(c)),
                "missing clearly-feasible {:?} (p={})",
                c,
                Slope::p(&s, c)
            );
        }
    }

    #[test]
    fn saves_samples_vs_exhaustive() {
        let s = space();
        let mut eval = Slope { rng: Rng::new(1) };
        let r = CompassV::new(CompassVParams::default()).run(&s, 0.9, &mut eval);
        // Feasible region is tiny; most of the space is never evaluated.
        let savings = r.savings_vs_exhaustive(s.nominal_size(), 100);
        assert!(savings > 0.5, "savings {savings}");
    }

    #[test]
    fn trace_is_monotone() {
        let s = space();
        let mut eval = Slope { rng: Rng::new(2) };
        let r = CompassV::new(CompassVParams::default()).run(&s, 0.7, &mut eval);
        for w in r.trace.windows(2) {
            assert!(w[0].samples <= w[1].samples);
            assert!(w[0].found <= w[1].found);
        }
        assert_eq!(r.trace.last().unwrap().found, r.feasible.len());
    }

    #[test]
    fn evaluates_each_config_at_most_once() {
        let s = space();
        let mut eval = Slope { rng: Rng::new(3) };
        let r = CompassV::new(CompassVParams::default()).run(&s, 0.5, &mut eval);
        assert!(r.evaluated <= s.nominal_size());
    }

    #[test]
    fn deterministic_given_seed() {
        let s = space();
        let run = || {
            let mut eval = Slope { rng: Rng::new(9) };
            CompassV::new(CompassVParams::default()).run(&s, 0.7, &mut eval)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.samples_used, b.samples_used);
        assert_eq!(a.feasible.len(), b.feasible.len());
    }
}
