//! Random-order search baseline (ablation: COMPASS-V without navigation).
//!
//! Evaluates configurations in a uniformly random order with the same
//! progressive budgeting + Wilson early stopping as COMPASS-V, but no
//! gradient guidance or lateral expansion. Useful to separate how much of
//! the savings come from early stopping vs from guided navigation.

use super::budget::{progressive_evaluate, BudgetSchedule};
use super::compass_v::SearchResult;
use super::trace::TracePoint;
use super::Evaluator;
use crate::configspace::ConfigSpace;
use crate::util::Rng;

/// Evaluate all valid configurations in random order with progressive
/// budgeting. Stops after `max_evals` configurations if given.
pub fn random_search<E: Evaluator + ?Sized>(
    space: &ConfigSpace,
    tau: f64,
    schedule: &BudgetSchedule,
    z: f64,
    seed: u64,
    max_evals: Option<usize>,
    evaluator: &mut E,
) -> SearchResult {
    let mut rng = Rng::new(seed);
    let mut order = space.enumerate_valid();
    rng.shuffle(&mut order);
    if let Some(m) = max_evals {
        order.truncate(m);
    }

    let mut feasible = Vec::new();
    let mut samples_used = 0u64;
    let mut trace = vec![TracePoint { samples: 0, found: 0 }];
    let evaluated = order.len();
    for cfg in order {
        let out = progressive_evaluate(evaluator, space, &cfg, tau, schedule, z);
        samples_used += out.samples as u64;
        if out.feasible {
            feasible.push((cfg, out.acc));
        }
        trace.push(TracePoint { samples: samples_used, found: feasible.len() });
    }
    SearchResult { feasible, evaluated, samples_used, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{Config, ParamDef};
    use crate::util::Rng;

    struct Half {
        rng: Rng,
    }

    impl Evaluator for Half {
        fn sample(&mut self, space: &ConfigSpace, cfg: &Config, n: u32) -> u32 {
            let p = if space.normalize(cfg)[0] > 0.5 { 0.95 } else { 0.05 };
            (0..n).filter(|_| self.rng.bernoulli(p)).count() as u32
        }
    }

    #[test]
    fn finds_roughly_half() {
        let s = ConfigSpace::new(
            "t",
            vec![ParamDef::discrete("x", (0..20).collect())],
            vec![],
        );
        let mut eval = Half { rng: Rng::new(4) };
        let r = random_search(
            &s,
            0.5,
            &BudgetSchedule::rag(),
            1.96,
            7,
            None,
            &mut eval,
        );
        assert_eq!(r.evaluated, 20);
        assert_eq!(r.feasible.len(), 10); // x in 10..=19: i/19 > 0.5
        // Early stopping must beat the full budget.
        assert!(r.samples_used < 20 * 100);
    }
}
