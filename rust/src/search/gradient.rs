//! Inverse-distance-weighted finite-difference gradients (paper Eq. 3).
//!
//! Compound workflows are non-differentiable, so COMPASS-V estimates a
//! pseudo-gradient at a configuration `c` by interpolating accuracy
//! differences from the `k` nearest *evaluated* configurations in the
//! normalized `[0,1]^d` space, weighting each neighbor by `d(c,n)^-p`.

/// An evaluated configuration: normalized coordinates + accuracy estimate.
pub type Observation = (Vec<f64>, f64);

/// Euclidean distance in normalized space.
pub fn distance(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Estimate the gradient at `point` (accuracy `acc`) from the evaluated
/// set. Returns one slope per dimension (0.0 where no neighbor moved on
/// that dimension).
pub fn idw_gradient(
    point: &[f64],
    acc: f64,
    evaluated: &[Observation],
    knn: usize,
    power: f64,
) -> Vec<f64> {
    let d = point.len();
    // k nearest distinct neighbors.
    let mut neigh: Vec<(f64, &Observation)> = evaluated
        .iter()
        .map(|o| (distance(point, &o.0), o))
        .filter(|(dist, _)| *dist > 1e-12)
        .collect();
    neigh.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    neigh.truncate(knn);

    let mut grad = vec![0.0; d];
    for i in 0..d {
        let mut num = 0.0;
        let mut den = 0.0;
        for (dist, (coords, nacc)) in &neigh {
            let dx = coords[i] - point[i];
            if dx.abs() < 1e-9 {
                continue; // neighbor didn't move on this axis
            }
            let w = dist.powf(-power);
            num += w * (nacc - acc) / dx;
            den += w;
        }
        if den > 0.0 {
            grad[i] = num / den;
        }
    }
    grad
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_linear_slope() {
        // acc = 2x + 0.5y: slopes should come out near (2, 0.5).
        let f = |x: f64, y: f64| 2.0 * x + 0.5 * y;
        let mut evaluated = Vec::new();
        for &x in &[0.0, 0.25, 0.5, 0.75, 1.0] {
            for &y in &[0.0, 0.5, 1.0] {
                evaluated.push((vec![x, y], f(x, y)));
            }
        }
        let p = vec![0.5, 0.5];
        let g = idw_gradient(&p, f(0.5, 0.5), &evaluated, 6, 2.0);
        assert!((g[0] - 2.0).abs() < 0.3, "gx {}", g[0]);
        assert!((g[1] - 0.5).abs() < 0.3, "gy {}", g[1]);
    }

    #[test]
    fn empty_evaluated_gives_zero() {
        let g = idw_gradient(&[0.5, 0.5], 0.3, &[], 5, 2.0);
        assert_eq!(g, vec![0.0, 0.0]);
    }

    #[test]
    fn axis_without_variation_gets_zero() {
        // All neighbors share y -> dim 1 slope must be 0.
        let evaluated = vec![
            (vec![0.0, 0.5], 0.1),
            (vec![1.0, 0.5], 0.9),
        ];
        let g = idw_gradient(&[0.5, 0.5], 0.5, &evaluated, 5, 2.0);
        assert!(g[0] > 0.5);
        assert_eq!(g[1], 0.0);
    }

    #[test]
    fn closer_neighbors_dominate() {
        // Near neighbor says slope +1, far neighbor says slope -1.
        let evaluated = vec![
            (vec![0.6], 0.6),  // dist 0.1, slope +1
            (vec![1.0], 0.0),  // dist 0.5, slope -1
        ];
        let g = idw_gradient(&[0.5], 0.5, &evaluated, 2, 2.0);
        assert!(g[0] > 0.0, "{}", g[0]);
    }

    #[test]
    fn knn_truncates() {
        let evaluated = vec![
            (vec![0.51], 1.0), // nearest: slope big positive
            (vec![0.9], 0.0),
            (vec![1.0], 0.0),
        ];
        let g1 = idw_gradient(&[0.5], 0.5, &evaluated, 1, 2.0);
        let g3 = idw_gradient(&[0.5], 0.5, &evaluated, 3, 2.0);
        // With k=1 only the huge local slope survives.
        assert!(g1[0] > g3[0]);
    }
}
