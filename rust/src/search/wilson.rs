//! Wilson score confidence intervals for Bernoulli proportions.
//!
//! COMPASS-V classifies a configuration as feasible only when the interval
//! lower bound clears τ, infeasible only when the upper bound falls below
//! it, and otherwise escalates to the next budget level (paper §IV-B,
//! "Progressive Evaluation").

/// Two-sided Wilson score interval for `successes` out of `n` trials at
/// critical value `z` (e.g. 1.96 for 95%).
pub fn wilson_interval(successes: u32, n: u32, z: f64) -> (f64, f64) {
    if n == 0 {
        return (0.0, 1.0);
    }
    let n_f = n as f64;
    let p = successes as f64 / n_f;
    let z2 = z * z;
    let denom = 1.0 + z2 / n_f;
    let center = (p + z2 / (2.0 * n_f)) / denom;
    let half = (z / denom) * (p * (1.0 - p) / n_f + z2 / (4.0 * n_f * n_f)).sqrt();
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Classification of a configuration against threshold τ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Classification {
    /// CI lower bound > τ.
    Feasible,
    /// CI upper bound < τ.
    Infeasible,
    /// Interval straddles τ: needs more samples.
    Uncertain,
}

/// Classify a (successes, n) observation against τ.
pub fn classify(successes: u32, n: u32, tau: f64, z: f64) -> Classification {
    let (lo, hi) = wilson_interval(successes, n, z);
    if lo > tau {
        Classification::Feasible
    } else if hi < tau {
        Classification::Infeasible
    } else {
        Classification::Uncertain
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_contains_point_estimate() {
        for (s, n) in [(0u32, 10u32), (5, 10), (10, 10), (37, 100)] {
            let (lo, hi) = wilson_interval(s, n, 1.96);
            let p = s as f64 / n as f64;
            assert!(lo <= p + 1e-12 && p <= hi + 1e-12, "{s}/{n}: [{lo},{hi}]");
            assert!((0.0..=1.0).contains(&lo) && (0.0..=1.0).contains(&hi));
        }
    }

    #[test]
    fn interval_shrinks_with_n() {
        let (lo1, hi1) = wilson_interval(6, 10, 1.96);
        let (lo2, hi2) = wilson_interval(60, 100, 1.96);
        let (lo3, hi3) = wilson_interval(600, 1000, 1.96);
        assert!(hi1 - lo1 > hi2 - lo2);
        assert!(hi2 - lo2 > hi3 - lo3);
    }

    #[test]
    fn known_value() {
        // Wilson 95% for 8/10: approx [0.490, 0.943].
        let (lo, hi) = wilson_interval(8, 10, 1.959964);
        assert!((lo - 0.4902).abs() < 5e-3, "lo {lo}");
        assert!((hi - 0.9433).abs() < 5e-3, "hi {hi}");
    }

    #[test]
    fn classification_boundaries() {
        assert_eq!(classify(100, 100, 0.5, 1.96), Classification::Feasible);
        assert_eq!(classify(0, 100, 0.5, 1.96), Classification::Infeasible);
        assert_eq!(classify(50, 100, 0.5, 1.96), Classification::Uncertain);
    }

    #[test]
    fn zero_trials_uncertain() {
        assert_eq!(classify(0, 0, 0.5, 1.96), Classification::Uncertain);
    }

    #[test]
    fn coverage_simulation() {
        // Empirical coverage of the 95% interval should be >= ~93%.
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        let p = 0.7;
        let n = 50u32;
        let trials = 2000;
        let mut covered = 0;
        for _ in 0..trials {
            let s = (0..n).filter(|_| rng.bernoulli(p)).count() as u32;
            let (lo, hi) = wilson_interval(s, n, 1.96);
            if lo <= p && p <= hi {
                covered += 1;
            }
        }
        assert!(covered as f64 / trials as f64 > 0.93, "coverage {covered}");
    }
}
