//! Exhaustive grid search — the paper's ground-truth baseline (§VI-B).
//!
//! Evaluates every valid configuration at the full per-configuration
//! budget `B_max`, consuming `|C| * B_max` samples. COMPASS-V's recall
//! and savings are measured against this run.

use super::Evaluator;
use crate::configspace::{Config, ConfigSpace};

/// Result of the exhaustive baseline.
#[derive(Clone, Debug)]
pub struct GridResult {
    /// Every valid configuration with its full-budget accuracy estimate.
    pub all: Vec<(Config, f64)>,
    /// Total samples consumed (`|C| * b_max`).
    pub samples_used: u64,
}

impl GridResult {
    /// The ground-truth feasible set at threshold τ.
    pub fn feasible(&self, tau: f64) -> Vec<(Config, f64)> {
        self.all
            .iter()
            .filter(|(_, a)| *a >= tau)
            .cloned()
            .collect()
    }

    /// Feasible fraction at τ (x-axis of paper Fig. 4).
    pub fn feasible_fraction(&self, tau: f64) -> f64 {
        self.feasible(tau).len() as f64 / self.all.len() as f64
    }
}

/// Evaluate every valid configuration at `b_max` samples.
pub fn grid_search<E: Evaluator + ?Sized>(
    space: &ConfigSpace,
    b_max: u32,
    evaluator: &mut E,
) -> GridResult {
    let mut all = Vec::new();
    let mut samples_used = 0u64;
    for cfg in space.enumerate_valid() {
        let s = evaluator.sample(space, &cfg, b_max);
        samples_used += b_max as u64;
        all.push((cfg, s as f64 / b_max as f64));
    }
    GridResult { all, samples_used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};

    struct StepFn;

    impl Evaluator for StepFn {
        fn sample(&mut self, space: &ConfigSpace, cfg: &Config, n: u32) -> u32 {
            // acc = 1.0 iff x >= 3, else 0.
            if space.normalize(cfg)[0] >= 0.5 {
                n
            } else {
                0
            }
        }
    }

    #[test]
    fn covers_whole_space() {
        let s = ConfigSpace::new(
            "t",
            vec![ParamDef::discrete("x", (0..7).collect())],
            vec![],
        );
        let r = grid_search(&s, 50, &mut StepFn);
        assert_eq!(r.all.len(), 7);
        assert_eq!(r.samples_used, 7 * 50);
        assert_eq!(r.feasible(0.5).len(), 4); // x in {3,4,5,6}
        assert!((r.feasible_fraction(0.5) - 4.0 / 7.0).abs() < 1e-12);
    }
}
