//! COMPASS-V feasible-configuration search (paper §IV) and baselines.
//!
//! The optimization problem (Eq. 2): find every configuration whose task
//! accuracy meets the operator threshold τ,
//! `F = { (c, Acc(c)) : c ∈ C, Acc(c) >= τ }` — *coverage* of the feasible
//! region rather than convergence to a single optimum, because runtime
//! adaptation needs a ladder of configurations to switch between.
//!
//! Components:
//! * [`lhs`] — Latin Hypercube seeding (diverse initial coverage);
//! * [`wilson`] — Wilson score intervals for progressive-budget early
//!   stopping;
//! * [`gradient`] — inverse-distance-weighted finite-difference gradient
//!   estimation over the normalized space (Eq. 3);
//! * [`compass_v`] — Algorithm 1: hill-climbing toward the feasible region,
//!   breadth-first lateral expansion inside it;
//! * [`grid`] / [`random_search`] — exhaustive and random baselines.

pub mod budget;
pub mod compass_v;
pub mod gradient;
pub mod grid;
pub mod lhs;
pub mod random_search;
pub mod trace;
pub mod wilson;

pub use budget::BudgetSchedule;
pub use compass_v::{CompassV, CompassVParams, SearchResult};
pub use grid::{grid_search, GridResult};
pub use random_search::random_search;
pub use trace::TracePoint;

use crate::configspace::{Config, ConfigSpace};

/// Source of per-configuration Bernoulli accuracy observations.
///
/// `sample(space, cfg, n)` draws `n` fresh evaluation samples (e.g. `n`
/// dataset items pushed through the workflow under `cfg`) and returns how
/// many succeeded. Implementations must be deterministic given their seed
/// and must return *fresh* draws on repeated calls (progressive budgeting
/// accumulates them).
pub trait Evaluator {
    fn sample(&mut self, space: &ConfigSpace, cfg: &Config, n: u32) -> u32;
}
