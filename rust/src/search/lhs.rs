//! Latin Hypercube Sampling over the configuration grid (paper §IV-B,
//! "Initialization"; McKay et al. 1979).
//!
//! Each axis is divided into `n` strata visited in a random permutation,
//! giving diverse coverage with few samples — this is what seeds the
//! feasible-region discovery (the `P_seed >= 1 - (1-f)^n_init` bound in
//! the paper's completeness analysis).

use crate::configspace::{Config, ConfigSpace};
use crate::util::Rng;

/// Draw up to `n` distinct valid configurations by LHS.
///
/// Invalid stratified picks are repaired by re-randomizing offending axes
/// (up to a bounded number of attempts), then deduplicated.
pub fn lhs_sample(space: &ConfigSpace, n: usize, rng: &mut Rng) -> Vec<Config> {
    assert!(n > 0);
    let d = space.dims();
    // Per-axis stratified positions: permutation of strata midpoints.
    let mut strata: Vec<Vec<f64>> = (0..d)
        .map(|_| {
            let mut s: Vec<f64> = (0..n)
                .map(|i| (i as f64 + rng.uniform()) / n as f64)
                .collect();
            rng.shuffle(&mut s);
            s
        })
        .collect();

    let mut out: Vec<Config> = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::new();
    for i in 0..n {
        let mut cfg: Config = (0..d)
            .map(|a| to_index(strata[a][i], space.params[a].len()))
            .collect();
        // Repair invalid configs by re-drawing random axes.
        let mut attempts = 0;
        while !space.valid(&cfg) && attempts < 64 {
            let axis = rng.choice_index(d);
            cfg[axis] = rng.choice_index(space.params[axis].len());
            attempts += 1;
        }
        if !space.valid(&cfg) {
            continue;
        }
        if seen.insert(space.flat_id(&cfg)) {
            out.push(cfg);
        }
    }
    // Shuffle leftovers back for reproducibility independence.
    for s in strata.iter_mut() {
        s.clear();
    }
    out
}

fn to_index(u: f64, len: usize) -> usize {
    ((u * len as f64) as usize).min(len - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::rag_space;

    #[test]
    fn samples_are_valid_and_distinct() {
        let space = rag_space();
        let mut rng = Rng::new(11);
        let samples = lhs_sample(&space, 16, &mut rng);
        assert!(!samples.is_empty());
        let ids: std::collections::HashSet<usize> =
            samples.iter().map(|c| space.flat_id(c)).collect();
        assert_eq!(ids.len(), samples.len());
        for c in &samples {
            assert!(space.valid(c));
        }
    }

    #[test]
    fn covers_axes_broadly() {
        // With n = axis length, LHS should hit most strata of each axis.
        let space = rag_space();
        let mut rng = Rng::new(5);
        let samples = lhs_sample(&space, 24, &mut rng);
        for axis in 0..space.dims() {
            let distinct: std::collections::HashSet<usize> =
                samples.iter().map(|c| c[axis]).collect();
            assert!(
                distinct.len() >= space.params[axis].len() / 2,
                "axis {axis} coverage {distinct:?}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let space = rag_space();
        let a = lhs_sample(&space, 8, &mut Rng::new(7));
        let b = lhs_sample(&space, 8, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
