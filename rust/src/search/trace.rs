//! Anytime search traces (paper Fig. 3): feasible-found vs samples used.

/// One point of the anytime curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// Total evaluation samples consumed so far.
    pub samples: u64,
    /// Feasible configurations discovered so far.
    pub found: usize,
}

/// Grid-search best/worst envelopes for the convergence plot shading.
///
/// Best case: the exhaustive search happens to evaluate every feasible
/// configuration first; worst case: it evaluates them all last. Both
/// consume the full `b_max` per configuration (the exhaustive baseline).
pub fn grid_envelope(
    n_total: usize,
    n_feasible: usize,
    b_max: u32,
) -> (Vec<TracePoint>, Vec<TracePoint>) {
    let b = b_max as u64;
    let best: Vec<TracePoint> = (0..=n_feasible)
        .map(|i| TracePoint { samples: i as u64 * b, found: i })
        .collect();
    let infeasible = (n_total - n_feasible) as u64;
    let mut worst = vec![TracePoint { samples: 0, found: 0 }];
    worst.push(TracePoint { samples: infeasible * b, found: 0 });
    worst.extend(
        (1..=n_feasible)
            .map(|i| TracePoint { samples: (infeasible + i as u64) * b, found: i }),
    );
    (best, worst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_shapes() {
        let (best, worst) = grid_envelope(100, 10, 50);
        assert_eq!(best.first().unwrap().found, 0);
        assert_eq!(best.last().unwrap().found, 10);
        assert_eq!(best.last().unwrap().samples, 500);
        assert_eq!(worst.last().unwrap().samples, 100 * 50);
        assert_eq!(worst[1].samples, 90 * 50);
        assert_eq!(worst[1].found, 0);
    }
}
