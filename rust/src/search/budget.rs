//! Progressive evaluation budgets (paper §IV-B).
//!
//! Configurations start at the first level and escalate only while their
//! Wilson interval still straddles τ — clearly (in)feasible configurations
//! stop early, which is where most of COMPASS-V's savings at extreme
//! feasible fractions come from (paper Fig. 4).

use super::wilson::{classify, Classification};
use super::Evaluator;
use crate::configspace::{Config, ConfigSpace};

/// Cumulative sample levels, e.g. `[10, 25, 50, 100]`: evaluate 10, then
/// 15 more, … up to `b_max() = 100` total.
#[derive(Clone, Debug)]
pub struct BudgetSchedule {
    pub levels: Vec<u32>,
}

impl BudgetSchedule {
    pub fn new(levels: Vec<u32>) -> Self {
        assert!(!levels.is_empty());
        assert!(levels.windows(2).all(|w| w[0] < w[1]), "levels must increase");
        BudgetSchedule { levels }
    }

    /// The paper's RAG schedule (max 100 samples).
    pub fn rag() -> Self {
        BudgetSchedule::new(vec![10, 25, 50, 100])
    }

    /// The paper's object-detection schedule (max 200 samples).
    pub fn detection() -> Self {
        BudgetSchedule::new(vec![12, 25, 50, 100, 200])
    }

    /// Maximum per-configuration budget `B_max`.
    pub fn b_max(&self) -> u32 {
        *self.levels.last().unwrap()
    }
}

/// Outcome of progressively evaluating one configuration.
#[derive(Clone, Copy, Debug)]
pub struct EvalOutcome {
    /// Point estimate â at the stopping level.
    pub acc: f64,
    /// Samples consumed for this configuration.
    pub samples: u32,
    /// Feasibility decision (`acc >= tau` fallback at `B_max`).
    pub feasible: bool,
    /// True iff the decision came from a confident CI (not the fallback).
    pub confident: bool,
}

/// Progressive evaluation with Wilson early stopping (Alg. 1 lines 5-10).
///
/// `z` guards the *feasible* decision; `z_infeasible` guards the
/// *infeasible* one. Discarding a configuration is the unrecoverable
/// error for a recall-oriented search (a false-feasible merely costs
/// later profiling), so the default infeasible gate is stricter —
/// borderline configurations escalate to the full budget, where their
/// classification agrees with the exhaustive baseline by construction
/// (identical sample streams).
#[allow(clippy::too_many_arguments)]
pub fn progressive_evaluate_asym<E: Evaluator + ?Sized>(
    evaluator: &mut E,
    space: &ConfigSpace,
    cfg: &Config,
    tau: f64,
    schedule: &BudgetSchedule,
    z: f64,
    z_infeasible: f64,
) -> EvalOutcome {
    let mut successes = 0u32;
    let mut drawn = 0u32;
    for &level in &schedule.levels {
        let extra = level - drawn;
        successes += evaluator.sample(space, cfg, extra);
        drawn = level;
        if drawn == schedule.b_max() {
            break; // final level: decide by point estimate below
        }
        if classify(successes, drawn, tau, z) == Classification::Feasible {
            return EvalOutcome {
                acc: successes as f64 / drawn as f64,
                samples: drawn,
                feasible: true,
                confident: true,
            };
        }
        if classify(successes, drawn, tau, z_infeasible) == Classification::Infeasible {
            return EvalOutcome {
                acc: successes as f64 / drawn as f64,
                samples: drawn,
                feasible: false,
                confident: true,
            };
        }
    }
    // Budget exhausted: the point estimate (matches exhaustive search).
    let acc = successes as f64 / drawn as f64;
    EvalOutcome { acc, samples: drawn, feasible: acc >= tau, confident: false }
}

/// Symmetric-z progressive evaluation (paper Alg. 1 as written).
pub fn progressive_evaluate<E: Evaluator + ?Sized>(
    evaluator: &mut E,
    space: &ConfigSpace,
    cfg: &Config,
    tau: f64,
    schedule: &BudgetSchedule,
    z: f64,
) -> EvalOutcome {
    progressive_evaluate_asym(evaluator, space, cfg, tau, schedule, z, z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};
    use crate::util::Rng;

    struct FixedP {
        p: f64,
        rng: Rng,
    }

    impl Evaluator for FixedP {
        fn sample(&mut self, _s: &ConfigSpace, _c: &Config, n: u32) -> u32 {
            (0..n).filter(|_| self.rng.bernoulli(self.p)).count() as u32
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0, 1])], vec![])
    }

    #[test]
    fn clear_cases_stop_early() {
        let s = space();
        let sched = BudgetSchedule::rag();
        let mut hi = FixedP { p: 0.95, rng: Rng::new(1) };
        let out = progressive_evaluate(&mut hi, &s, &vec![0], 0.5, &sched, 1.96);
        assert!(out.feasible && out.confident);
        assert!(out.samples <= 25, "used {}", out.samples);

        let mut lo = FixedP { p: 0.05, rng: Rng::new(2) };
        let out = progressive_evaluate(&mut lo, &s, &vec![0], 0.5, &sched, 1.96);
        assert!(!out.feasible && out.confident);
        assert!(out.samples <= 25);
    }

    #[test]
    fn borderline_exhausts_budget() {
        let s = space();
        let sched = BudgetSchedule::rag();
        let mut mid = FixedP { p: 0.5, rng: Rng::new(3) };
        let out = progressive_evaluate(&mut mid, &s, &vec![0], 0.5, &sched, 1.96);
        assert_eq!(out.samples, sched.b_max());
    }

    #[test]
    #[should_panic(expected = "increase")]
    fn rejects_bad_schedule() {
        BudgetSchedule::new(vec![10, 10]);
    }
}
