//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! `make artifacts` (build-time Python) leaves `artifacts/manifest.json`,
//! one `<model>.hlo.txt` per model, and raw little-endian f32 weight
//! blobs. This module loads the manifest, compiles every HLO on a PJRT
//! CPU client, uploads each model's weights to device buffers **once**,
//! and exposes a typed `execute` for the request path — which is
//! therefore Python-free and weight-copy-free (DESIGN.md, aot.py).
//!
//! PJRT handles are raw pointers (`!Send`), so an [`ArtifactLib`] must be
//! created inside the thread that uses it (the server worker thread,
//! the profiler, …).

pub mod manifest;

// Offline builds resolve the `xla` PJRT bindings to an in-tree stub that
// fails cleanly at client construction; swap this for `use xla;` (and a
// Cargo dependency) when the real crate is available.
#[path = "xla_stub.rs"]
mod xla;

pub use manifest::{ArtifactMeta, IoSpec, Manifest};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A typed input tensor for [`ArtifactLib::execute`].
pub enum TensorIn<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// A typed output tensor.
#[derive(Clone, Debug)]
pub enum TensorOut {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorOut::F32(v) => Ok(v),
            TensorOut::I32(_) => bail!("expected f32 output, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorOut::I32(v) => Ok(v),
            TensorOut::F32(_) => bail!("expected i32 output, got f32"),
        }
    }
}

struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident weights in manifest (argument) order.
    weights: Vec<xla::PjRtBuffer>,
    meta: ArtifactMeta,
}

/// A compiled, weight-loaded artifact library bound to one PJRT client.
pub struct ArtifactLib {
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
    dir: std::path::PathBuf,
    manifest: Manifest,
}

impl ArtifactLib {
    /// Load + compile the named artifacts (or all when `names` is None).
    ///
    /// Compiling every model takes a few seconds; serving paths load only
    /// the models their plan references.
    pub fn load(dir: &Path, names: Option<&[&str]>) -> Result<ArtifactLib> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let mut lib = ArtifactLib {
            client,
            models: HashMap::new(),
            dir: dir.to_path_buf(),
            manifest,
        };
        let all: Vec<String> = match names {
            Some(ns) => ns.iter().map(|s| s.to_string()).collect(),
            None => lib.manifest.names(),
        };
        for name in all {
            lib.ensure_loaded(&name)?;
        }
        Ok(lib)
    }

    /// The manifest backing this library.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile + upload one model if not already resident.
    pub fn ensure_loaded(&mut self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        let hlo_path = self.dir.join(&meta.hlo);
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow!("parse {hlo_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;

        // Upload weights once: raw LE f32 blob sliced per manifest params.
        let mut weights = Vec::with_capacity(meta.params.len());
        if !meta.params.is_empty() {
            let bin_rel = meta
                .weights_bin
                .as_ref()
                .ok_or_else(|| anyhow!("{name}: params without weights_bin"))?;
            let blob = std::fs::read(self.dir.join(bin_rel))
                .with_context(|| format!("reading weights for {name}"))?;
            let floats = bytes_to_f32(&blob)?;
            for p in &meta.params {
                let end = p.offset + p.numel;
                if end > floats.len() {
                    bail!("{name}: weights blob too short for {}", p.name);
                }
                let dims: Vec<usize> = if p.shape.is_empty() {
                    vec![]
                } else {
                    p.shape.clone()
                };
                let buf = self
                    .client
                    .buffer_from_host_buffer::<f32>(
                        &floats[p.offset..end],
                        &dims,
                        None,
                    )
                    .map_err(|e| anyhow!("upload {name}/{}: {e:?}", p.name))?;
                weights.push(buf);
            }
        }
        self.models.insert(name.to_string(), LoadedModel { exe, weights, meta });
        Ok(())
    }

    /// Execute a model with the given data inputs (weights are implicit).
    ///
    /// Inputs must match the manifest order/shapes; outputs come back in
    /// manifest order.
    pub fn execute(&self, name: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
        let model = self
            .models
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        if inputs.len() != model.meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                model.meta.inputs.len(),
                inputs.len()
            );
        }

        // Upload data inputs (small: tokens, queries, one image).
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (spec, t) in model.meta.inputs.iter().zip(inputs) {
            let buf = match t {
                TensorIn::F32(data, dims) => {
                    if spec.dtype != "f32" {
                        bail!("{name}/{}: expected {}, got f32", spec.name, spec.dtype);
                    }
                    self.client
                        .buffer_from_host_buffer::<f32>(data, dims, None)
                        .map_err(|e| anyhow!("input {}: {e:?}", spec.name))?
                }
                TensorIn::I32(data, dims) => {
                    if spec.dtype != "i32" {
                        bail!("{name}/{}: expected {}, got i32", spec.name, spec.dtype);
                    }
                    self.client
                        .buffer_from_host_buffer::<i32>(data, dims, None)
                        .map_err(|e| anyhow!("input {}: {e:?}", spec.name))?
                }
            };
            bufs.push(buf);
        }

        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(model.weights.len() + bufs.len());
        args.extend(model.weights.iter());
        args.extend(bufs.iter());

        let result = model
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != model.meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                model.meta.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (spec, part) in model.meta.outputs.iter().zip(parts) {
            let out = match spec.dtype.as_str() {
                "f32" => TensorOut::F32(
                    part.to_vec::<f32>()
                        .map_err(|e| anyhow!("read {name} out: {e:?}"))?,
                ),
                "i32" => TensorOut::I32(
                    part.to_vec::<i32>()
                        .map_err(|e| anyhow!("read {name} out: {e:?}"))?,
                ),
                other => bail!("{name}: unsupported output dtype {other}"),
            };
            outs.push(out);
        }
        Ok(outs)
    }

    /// Artifact metadata (panics if not loaded).
    pub fn meta(&self, name: &str) -> &ArtifactMeta {
        &self.models[name].meta
    }

    /// Names of currently loaded models.
    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

fn bytes_to_f32(blob: &[u8]) -> Result<Vec<f32>> {
    if blob.len() % 4 != 0 {
        bail!("weights blob length {} not a multiple of 4", blob.len());
    }
    Ok(blob
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Default artifacts directory (`COMPASS_ARTIFACTS` env override).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("COMPASS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_to_f32_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, 1e-8];
        let mut blob = Vec::new();
        for v in vals {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(bytes_to_f32(&blob).unwrap(), vals);
        assert!(bytes_to_f32(&blob[..5]).is_err());
    }
}
