//! `artifacts/manifest.json` schema (produced by `python -m compile.aot`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One data input or output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One weight tensor slice inside the model's weights blob.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 elements (not bytes).
    pub offset: usize,
    pub numel: usize,
}

/// One artifact entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo: String,
    pub kind: String,
    pub weights_bin: Option<String>,
    pub params: Vec<ParamSpec>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Free-form metadata from the Python side (sizes, aliases, …).
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactMeta {
    /// Integer metadata field (e.g. `seq`, `gen_len`).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key)?.as_usize()
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key)?.as_str()
    }
}

/// The full parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in arts {
            artifacts.insert(name.clone(), parse_entry(name, entry)?);
        }
        Ok(Manifest { artifacts })
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    /// All artifacts of a kind (e.g. every "generator"), sorted by name.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v
            .get("name")
            .and_then(|s| s.as_str())
            .unwrap_or_default()
            .to_string(),
        shape: v
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("io missing shape"))?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect(),
        dtype: v
            .get("dtype")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow!("io missing dtype"))?
            .to_string(),
    })
}

fn parse_entry(name: &str, entry: &Json) -> Result<ArtifactMeta> {
    let get_str = |k: &str| -> Result<String> {
        entry
            .get(k)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow!("{name}: missing {k}"))
    };
    let params = entry
        .get("params")
        .and_then(|p| p.as_arr())
        .unwrap_or(&[])
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p
                    .get("name")
                    .and_then(|s| s.as_str())
                    .unwrap_or_default()
                    .to_string(),
                shape: p
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                offset: p
                    .get("offset")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("{name}: param missing offset"))?,
                numel: p
                    .get("numel")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("{name}: param missing numel"))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ArtifactMeta {
        name: name.to_string(),
        hlo: get_str("hlo")?,
        kind: get_str("kind")?,
        weights_bin: entry
            .get("weights_bin")
            .and_then(|v| v.as_str())
            .map(str::to_string),
        params,
        inputs: entry
            .get("inputs")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(parse_io)
            .collect::<Result<Vec<_>>>()?,
        outputs: entry
            .get("outputs")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(parse_io)
            .collect::<Result<Vec<_>>>()?,
        meta: entry
            .get("meta")
            .and_then(|v| v.as_obj())
            .cloned()
            .unwrap_or_default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": {
        "gen-64": {
          "hlo": "gen-64.hlo.txt",
          "kind": "generator",
          "weights_bin": "weights/gen-64.bin",
          "meta": {"d_model": 64, "gen_len": 16, "alias": "llama3.2:1b"},
          "params": [{"name": "embed", "shape": [256, 64], "offset": 0, "numel": 16384}],
          "inputs": [{"name": "tokens", "shape": [64], "dtype": "i32"}],
          "outputs": [{"name": "gen", "shape": [16], "dtype": "i32"},
                      {"name": "score", "shape": [], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("gen-64").unwrap();
        assert_eq!(a.kind, "generator");
        assert_eq!(a.params[0].numel, 16384);
        assert_eq!(a.inputs[0].dtype, "i32");
        assert_eq!(a.outputs[1].shape.len(), 0);
        assert_eq!(a.outputs[1].numel(), 1);
        assert_eq!(a.meta_usize("gen_len"), Some(16));
        assert_eq!(a.meta_str("alias"), Some("llama3.2:1b"));
        assert_eq!(m.by_kind("generator").len(), 1);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": {"x": {"kind": "k"}}}"#).is_err());
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Integration hook: parse the actual artifacts/manifest.json when
        // artifacts have been built (skipped silently otherwise).
        let p = std::path::Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.by_kind("generator").len() >= 6);
            assert!(m.by_kind("reranker").len() >= 3);
            assert!(m.artifact("retriever").is_some());
        }
    }
}
