//! Serving metrics: per-request records, SLO compliance, reports.
//!
//! Both the live serving system ([`crate::serving`]) and the discrete-
//! event simulator ([`crate::sim`]) produce the same [`RequestRecord`]
//! stream, so every figure harness consumes one code path.

pub mod report;

use crate::util::stats::{cdf_points, Summary};

/// One completed request, in milliseconds on the run's clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    /// Arrival (enqueue) time.
    pub arrival_ms: f64,
    /// Service start time.
    pub start_ms: f64,
    /// Completion time.
    pub finish_ms: f64,
    /// Ladder index of the configuration that served it.
    pub config_idx: usize,
    /// Expected accuracy of that configuration.
    pub accuracy: f64,
    /// Live runs: whether the sampled answer was correct.
    pub success: Option<bool>,
}

impl RequestRecord {
    /// End-to-end response time (queue wait + service).
    pub fn latency_ms(&self) -> f64 {
        self.finish_ms - self.arrival_ms
    }

    /// Time spent queued.
    pub fn wait_ms(&self) -> f64 {
        self.start_ms - self.arrival_ms
    }
}

/// A configuration switch event (for the Fig. 7 timeline).
#[derive(Clone, Copy, Debug)]
pub struct SwitchEvent {
    pub at_ms: f64,
    pub from_idx: usize,
    pub to_idx: usize,
}

/// Aggregated metrics of one serving run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub requests: usize,
    pub latency: Summary,
    /// Fraction of requests with latency <= SLO.
    pub slo_compliance: f64,
    /// Mean expected accuracy of the configurations used.
    pub mean_accuracy: f64,
    /// Live-run measured success rate (None for simulations).
    pub success_rate: Option<f64>,
    /// Number of configuration switches.
    pub switches: usize,
    /// Fraction of requests served by each ladder index.
    pub config_usage: Vec<f64>,
}

impl RunSummary {
    pub fn compute(
        records: &[RequestRecord],
        switches: &[SwitchEvent],
        slo_ms: f64,
        n_configs: usize,
    ) -> RunSummary {
        let lat: Vec<f64> = records.iter().map(|r| r.latency_ms()).collect();
        let compliant = records
            .iter()
            .filter(|r| r.latency_ms() <= slo_ms)
            .count();
        let mut usage = vec![0.0; n_configs];
        for r in records {
            if r.config_idx < n_configs {
                usage[r.config_idx] += 1.0;
            }
        }
        let n = records.len().max(1) as f64;
        for u in usage.iter_mut() {
            *u /= n;
        }
        let successes: Vec<bool> =
            records.iter().filter_map(|r| r.success).collect();
        RunSummary {
            requests: records.len(),
            latency: Summary::of(&lat),
            slo_compliance: compliant as f64 / n,
            mean_accuracy: records.iter().map(|r| r.accuracy).sum::<f64>() / n,
            success_rate: if successes.is_empty() {
                None
            } else {
                Some(
                    successes.iter().filter(|s| **s).count() as f64
                        / successes.len() as f64,
                )
            },
            switches: switches.len(),
            config_usage: usage,
        }
    }
}

/// Latency CDF of a run (paper Fig. 6).
pub fn latency_cdf(records: &[RequestRecord], points: usize) -> Vec<(f64, f64)> {
    let lat: Vec<f64> = records.iter().map(|r| r.latency_ms()).collect();
    cdf_points(&lat, points)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arr: f64, start: f64, fin: f64, idx: usize) -> RequestRecord {
        RequestRecord {
            id: 0,
            arrival_ms: arr,
            start_ms: start,
            finish_ms: fin,
            config_idx: idx,
            accuracy: 0.8,
            success: None,
        }
    }

    #[test]
    fn summary_counts_compliance() {
        let records = vec![
            rec(0.0, 0.0, 50.0, 0),
            rec(0.0, 10.0, 200.0, 1),
            rec(0.0, 20.0, 90.0, 0),
        ];
        let s = RunSummary::compute(&records, &[], 100.0, 2);
        assert_eq!(s.requests, 3);
        assert!((s.slo_compliance - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.config_usage[0] - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.switches, 0);
        assert_eq!(s.success_rate, None);
    }

    #[test]
    fn success_rate_from_live_samples() {
        let mut a = rec(0.0, 0.0, 10.0, 0);
        a.success = Some(true);
        let mut b = rec(0.0, 0.0, 10.0, 0);
        b.success = Some(false);
        let s = RunSummary::compute(&[a, b], &[], 100.0, 1);
        assert_eq!(s.success_rate, Some(0.5));
    }

    #[test]
    fn record_latency_decomposition() {
        let r = rec(10.0, 30.0, 70.0, 0);
        assert_eq!(r.latency_ms(), 60.0);
        assert_eq!(r.wait_ms(), 20.0);
    }
}
