//! Console + CSV reporting for serving runs and experiments.

use std::path::Path;

use super::{RequestRecord, RunSummary, SwitchEvent};
use crate::util::csv::CsvWriter;

/// Render a run summary as a console table row.
pub fn summary_row(label: &str, s: &RunSummary) -> String {
    format!(
        "{:<18} req {:>6}  SLO {:>6.1}%  acc {:>5.3}  p50 {:>8.1}ms  p95 {:>8.1}ms  switches {:>3}",
        label,
        s.requests,
        s.slo_compliance * 100.0,
        s.mean_accuracy,
        s.latency.p50,
        s.latency.p95,
        s.switches
    )
}

/// Dump raw request records (one row per request).
pub fn write_records_csv(path: &Path, records: &[RequestRecord]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "id", "arrival_ms", "start_ms", "finish_ms", "latency_ms",
            "config_idx", "accuracy", "success",
        ],
    )?;
    for r in records {
        w.row(&[
            r.id.to_string(),
            format!("{:.3}", r.arrival_ms),
            format!("{:.3}", r.start_ms),
            format!("{:.3}", r.finish_ms),
            format!("{:.3}", r.latency_ms()),
            r.config_idx.to_string(),
            format!("{:.4}", r.accuracy),
            r.success.map(|b| b.to_string()).unwrap_or_default(),
        ])?;
    }
    w.flush()
}

/// Dump switch events (Fig. 7 timeline overlay).
pub fn write_switches_csv(path: &Path, switches: &[SwitchEvent]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(path, &["at_ms", "from_idx", "to_idx"])?;
    for s in switches {
        w.row(&[
            format!("{:.3}", s.at_ms),
            s.from_idx.to_string(),
            s.to_idx.to_string(),
        ])?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_outputs_written() {
        let dir = std::env::temp_dir().join("compass_report_test");
        let rec = RequestRecord {
            id: 1,
            arrival_ms: 0.0,
            start_ms: 1.0,
            finish_ms: 5.0,
            config_idx: 2,
            accuracy: 0.9,
            success: Some(true),
        };
        write_records_csv(&dir.join("r.csv"), &[rec]).unwrap();
        let text = std::fs::read_to_string(dir.join("r.csv")).unwrap();
        assert!(text.contains("1,0.000,1.000,5.000,5.000,2,0.9000,true"));
        write_switches_csv(
            &dir.join("s.csv"),
            &[SwitchEvent { at_ms: 3.0, from_idx: 2, to_idx: 1 }],
        )
        .unwrap();
        let text = std::fs::read_to_string(dir.join("s.csv")).unwrap();
        assert!(text.contains("3.000,2,1"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
