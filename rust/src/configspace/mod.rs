//! Compound-AI configuration spaces (paper §II-A, Eq. 1).
//!
//! A workflow exposes heterogeneous parameters (categorical model choices,
//! discrete k values, continuous thresholds quantized to grids); one
//! complete assignment is a [`Config`]. The space is the Cartesian product
//! of per-parameter value lists minus validity constraints, and induces an
//! adjacency graph (configs differing in one parameter step) over which
//! COMPASS-V hill-climbs and laterally expands.

mod space;

pub use space::{Config, ConfigSpace, Constraint, ParamDef, Value};

use crate::workflows::rag::{GENERATOR_NAMES, RERANKER_NAMES};

/// Retriever-k grid (paper: 3, 5, 10, 20, 50).
pub const RETRIEVER_KS: [i64; 5] = [3, 5, 10, 20, 50];
/// Rerank-k grid (paper: 1, 3, 5, 10).
pub const RERANK_KS: [i64; 4] = [1, 3, 5, 10];

/// The RAG workflow space (paper §VI-B): 6 generators x 5 retriever-k x
/// 4 rerank-k x 3 rerankers, constrained to `rerank_k <= retriever_k`.
pub fn rag_space() -> ConfigSpace {
    ConfigSpace::new(
        "rag",
        vec![
            ParamDef::categorical("generator", GENERATOR_NAMES.to_vec()),
            ParamDef::discrete("retriever_k", RETRIEVER_KS.to_vec()),
            ParamDef::discrete("rerank_k", RERANK_KS.to_vec()),
            ParamDef::categorical("reranker", RERANKER_NAMES.to_vec()),
        ],
        vec![Constraint::LeqNumeric { a: 2, b: 1 }], // rerank_k <= retriever_k
    )
}

/// The object-detection cascade space (paper §VI-B): 3 detectors x
/// 4 verifiers (incl. none) x 7 confidence thresholds x 5 NMS thresholds.
pub fn detection_space() -> ConfigSpace {
    let conf: Vec<f64> = (0..7).map(|i| 0.10 + i as f64 * (0.40 / 6.0)).collect();
    let nms: Vec<f64> = (0..5).map(|i| 0.30 + i as f64 * 0.10).collect();
    ConfigSpace::new(
        "detection",
        vec![
            ParamDef::categorical("detector", vec!["det-n", "det-s", "det-m"]),
            ParamDef::categorical("verifier", vec!["none", "ver-m", "ver-l", "ver-x"]),
            ParamDef::continuous_grid("conf_thr", conf),
            ParamDef::continuous_grid("nms_thr", nms),
        ],
        vec![],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rag_space_counts() {
        let s = rag_space();
        assert_eq!(s.nominal_size(), 6 * 5 * 4 * 3);
        // rerank_k <= retriever_k: k=3 -> 2 rk, k=5 -> 3, k>=10 -> 4.
        let valid = s.enumerate_valid();
        assert_eq!(valid.len(), 6 * 3 * (2 + 3 + 4 + 4 + 4));
    }

    #[test]
    fn detection_space_counts() {
        let s = detection_space();
        assert_eq!(s.nominal_size(), 3 * 4 * 7 * 5);
        assert_eq!(s.enumerate_valid().len(), 420);
    }
}
