//! Configuration-space engine: parameters, configs, adjacency, indexing.

use std::fmt;

/// A parameter value: heterogeneous types per the paper (§II-A).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
}

impl Value {
    /// Numeric view (for constraints and normalization of ordered params).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            Value::Str(_) => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x:.2}"),
        }
    }
}

/// One adjustable component parameter with its finite value grid.
#[derive(Clone, Debug)]
pub struct ParamDef {
    pub name: String,
    pub values: Vec<Value>,
}

impl ParamDef {
    pub fn categorical(name: &str, values: Vec<&str>) -> ParamDef {
        ParamDef {
            name: name.into(),
            values: values.into_iter().map(|v| Value::Str(v.into())).collect(),
        }
    }

    pub fn discrete(name: &str, values: Vec<i64>) -> ParamDef {
        ParamDef {
            name: name.into(),
            values: values.into_iter().map(Value::Int).collect(),
        }
    }

    /// A continuous parameter quantized onto an ordered grid.
    pub fn continuous_grid(name: &str, values: Vec<f64>) -> ParamDef {
        ParamDef {
            name: name.into(),
            values: values.into_iter().map(Value::Float).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// A complete parameter assignment, as one value index per parameter.
pub type Config = Vec<usize>;

/// Validity constraints between parameters.
#[derive(Clone, Debug)]
pub enum Constraint {
    /// `value[a] <= value[b]` numerically (e.g. rerank-k <= retriever-k).
    LeqNumeric { a: usize, b: usize },
}

impl Constraint {
    pub fn ok(&self, space: &ConfigSpace, cfg: &[usize]) -> bool {
        match *self {
            Constraint::LeqNumeric { a, b } => {
                let va = space.params[a].values[cfg[a]].as_f64();
                let vb = space.params[b].values[cfg[b]].as_f64();
                match (va, vb) {
                    (Some(x), Some(y)) => x <= y,
                    _ => true,
                }
            }
        }
    }
}

/// The combinatorial configuration space `C = P1 x ... x Pn` (Eq. 1).
#[derive(Clone, Debug)]
pub struct ConfigSpace {
    pub name: String,
    pub params: Vec<ParamDef>,
    pub constraints: Vec<Constraint>,
}

impl ConfigSpace {
    pub fn new(name: &str, params: Vec<ParamDef>, constraints: Vec<Constraint>) -> Self {
        assert!(!params.is_empty());
        assert!(params.iter().all(|p| !p.is_empty()));
        ConfigSpace { name: name.into(), params, constraints }
    }

    /// Number of parameters (dimensions).
    pub fn dims(&self) -> usize {
        self.params.len()
    }

    /// Size of the unconstrained product space.
    pub fn nominal_size(&self) -> usize {
        self.params.iter().map(|p| p.len()).product()
    }

    /// Does `cfg` satisfy every constraint?
    pub fn valid(&self, cfg: &[usize]) -> bool {
        self.constraints.iter().all(|c| c.ok(self, cfg))
    }

    /// All valid configurations, in flat-index order.
    pub fn enumerate_valid(&self) -> Vec<Config> {
        (0..self.nominal_size())
            .map(|id| self.from_flat(id))
            .filter(|c| self.valid(c))
            .collect()
    }

    /// Flat (row-major) index of a config — a stable hashable id.
    pub fn flat_id(&self, cfg: &[usize]) -> usize {
        debug_assert_eq!(cfg.len(), self.dims());
        let mut id = 0usize;
        for (p, &i) in self.params.iter().zip(cfg) {
            debug_assert!(i < p.len());
            id = id * p.len() + i;
        }
        id
    }

    /// Inverse of [`flat_id`].
    pub fn from_flat(&self, mut id: usize) -> Config {
        let mut cfg = vec![0usize; self.dims()];
        for (slot, p) in cfg.iter_mut().zip(&self.params).rev() {
            *slot = id % p.len();
            id /= p.len();
        }
        cfg
    }

    /// Normalized coordinates in `[0,1]^d` (paper Eq. 3 requires distance
    /// over heterogeneous types; value *index* position is used, which is
    /// exact for ordered grids and a rank encoding for categoricals).
    pub fn normalize(&self, cfg: &[usize]) -> Vec<f64> {
        cfg.iter()
            .zip(&self.params)
            .map(|(&i, p)| {
                if p.len() <= 1 {
                    0.0
                } else {
                    i as f64 / (p.len() - 1) as f64
                }
            })
            .collect()
    }

    /// Per-axis normalized step size (distance between adjacent values).
    pub fn step(&self, axis: usize) -> f64 {
        let n = self.params[axis].len();
        if n <= 1 {
            1.0
        } else {
            1.0 / (n - 1) as f64
        }
    }

    /// Grid-adjacent valid neighbors: one parameter moved one step.
    pub fn neighbors_step(&self, cfg: &[usize]) -> Vec<Config> {
        let mut out = Vec::new();
        for axis in 0..self.dims() {
            for delta in [-1i64, 1] {
                let ni = cfg[axis] as i64 + delta;
                if ni < 0 || ni >= self.params[axis].len() as i64 {
                    continue;
                }
                let mut n = cfg.to_vec();
                n[axis] = ni as usize;
                if self.valid(&n) {
                    out.push(n);
                }
            }
        }
        out
    }

    /// All valid configs differing from `cfg` in exactly the given axis.
    pub fn axis_neighbors(&self, cfg: &[usize], axis: usize) -> Vec<Config> {
        (0..self.params[axis].len())
            .filter(|&i| i != cfg[axis])
            .map(|i| {
                let mut n = cfg.to_vec();
                n[axis] = i;
                n
            })
            .filter(|n| self.valid(n))
            .collect()
    }

    /// The named value of parameter `axis` in `cfg`.
    pub fn value(&self, cfg: &[usize], axis: usize) -> &Value {
        &self.params[axis].values[cfg[axis]]
    }

    /// Look up a parameter index by name.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// The value of the named parameter in `cfg` (panics on bad name).
    pub fn named_value(&self, cfg: &[usize], name: &str) -> &Value {
        let i = self
            .param_index(name)
            .unwrap_or_else(|| panic!("no param {name}"));
        self.value(cfg, i)
    }

    /// Human-readable config tuple, e.g. `(gen-96, 10, 3, rr-48)`.
    pub fn display(&self, cfg: &[usize]) -> String {
        let parts: Vec<String> = cfg
            .iter()
            .zip(&self.params)
            .map(|(&i, p)| p.values[i].to_string())
            .collect();
        format!("({})", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ConfigSpace {
        ConfigSpace::new(
            "tiny",
            vec![
                ParamDef::categorical("m", vec!["a", "b", "c"]),
                ParamDef::discrete("k", vec![1, 2, 5]),
                ParamDef::discrete("j", vec![1, 4]),
            ],
            vec![Constraint::LeqNumeric { a: 2, b: 1 }], // j <= k
        )
    }

    #[test]
    fn flat_id_roundtrip() {
        let s = tiny();
        for id in 0..s.nominal_size() {
            assert_eq!(s.flat_id(&s.from_flat(id)), id);
        }
    }

    #[test]
    fn constraint_filters() {
        let s = tiny();
        let valid = s.enumerate_valid();
        // j=1 always ok (k>=1); j=4 needs k=5: 3 * (3 + 1) = 12.
        assert_eq!(valid.len(), 12);
        for c in &valid {
            assert!(s.valid(c));
        }
    }

    #[test]
    fn normalize_bounds() {
        let s = tiny();
        for c in s.enumerate_valid() {
            for x in s.normalize(&c) {
                assert!((0.0..=1.0).contains(&x));
            }
        }
        assert_eq!(s.normalize(&vec![0, 0, 0]), vec![0.0, 0.0, 0.0]);
        assert_eq!(s.normalize(&vec![2, 2, 1]), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn neighbors_respect_constraints() {
        let s = tiny();
        // (a, k=1, j=1): raising j to 4 violates j<=k, so not a neighbor.
        let n = s.neighbors_step(&vec![0, 0, 0]);
        assert!(n.iter().all(|c| s.valid(c)));
        assert!(!n.contains(&vec![0, 0, 1]));
        // (a, k=5, j=1) -> raising j is fine.
        let n = s.neighbors_step(&vec![0, 2, 0]);
        assert!(n.contains(&vec![0, 2, 1]));
    }

    #[test]
    fn axis_neighbors_change_one_axis() {
        let s = tiny();
        let n = s.axis_neighbors(&vec![1, 2, 0], 0);
        assert_eq!(n.len(), 2);
        for c in n {
            assert_eq!(c[1..], [2, 0]);
        }
    }

    #[test]
    fn display_readable() {
        let s = tiny();
        assert_eq!(s.display(&vec![1, 2, 0]), "(b, 5, 1)");
    }
}
