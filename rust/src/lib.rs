//! # Compass — optimizing compound AI workflows for dynamic adaptation
//!
//! A from-scratch reproduction of *Compass: Optimizing Compound AI Workflows
//! for Dynamic Adaptation* (Gravara, Herrera, Nastic — TU Wien, 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's system contribution: the
//!   [`search`] module implements COMPASS-V feasible-configuration search,
//!   [`planner`] profiles configurations and derives AQM switching policies,
//!   and [`serving`] hosts the Elastico runtime controller inside a real
//!   inference-serving loop (central queue, load monitor, executor threads).
//! * **Layer 2 / Layer 1 (build-time Python)** — JAX models with Pallas
//!   kernels, AOT-lowered to HLO text and executed through [`runtime`]
//!   (PJRT CPU via the `xla` crate). Python is never on the request path.
//!
//! The crate is fully self-contained beyond `xla` + `anyhow`: JSON, CSV,
//! RNG, statistics and the benchmark harness are all in [`util`]
//! (offline-build constraint, DESIGN.md §6).
//!
//! ## Quick tour
//!
//! ```no_run
//! use compass::configspace::rag_space;
//! use compass::oracle::RagOracle;
//! use compass::search::{CompassV, CompassVParams};
//!
//! let space = rag_space();
//! let mut oracle = RagOracle::new_rag(7);
//! let result = CompassV::new(CompassVParams::default())
//!     .run(&space, 0.75, &mut oracle);
//! println!("feasible configs: {}", result.feasible.len());
//! ```

pub mod configspace;
pub mod eval;
pub mod experiments;
pub mod metrics;
pub mod oracle;
pub mod planner;
pub mod runtime;
pub mod search;
pub mod serving;
pub mod sim;
pub mod util;
pub mod workflows;
pub mod workload;
