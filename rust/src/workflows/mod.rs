//! Compound-AI workflow executors over the AOT artifacts.
//!
//! A workflow turns one request + one configuration into real PJRT
//! compute (retriever / rerankers / generators for RAG; detector /
//! verifier CNNs for the cascade). The serving layer measures the wall
//! clock around [`Workflow::run`]; accuracy bookkeeping follows the
//! calibrated model documented in DESIGN.md §2.

pub mod detection;
pub mod rag;

use crate::configspace::{Config, ConfigSpace};

/// Result of one workflow execution (latency is measured by the caller).
#[derive(Clone, Copy, Debug)]
pub struct ExecOutcome {
    /// Expected accuracy of the configuration used.
    pub accuracy: f64,
    /// Whether this particular request succeeded (sampled/measured).
    pub success: Option<bool>,
}

/// A runnable compound workflow bound to a configuration space.
pub trait Workflow {
    /// Execute one (generated) request under `cfg`.
    fn run(&mut self, space: &ConfigSpace, cfg: &Config) -> anyhow::Result<ExecOutcome>;

    /// Workflow name (for reports).
    fn name(&self) -> &str;
}

impl<W: Workflow + ?Sized> crate::planner::ConfigRunner for W {
    fn run_once(&mut self, space: &ConfigSpace, cfg: &Config) -> f64 {
        let t0 = std::time::Instant::now();
        if let Err(e) = self.run(space, cfg) {
            panic!("workflow {} failed during profiling: {e:#}", self.name());
        }
        t0.elapsed().as_secs_f64() * 1e3
    }
}
