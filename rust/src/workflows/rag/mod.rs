//! The RAG workflow executor (paper §II-A): retriever → reranker →
//! generator, entirely over AOT artifacts on the PJRT request path.
//!
//! Request generation and accuracy accounting follow the substitution in
//! DESIGN.md §2: the harness owns a synthetic corpus with a *planted*
//! relevant document per query, so retrieval/rerank recall is **measured
//! from real compute** (the planted document competes in the real
//! similarity race and in the real cross-encoder scores), while the final
//! generation step's correctness is sampled from the calibrated
//! per-generator quality (random-weight LMs cannot answer questions).

pub mod corpus;
pub mod pipeline;

pub use corpus::Corpus;
pub use pipeline::RagWorkflow;

/// Generator artifact names, fastest to most accurate (ladder order;
/// aliases in the manifest map these to the paper's LLaMA3/Gemma3 sizes).
pub const GENERATOR_NAMES: [&str; 6] =
    ["gen-64", "gen-96", "gen-128", "gen-160", "gen-224", "gen-288"];

/// Reranker artifact names (≙ MS-MARCO, BGE-base, BGE-v2).
pub const RERANKER_NAMES: [&str; 3] = ["rr-48", "rr-96", "rr-160"];

/// Reranker keep-strength: weight of true relevance vs cross-encoder
/// score noise when ranking candidates (bigger reranker = sharper; the
/// resulting keep-probabilities track `oracle::rag::RERANK_MISS`).
pub const RERANK_ALPHA: [f64; 3] = [1.1, 1.7, 2.8];
