//! Synthetic retrieval corpus with planted ground truth.
//!
//! Each document is an embedding row plus a token sequence. A query is
//! generated *from* its ground-truth document: the query embedding is the
//! document embedding shrunk toward it plus Gaussian noise, so the
//! planted document wins the real similarity race with a probability that
//! rises with retriever-k — recall@k is measured, not assumed.

use crate::util::Rng;

/// Corpus dimensions match the retriever artifact (`retriever.hlo.txt`).
pub const CORPUS_N: usize = 256;
pub const EMBED_D: usize = 64;
pub const DOC_TOKENS: usize = 32;
pub const QUERY_TOKENS: usize = 16;
pub const VOCAB: i32 = 256;

/// The synthetic knowledge base.
pub struct Corpus {
    /// Row-major `[CORPUS_N, EMBED_D]` embeddings (unit-ish norm).
    pub embeddings: Vec<f32>,
    /// `[CORPUS_N, DOC_TOKENS]` token ids.
    pub doc_tokens: Vec<i32>,
    /// Query noise scale (full-norm distractor component).
    pub query_noise: f64,
    /// Query/doc signal strength range: each query draws its own
    /// difficulty uniformly from this interval, which smooths recall@k
    /// into the diminishing-returns curve of real retrieval
    /// (calibration target: oracle::rag::retrieval_recall; DESIGN.md §2).
    pub query_signal: (f64, f64),
}

/// One generated request.
pub struct Query {
    /// Planted relevant document id.
    pub truth: usize,
    pub embedding: Vec<f32>,
    pub tokens: Vec<i32>,
}

impl Corpus {
    /// Deterministically generate the corpus.
    pub fn generate(seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let mut embeddings = Vec::with_capacity(CORPUS_N * EMBED_D);
        for _ in 0..CORPUS_N * EMBED_D {
            embeddings.push((rng.normal() / (EMBED_D as f64).sqrt()) as f32);
        }
        let mut doc_tokens = Vec::with_capacity(CORPUS_N * DOC_TOKENS);
        for _ in 0..CORPUS_N * DOC_TOKENS {
            doc_tokens.push(rng.below(VOCAB as u64) as i32);
        }
        Corpus {
            embeddings,
            doc_tokens,
            // Calibrated so recall@k spans ~0.5 (k=3) → ~0.97+ (k=50),
            // mirroring oracle::rag::retrieval_recall.
            query_noise: 1.0,
            query_signal: (0.25, 0.55),
        }
    }

    /// Embedding row of document `i`.
    pub fn embedding(&self, i: usize) -> &[f32] {
        &self.embeddings[i * EMBED_D..(i + 1) * EMBED_D]
    }

    /// Token row of document `i`.
    pub fn tokens(&self, i: usize) -> &[i32] {
        &self.doc_tokens[i * DOC_TOKENS..(i + 1) * DOC_TOKENS]
    }

    /// Generate a query whose ground truth is a random document.
    pub fn sample_query(&self, rng: &mut Rng) -> Query {
        let truth = rng.choice_index(CORPUS_N);
        let doc = self.embedding(truth);
        // Per-query difficulty: the signal strength of the planted doc.
        let signal = rng.range_f64(self.query_signal.0, self.query_signal.1);
        let embedding: Vec<f32> = doc
            .iter()
            .map(|&x| {
                (signal * x as f64
                    + self.query_noise * rng.normal() / (EMBED_D as f64).sqrt())
                    as f32
            })
            .collect();
        // Query tokens: first half of the doc tokens with perturbations.
        let dt = self.tokens(truth);
        let tokens: Vec<i32> = (0..QUERY_TOKENS)
            .map(|j| {
                if rng.bernoulli(0.25) {
                    rng.below(VOCAB as u64) as i32
                } else {
                    dt[j % DOC_TOKENS]
                }
            })
            .collect();
        Query { truth, embedding, tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Host-side replica of the retriever scoring (dot products) used to
    /// validate recall calibration without PJRT.
    fn top_k_host(corpus: &Corpus, query: &[f32], k: usize) -> Vec<usize> {
        let mut scores: Vec<(f64, usize)> = (0..CORPUS_N)
            .map(|i| {
                let dot: f64 = corpus
                    .embedding(i)
                    .iter()
                    .zip(query)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                (dot, i)
            })
            .collect();
        scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scores.into_iter().take(k).map(|(_, i)| i).collect()
    }

    #[test]
    fn deterministic() {
        let a = Corpus::generate(3);
        let b = Corpus::generate(3);
        assert_eq!(a.embeddings, b.embeddings);
        assert_eq!(a.doc_tokens, b.doc_tokens);
    }

    #[test]
    fn recall_rises_with_k() {
        let corpus = Corpus::generate(7);
        let mut rng = Rng::new(11);
        let trials = 400;
        let mut recall = |k: usize| {
            let mut rng2 = rng.fork(k as u64);
            let hits = (0..trials)
                .filter(|_| {
                    let q = corpus.sample_query(&mut rng2);
                    top_k_host(&corpus, &q.embedding, k).contains(&q.truth)
                })
                .count();
            hits as f64 / trials as f64
        };
        let r3 = recall(3);
        let r10 = recall(10);
        let r50 = recall(50);
        assert!(r3 < r10 && r10 < r50, "{r3} {r10} {r50}");
        assert!(r3 > 0.45 && r3 < 0.90, "recall@3 {r3}");
        assert!(r50 > 0.90, "recall@50 {r50}");
    }

    #[test]
    fn query_tokens_overlap_doc() {
        let corpus = Corpus::generate(1);
        let mut rng = Rng::new(2);
        let q = corpus.sample_query(&mut rng);
        let dt = corpus.tokens(q.truth);
        let overlap = q
            .tokens
            .iter()
            .enumerate()
            .filter(|(j, t)| dt[j % DOC_TOKENS] == **t)
            .count();
        assert!(overlap >= QUERY_TOKENS / 2);
    }
}

#[cfg(test)]
mod calib_scan {
    use super::*;
    use super::tests_helpers::top_k_host_pub as top_k_host;

    #[test]
    #[ignore]
    fn scan() {
        for (lo, hi) in [(0.14, 0.42), (0.20, 0.50), (0.25, 0.55), (0.18, 0.60), (0.22, 0.65)] {
            let mut corpus = Corpus::generate(7);
            corpus.query_signal = (lo, hi);
            let mut rng = Rng::new(11);
            let trials = 600;
            let mut recall = |k: usize, rng: &mut Rng| {
                let hits = (0..trials)
                    .filter(|_| {
                        let q = corpus.sample_query(rng);
                        top_k_host(&corpus, &q.embedding, k).contains(&q.truth)
                    })
                    .count();
                hits as f64 / trials as f64
            };
            let r3 = recall(3, &mut rng);
            let r5 = recall(5, &mut rng);
            let r10 = recall(10, &mut rng);
            let r20 = recall(20, &mut rng);
            let r50 = recall(50, &mut rng);
            println!("({lo},{hi}): r3={r3:.3} r5={r5:.3} r10={r10:.3} r20={r20:.3} r50={r50:.3}");
        }
    }
}

#[cfg(test)]
pub mod tests_helpers {
    use super::*;
    pub fn top_k_host_pub(corpus: &Corpus, query: &[f32], k: usize) -> Vec<usize> {
        let mut scores: Vec<(f64, usize)> = (0..CORPUS_N)
            .map(|i| {
                let dot: f64 = corpus
                    .embedding(i)
                    .iter()
                    .zip(query)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                (dot, i)
            })
            .collect();
        scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scores.into_iter().take(k).map(|(_, i)| i).collect()
    }
}
