//! The RAG pipeline executor: retriever → reranker → generator over PJRT.

use anyhow::{anyhow, Result};

use super::corpus::{Corpus, CORPUS_N, DOC_TOKENS, EMBED_D, QUERY_TOKENS};
use super::{GENERATOR_NAMES, RERANKER_NAMES, RERANK_ALPHA};
use crate::configspace::{Config, ConfigSpace};
use crate::oracle::rag::{BACKGROUND, GEN_QUALITY};
use crate::runtime::{ArtifactLib, TensorIn};
use crate::util::Rng;
use crate::workflows::{ExecOutcome, Workflow};

/// Reranker batch size baked into the artifacts (`RERANK_BATCH`).
const RERANK_BATCH: usize = 5;
/// Generator prompt length (`SEQ`).
const PROMPT_LEN: usize = 64;

/// The live RAG workflow: real PJRT execution per stage.
pub struct RagWorkflow {
    lib: ArtifactLib,
    corpus: Corpus,
    rng: Rng,
    name: String,
}

impl RagWorkflow {
    /// Load all RAG artifacts from `dir` (retriever + rerankers +
    /// generators). `seed` drives query generation and success sampling.
    pub fn load(dir: &std::path::Path, seed: u64) -> Result<RagWorkflow> {
        let mut names: Vec<&str> = vec!["retriever"];
        names.extend(RERANKER_NAMES);
        names.extend(GENERATOR_NAMES);
        let lib = ArtifactLib::load(dir, Some(&names))?;
        Ok(RagWorkflow {
            lib,
            corpus: Corpus::generate(seed ^ 0xC0805),
            rng: Rng::new(seed),
            name: "rag".into(),
        })
    }

    /// Load only the artifacts referenced by the given ladder configs
    /// (smaller startup footprint for serving).
    pub fn load_subset(
        dir: &std::path::Path,
        space: &ConfigSpace,
        configs: &[Config],
        seed: u64,
    ) -> Result<RagWorkflow> {
        let mut names: Vec<String> = vec!["retriever".into()];
        for cfg in configs {
            names.push(space.named_value(cfg, "generator").to_string());
            names.push(space.named_value(cfg, "reranker").to_string());
        }
        names.sort();
        names.dedup();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let lib = ArtifactLib::load(dir, Some(&refs))?;
        Ok(RagWorkflow {
            lib,
            corpus: Corpus::generate(seed ^ 0xC0805),
            rng: Rng::new(seed),
            name: "rag".into(),
        })
    }

    fn resolve<'a>(
        space: &'a ConfigSpace,
        cfg: &Config,
    ) -> Result<(String, usize, usize, String, usize)> {
        let gen = space.named_value(cfg, "generator").to_string();
        let rr = space.named_value(cfg, "reranker").to_string();
        let k = space
            .named_value(cfg, "retriever_k")
            .as_f64()
            .ok_or_else(|| anyhow!("retriever_k not numeric"))? as usize;
        let rk = space
            .named_value(cfg, "rerank_k")
            .as_f64()
            .ok_or_else(|| anyhow!("rerank_k not numeric"))? as usize;
        let rr_idx = RERANKER_NAMES
            .iter()
            .position(|n| *n == rr)
            .ok_or_else(|| anyhow!("unknown reranker {rr}"))?;
        Ok((gen, k, rk, rr, rr_idx))
    }

    /// Stage 1: real top-k retrieval through the PJRT artifact.
    fn retrieve(&self, query_emb: &[f32], k: usize) -> Result<Vec<usize>> {
        let outs = self.lib.execute(
            "retriever",
            &[
                TensorIn::F32(&self.corpus.embeddings, &[CORPUS_N, EMBED_D]),
                TensorIn::F32(query_emb, &[EMBED_D]),
            ],
        )?;
        let idx = outs[1].as_i32()?;
        Ok(idx.iter().take(k).map(|&i| i as usize).collect())
    }

    /// Stage 2: rerank candidates in batches of RERANK_BATCH through the
    /// cross-encoder artifact; rank by z-scored artifact score plus the
    /// calibrated relevance prior (DESIGN.md §2).
    fn rerank(
        &mut self,
        rr: &str,
        rr_idx: usize,
        q_tokens: &[i32],
        candidates: &[usize],
        truth: usize,
        rk: usize,
    ) -> Result<Vec<usize>> {
        let mut raw_scores: Vec<f64> = Vec::with_capacity(candidates.len());
        for chunk in candidates.chunks(RERANK_BATCH) {
            // Pack a padded batch of doc token rows.
            let mut d_tokens = vec![0i32; RERANK_BATCH * DOC_TOKENS];
            for (j, &doc) in chunk.iter().enumerate() {
                d_tokens[j * DOC_TOKENS..(j + 1) * DOC_TOKENS]
                    .copy_from_slice(self.corpus.tokens(doc));
            }
            let outs = self.lib.execute(
                rr,
                &[
                    TensorIn::I32(q_tokens, &[QUERY_TOKENS]),
                    TensorIn::I32(&d_tokens, &[RERANK_BATCH, DOC_TOKENS]),
                ],
            )?;
            let scores = outs[0].as_f32()?;
            raw_scores.extend(chunk.iter().enumerate().map(|(j, _)| scores[j] as f64));
        }
        // Z-score the cross-encoder outputs within this candidate set.
        let n = raw_scores.len() as f64;
        let mean = raw_scores.iter().sum::<f64>() / n;
        let var = raw_scores.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let std = var.sqrt().max(1e-6);
        let alpha = RERANK_ALPHA[rr_idx];
        let mut ranked: Vec<(f64, usize)> = candidates
            .iter()
            .zip(&raw_scores)
            .map(|(&doc, &s)| {
                let rel = if doc == truth { 1.0 } else { 0.0 };
                ((s - mean) / std + alpha * rel, doc)
            })
            .collect();
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        Ok(ranked.into_iter().take(rk).map(|(_, d)| d).collect())
    }

    /// Stage 3: pack the prompt and run the fused generate artifact.
    fn generate(&self, gen: &str, q_tokens: &[i32], docs: &[usize]) -> Result<f64> {
        let mut prompt = vec![0i32; PROMPT_LEN];
        prompt[..QUERY_TOKENS].copy_from_slice(q_tokens);
        let mut pos = QUERY_TOKENS;
        for &doc in docs {
            let dt = self.corpus.tokens(doc);
            let take = dt.len().min(PROMPT_LEN - pos);
            prompt[pos..pos + take].copy_from_slice(&dt[..take]);
            pos += take;
            if pos >= PROMPT_LEN {
                break;
            }
        }
        let outs = self.lib.execute(gen, &[TensorIn::I32(&prompt, &[PROMPT_LEN])])?;
        let score = outs[1].as_f32()?[0] as f64;
        Ok(score)
    }
}

impl Workflow for RagWorkflow {
    fn run(&mut self, space: &ConfigSpace, cfg: &Config) -> Result<ExecOutcome> {
        let (gen, k, rk, rr, rr_idx) = Self::resolve(space, cfg)?;
        let gen_idx = GENERATOR_NAMES
            .iter()
            .position(|n| *n == gen)
            .ok_or_else(|| anyhow!("unknown generator {gen}"))?;

        let query = self.corpus.sample_query(&mut self.rng);
        let candidates = self.retrieve(&query.embedding, k)?;
        let kept = self.rerank(&rr, rr_idx, &query.tokens, &candidates, query.truth, rk)?;
        let _confidence = self.generate(&gen, &query.tokens, &kept)?;

        // Accuracy accounting (DESIGN.md §2): the *context hit* is
        // measured from the real retrieval + rerank above; the final
        // generation correctness is sampled from the calibrated
        // per-generator quality.
        let hit = kept.contains(&query.truth);
        let q = GEN_QUALITY[gen_idx];
        let p_success = if hit { q } else { q * BACKGROUND };
        let success = self.rng.bernoulli(p_success);
        Ok(ExecOutcome {
            accuracy: p_success,
            success: Some(success),
        })
    }

    fn name(&self) -> &str {
        &self.name
    }
}
