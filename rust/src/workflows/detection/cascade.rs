//! Detector → gate → verifier cascade over PJRT artifacts.

use anyhow::{anyhow, Result};

use super::{DETECTOR_NAMES, VERIFIER_NAMES};
use crate::configspace::{Config, ConfigSpace};
use crate::oracle::detection::DetectionLandscape;
use crate::oracle::Landscape;
use crate::runtime::{ArtifactLib, TensorIn};
use crate::util::stats::OnlineStats;
use crate::util::Rng;
use crate::workflows::{ExecOutcome, Workflow};

/// Image side baked into the artifacts.
const IMG: usize = 32;

/// The live detection-cascade workflow.
pub struct DetectionWorkflow {
    lib: ArtifactLib,
    rng: Rng,
    /// Per-detector online stats of the raw max logit (gate calibration).
    conf_stats: Vec<OnlineStats>,
    landscape: DetectionLandscape,
    name: String,
}

impl DetectionWorkflow {
    pub fn load(dir: &std::path::Path, seed: u64) -> Result<DetectionWorkflow> {
        let mut names: Vec<&str> = DETECTOR_NAMES.to_vec();
        names.extend(VERIFIER_NAMES.iter().filter(|n| **n != "none"));
        let lib = ArtifactLib::load(dir, Some(&names))?;
        Ok(DetectionWorkflow {
            lib,
            rng: Rng::new(seed),
            conf_stats: vec![OnlineStats::new(); DETECTOR_NAMES.len()],
            landscape: DetectionLandscape,
            name: "detection".into(),
        })
    }

    fn sample_image(&mut self) -> Vec<f32> {
        (0..IMG * IMG * 3)
            .map(|_| self.rng.normal() as f32 * 0.5)
            .collect()
    }

    /// Fraction of requests that were forwarded to the verifier so far
    /// (diagnostics; approaches the configured threshold once the gate
    /// statistics have warmed up).
    pub fn gate_stats(&self) -> &[OnlineStats] {
        &self.conf_stats
    }
}

impl Workflow for DetectionWorkflow {
    fn run(&mut self, space: &ConfigSpace, cfg: &Config) -> Result<ExecOutcome> {
        let det = space.named_value(cfg, "detector").to_string();
        let ver = space.named_value(cfg, "verifier").to_string();
        let conf_thr = space
            .named_value(cfg, "conf_thr")
            .as_f64()
            .ok_or_else(|| anyhow!("conf_thr not numeric"))?;
        let det_idx = DETECTOR_NAMES
            .iter()
            .position(|n| *n == det)
            .ok_or_else(|| anyhow!("unknown detector {det}"))?;

        let image = self.sample_image();
        let outs = self
            .lib
            .execute(&det, &[TensorIn::F32(&image, &[IMG, IMG, 3])])?;
        let conf_map = outs[0].as_f32()?;
        let raw = conf_map.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;

        // Online z-score -> sigmoid: a calibrated confidence in (0,1).
        let stats = &mut self.conf_stats[det_idx];
        stats.push(raw);
        let std = stats.std().max(1e-3);
        let z = (raw - stats.mean()) / std;
        let confidence = 1.0 / (1.0 + (-z).exp());

        // The cascade gate: below-gate predictions are re-checked by the
        // verifier. The paper sweeps conf_thr over 0.1..0.5; a centered
        // sigmoid confidence has median 0.5, so the threshold maps to the
        // gate linearly as `gate = 0.25 + 1.5 * thr` — giving the same
        // coverage curve as `oracle::detection::forwarded_fraction`, i.e.
        // higher thresholds forward more requests to the verifier.
        if ver != "none" {
            let gate = (0.25 + 1.5 * conf_thr).min(1.0);
            if confidence < gate {
                let _ = self
                    .lib
                    .execute(&ver, &[TensorIn::F32(&image, &[IMG, IMG, 3])])?;
            }
        }

        let accuracy = self.landscape.true_accuracy(space, cfg);
        let success = self.rng.bernoulli(accuracy);
        Ok(ExecOutcome { accuracy, success: Some(success) })
    }

    fn name(&self) -> &str {
        &self.name
    }
}
