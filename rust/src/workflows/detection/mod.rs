//! The object-detection cascade executor (paper §VI-B): a lightweight
//! detector screens every image; low-confidence predictions are forwarded
//! to a heavier verifier.
//!
//! The gate runs on **real compute**: the detector artifact's max cell
//! logit is z-scored online (per detector) and squashed to (0,1), and the
//! configured confidence threshold decides whether the verifier artifact
//! runs — so the fraction of requests paying the verifier cost moves with
//! the threshold exactly as in the paper's cascade. Accuracy accounting
//! uses the calibrated mAP landscape (DESIGN.md §2).

pub mod cascade;

pub use cascade::DetectionWorkflow;

/// Detector artifact names (≙ YOLOv8 n/s/m).
pub const DETECTOR_NAMES: [&str; 3] = ["det-n", "det-s", "det-m"];

/// Verifier options: none (cascade off) or a verifier artifact
/// (≙ YOLOv8 m/l/x).
pub const VERIFIER_NAMES: [&str; 4] = ["none", "ver-m", "ver-l", "ver-x"];
