//! Accuracy evaluation backends for the search layer.
//!
//! COMPASS-V consumes per-sample success/failure observations through the
//! [`Evaluator`] trait. Two backends exist:
//!
//! * the calibrated surrogate oracles in [`crate::oracle`] (fast; used by
//!   the paper-scale search experiments), and
//! * [`LiveEvaluator`] here, which pushes real requests through a live
//!   [`Workflow`] over PJRT — the "run the actual pipeline on dataset
//!   samples" path, used by the end-to-end example on small subspaces.

use crate::configspace::{Config, ConfigSpace};
use crate::search::Evaluator;
use crate::workflows::Workflow;

/// Evaluates configurations by executing the live workflow.
pub struct LiveEvaluator<W: Workflow> {
    workflow: W,
    /// Total workflow executions performed (cost accounting).
    pub executions: u64,
}

impl<W: Workflow> LiveEvaluator<W> {
    pub fn new(workflow: W) -> Self {
        LiveEvaluator { workflow, executions: 0 }
    }

    pub fn into_inner(self) -> W {
        self.workflow
    }
}

impl<W: Workflow> Evaluator for LiveEvaluator<W> {
    fn sample(&mut self, space: &ConfigSpace, cfg: &Config, n: u32) -> u32 {
        let mut successes = 0;
        for _ in 0..n {
            self.executions += 1;
            match self.workflow.run(space, cfg) {
                Ok(out) => {
                    if out.success.unwrap_or(false) {
                        successes += 1;
                    }
                }
                Err(e) => panic!("live evaluation failed: {e:#}"),
            }
        }
        successes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};
    use crate::workflows::ExecOutcome;

    struct AlwaysRight;

    impl Workflow for AlwaysRight {
        fn run(
            &mut self,
            _space: &ConfigSpace,
            _cfg: &Config,
        ) -> anyhow::Result<ExecOutcome> {
            Ok(ExecOutcome { accuracy: 1.0, success: Some(true) })
        }

        fn name(&self) -> &str {
            "always-right"
        }
    }

    #[test]
    fn counts_successes_and_executions() {
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut e = LiveEvaluator::new(AlwaysRight);
        assert_eq!(e.sample(&s, &vec![0], 25), 25);
        assert_eq!(e.executions, 25);
    }
}
