//! Pareto-front construction over (accuracy ↑, latency ↓) (paper §III-A).

use super::profiler::LatencyProfile;
use crate::configspace::Config;

/// A feasible configuration with its accuracy estimate and latency profile.
#[derive(Clone, Debug)]
pub struct ProfiledConfig {
    pub config: Config,
    /// Human-readable tuple (for tables/plots).
    pub label: String,
    pub accuracy: f64,
    pub latency: LatencyProfile,
}

impl ProfiledConfig {
    /// `self` dominates `other` if it is at least as good on both axes and
    /// strictly better on one (accuracy higher, mean latency lower).
    pub fn dominates(&self, other: &ProfiledConfig) -> bool {
        let acc_ge = self.accuracy >= other.accuracy;
        let lat_le = self.latency.mean_ms <= other.latency.mean_ms;
        let strictly = self.accuracy > other.accuracy
            || self.latency.mean_ms < other.latency.mean_ms;
        acc_ge && lat_le && strictly
    }
}

/// Keep only non-dominated configurations, ordered by increasing mean
/// service time (the AQM ladder order, Eq. 4: s̄0 < s̄1 < … < s̄n, which
/// on a Pareto front implies a0 < a1 < … < an).
pub fn pareto_front(mut configs: Vec<ProfiledConfig>) -> Vec<ProfiledConfig> {
    configs.sort_by(|a, b| {
        a.latency
            .mean_ms
            .partial_cmp(&b.latency.mean_ms)
            .unwrap()
            .then(b.accuracy.partial_cmp(&a.accuracy).unwrap())
    });
    let mut front: Vec<ProfiledConfig> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for c in configs {
        // Sorted by latency: c is non-dominated iff it improves accuracy.
        if c.accuracy > best_acc {
            best_acc = c.accuracy;
            front.push(c);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pc(acc: f64, mean: f64) -> ProfiledConfig {
        ProfiledConfig {
            config: vec![],
            label: format!("a{acc}-l{mean}"),
            accuracy: acc,
            latency: LatencyProfile {
                mean_ms: mean,
                p50_ms: mean,
                p95_ms: mean * 1.5,
                runs: 10,
            },
        }
    }

    #[test]
    fn removes_dominated() {
        let front = pareto_front(vec![
            pc(0.70, 100.0),
            pc(0.80, 50.0), // dominates the first
            pc(0.90, 200.0),
            pc(0.85, 300.0), // dominated by 0.90@200
        ]);
        let labels: Vec<&str> = front.iter().map(|c| c.label.as_str()).collect();
        assert_eq!(labels, vec!["a0.8-l50", "a0.9-l200"]);
    }

    #[test]
    fn ladder_is_ordered_both_axes() {
        let front = pareto_front(vec![
            pc(0.76, 20.0),
            pc(0.82, 45.0),
            pc(0.85, 70.0),
            pc(0.70, 30.0),
            pc(0.80, 90.0),
        ]);
        for w in front.windows(2) {
            assert!(w[0].latency.mean_ms < w[1].latency.mean_ms);
            assert!(w[0].accuracy < w[1].accuracy);
        }
    }

    #[test]
    fn dominates_is_strict() {
        let a = pc(0.8, 50.0);
        let b = pc(0.8, 50.0);
        assert!(!a.dominates(&b));
        assert!(pc(0.8, 40.0).dominates(&b));
        assert!(pc(0.9, 50.0).dominates(&b));
        assert!(!pc(0.9, 60.0).dominates(&b));
    }

    #[test]
    fn single_config_front() {
        let front = pareto_front(vec![pc(0.8, 10.0)]);
        assert_eq!(front.len(), 1);
    }
}
