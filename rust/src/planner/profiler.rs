//! Latency profiling of configurations on target hardware (paper §III-A).
//!
//! The Planner runs each feasible configuration against representative
//! inputs and records latency statistics. For LLM-bearing workflows,
//! latency varies with input/output length, so percentile profiles are
//! kept; mean latency alone suffices only for the predictable components.

use crate::configspace::{Config, ConfigSpace};
use crate::util::stats::Summary;

/// Anything that can execute one request under a configuration and
/// report its service time in milliseconds. Implemented by the live
/// workflow executors ([`crate::workflows`]) and by modeled runners used
/// in tests and simulations.
pub trait ConfigRunner {
    fn run_once(&mut self, space: &ConfigSpace, cfg: &Config) -> f64;
}

/// Latency statistics of one configuration on the target hardware.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyProfile {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub runs: usize,
}

impl LatencyProfile {
    pub fn from_samples(samples: &[f64]) -> LatencyProfile {
        let s = Summary::of(samples);
        LatencyProfile {
            mean_ms: s.mean,
            p50_ms: s.p50,
            p95_ms: s.p95,
            runs: s.count,
        }
    }
}

/// Profile a configuration with `runs` executions (plus `warmup` untimed).
pub fn profile_config<R: ConfigRunner + ?Sized>(
    runner: &mut R,
    space: &ConfigSpace,
    cfg: &Config,
    warmup: usize,
    runs: usize,
) -> LatencyProfile {
    for _ in 0..warmup {
        runner.run_once(space, cfg);
    }
    let samples: Vec<f64> = (0..runs.max(1))
        .map(|_| runner.run_once(space, cfg))
        .collect();
    LatencyProfile::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};

    struct FixedSeq {
        seq: Vec<f64>,
        i: usize,
    }

    impl ConfigRunner for FixedSeq {
        fn run_once(&mut self, _s: &ConfigSpace, _c: &Config) -> f64 {
            let v = self.seq[self.i % self.seq.len()];
            self.i += 1;
            v
        }
    }

    #[test]
    fn profile_reflects_samples() {
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut r = FixedSeq { seq: vec![10.0, 20.0, 30.0, 40.0], i: 0 };
        let p = profile_config(&mut r, &s, &vec![0], 0, 4);
        assert_eq!(p.runs, 4);
        assert!((p.mean_ms - 25.0).abs() < 1e-12);
        assert!(p.p95_ms >= p.p50_ms);
    }

    #[test]
    fn warmup_consumes_runs() {
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut r = FixedSeq { seq: vec![100.0, 10.0, 10.0], i: 0 };
        // warmup=1 skips the cold 100ms run.
        let p = profile_config(&mut r, &s, &vec![0], 1, 2);
        assert!((p.mean_ms - 10.0).abs() < 1e-12);
    }
}
