//! Latency profiling of configurations on target hardware (paper §III-A).
//!
//! The Planner runs each feasible configuration against representative
//! inputs and records latency statistics. For LLM-bearing workflows,
//! latency varies with input/output length, so percentile profiles are
//! kept; mean latency alone suffices only for the predictable components.

use crate::configspace::{Config, ConfigSpace};
use crate::util::stats::Summary;

/// Anything that can execute one request under a configuration and
/// report its service time in milliseconds. Implemented by the live
/// workflow executors ([`crate::workflows`]) and by modeled runners used
/// in tests and simulations.
pub trait ConfigRunner {
    fn run_once(&mut self, space: &ConfigSpace, cfg: &Config) -> f64;

    /// Execute one *batch* of `n` requests in a single dispatch and
    /// report the total batch wall time (ms). The default issues `n`
    /// independent dispatches — no amortization — so a runner without a
    /// real batched path fits `α ≈ 0` honestly. Batch-capable runners
    /// (a live engine with one call setup per batch) override this.
    fn run_batch(&mut self, space: &ConfigSpace, cfg: &Config, n: usize) -> f64 {
        (0..n.max(1)).map(|_| self.run_once(space, cfg)).sum()
    }
}

/// The batch service-time model `s̄(B) = α + β·B`: `α` is the
/// per-dispatch fixed cost, `β` the per-item marginal cost, both in ms.
/// Fit from measured batch timings by [`fit_batch_model`]; consumed by
/// the AQM threshold derivation
/// ([`crate::planner::AqmParams::with_batch`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchServiceModel {
    pub alpha_ms: f64,
    pub beta_ms: f64,
}

impl BatchServiceModel {
    /// Predicted batch service time s̄(B) for a batch of `b` requests.
    pub fn batch_ms(&self, b: usize) -> f64 {
        self.alpha_ms + self.beta_ms * b.max(1) as f64
    }

    /// Effective per-request service time s̄(B)/B at batch bound `b`.
    pub fn per_request_ms(&self, b: usize) -> f64 {
        self.batch_ms(b) / b.max(1) as f64
    }

    /// Ordinary least squares over `(batch size, measured batch ms)`
    /// points, with `α` clamped to be non-negative (a negative intercept
    /// is measurement noise, not a real dispatch credit). Needs at least
    /// two distinct batch sizes; with fewer it degenerates to `α = 0`,
    /// `β = mean per-request time`.
    pub fn fit(points: &[(usize, f64)]) -> BatchServiceModel {
        let n = points.len() as f64;
        let distinct = {
            let mut sizes: Vec<usize> = points.iter().map(|p| p.0).collect();
            sizes.sort_unstable();
            sizes.dedup();
            sizes.len()
        };
        if distinct < 2 {
            let beta = points
                .iter()
                .map(|&(b, y)| y / b.max(1) as f64)
                .sum::<f64>()
                / n.max(1.0);
            return BatchServiceModel { alpha_ms: 0.0, beta_ms: beta.max(0.0) };
        }
        let xbar = points.iter().map(|p| p.0 as f64).sum::<f64>() / n;
        let ybar = points.iter().map(|p| p.1).sum::<f64>() / n;
        let sxy: f64 = points
            .iter()
            .map(|&(x, y)| (x as f64 - xbar) * (y - ybar))
            .sum();
        let sxx: f64 = points
            .iter()
            .map(|&(x, _)| (x as f64 - xbar).powi(2))
            .sum();
        let beta = (sxy / sxx).max(0.0);
        let alpha = (ybar - beta * xbar).max(0.0);
        BatchServiceModel { alpha_ms: alpha, beta_ms: beta }
    }
}

/// The batch sizes the Planner profiles to fit `s̄(B) = α + β·B`.
pub const BATCH_PROFILE_SIZES: [usize; 3] = [1, 4, 8];

/// Fit the batch service-time model for one configuration: run `reps`
/// batches at each size in `sizes` (after one warmup batch per size),
/// average the batch wall times, and least-squares `s̄(B) = α + β·B`
/// over the `(size, mean batch ms)` points.
pub fn fit_batch_model<R: ConfigRunner + ?Sized>(
    runner: &mut R,
    space: &ConfigSpace,
    cfg: &Config,
    sizes: &[usize],
    reps: usize,
) -> BatchServiceModel {
    let mut points = Vec::with_capacity(sizes.len());
    for &b in sizes {
        runner.run_batch(space, cfg, b); // warmup
        let mean = (0..reps.max(1))
            .map(|_| runner.run_batch(space, cfg, b))
            .sum::<f64>()
            / reps.max(1) as f64;
        points.push((b, mean));
    }
    BatchServiceModel::fit(&points)
}

/// Latency statistics of one configuration on the target hardware.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyProfile {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub runs: usize,
}

impl LatencyProfile {
    pub fn from_samples(samples: &[f64]) -> LatencyProfile {
        let s = Summary::of(samples);
        LatencyProfile {
            mean_ms: s.mean,
            p50_ms: s.p50,
            p95_ms: s.p95,
            runs: s.count,
        }
    }

    /// The same profile on hardware `factor`x slower than the profiled
    /// reference (every quantile of a scaled random variable scales with
    /// it). Used by the per-pool AQM derivation to project a reference
    /// profile onto a pool's `speed_factor`; `factor == 1.0` is the
    /// identity bit-for-bit.
    pub fn scaled(&self, factor: f64) -> LatencyProfile {
        LatencyProfile {
            mean_ms: self.mean_ms * factor,
            p50_ms: self.p50_ms * factor,
            p95_ms: self.p95_ms * factor,
            runs: self.runs,
        }
    }
}

/// Profile a configuration with `runs` executions (plus `warmup` untimed).
pub fn profile_config<R: ConfigRunner + ?Sized>(
    runner: &mut R,
    space: &ConfigSpace,
    cfg: &Config,
    warmup: usize,
    runs: usize,
) -> LatencyProfile {
    for _ in 0..warmup {
        runner.run_once(space, cfg);
    }
    let samples: Vec<f64> = (0..runs.max(1))
        .map(|_| runner.run_once(space, cfg))
        .collect();
    LatencyProfile::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};

    struct FixedSeq {
        seq: Vec<f64>,
        i: usize,
    }

    impl ConfigRunner for FixedSeq {
        fn run_once(&mut self, _s: &ConfigSpace, _c: &Config) -> f64 {
            let v = self.seq[self.i % self.seq.len()];
            self.i += 1;
            v
        }
    }

    #[test]
    fn profile_reflects_samples() {
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut r = FixedSeq { seq: vec![10.0, 20.0, 30.0, 40.0], i: 0 };
        let p = profile_config(&mut r, &s, &vec![0], 0, 4);
        assert_eq!(p.runs, 4);
        assert!((p.mean_ms - 25.0).abs() < 1e-12);
        assert!(p.p95_ms >= p.p50_ms);
    }

    #[test]
    fn warmup_consumes_runs() {
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut r = FixedSeq { seq: vec![100.0, 10.0, 10.0], i: 0 };
        // warmup=1 skips the cold 100ms run.
        let p = profile_config(&mut r, &s, &vec![0], 1, 2);
        assert!((p.mean_ms - 10.0).abs() < 1e-12);
    }

    /// Scripted batch runner with an exact α + β·B cost.
    struct AffineBatch {
        alpha: f64,
        beta: f64,
    }

    impl ConfigRunner for AffineBatch {
        fn run_once(&mut self, _s: &ConfigSpace, _c: &Config) -> f64 {
            self.alpha + self.beta
        }
        fn run_batch(&mut self, _s: &ConfigSpace, _c: &Config, n: usize) -> f64 {
            self.alpha + self.beta * n.max(1) as f64
        }
    }

    #[test]
    fn batch_fit_recovers_alpha_and_beta_exactly() {
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut r = AffineBatch { alpha: 7.5, beta: 2.25 };
        let m = fit_batch_model(&mut r, &s, &vec![0], &BATCH_PROFILE_SIZES, 3);
        assert!((m.alpha_ms - 7.5).abs() < 1e-9, "α {}", m.alpha_ms);
        assert!((m.beta_ms - 2.25).abs() < 1e-9, "β {}", m.beta_ms);
        assert!((m.batch_ms(8) - (7.5 + 18.0)).abs() < 1e-9);
        assert!((m.per_request_ms(1) - 9.75).abs() < 1e-9);
    }

    #[test]
    fn unbatched_runner_fits_zero_alpha() {
        // The default run_batch loops run_once: s̄(B) = B·s̄(1) exactly,
        // so the fit must report no amortizable fixed cost.
        let s = ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0])], vec![]);
        let mut r = FixedSeq { seq: vec![12.0], i: 0 };
        let m = fit_batch_model(&mut r, &s, &vec![0], &BATCH_PROFILE_SIZES, 2);
        assert!(m.alpha_ms.abs() < 1e-9, "α {}", m.alpha_ms);
        assert!((m.beta_ms - 12.0).abs() < 1e-9, "β {}", m.beta_ms);
    }

    #[test]
    fn scaled_profile_scales_every_quantile() {
        let p = LatencyProfile { mean_ms: 20.0, p50_ms: 19.0, p95_ms: 30.0, runs: 7 };
        let s = p.scaled(2.5);
        assert!((s.mean_ms - 50.0).abs() < 1e-12);
        assert!((s.p50_ms - 47.5).abs() < 1e-12);
        assert!((s.p95_ms - 75.0).abs() < 1e-12);
        assert_eq!(s.runs, 7);
        assert_eq!(p.scaled(1.0), p, "unit factor is the identity");
    }

    #[test]
    fn fit_clamps_negative_intercepts_to_zero() {
        // Sub-linear batch costs (y = B·β − c) would fit α < 0; the
        // model clamps to 0 rather than crediting dispatch time.
        let m = BatchServiceModel::fit(&[(1, 1.0), (4, 7.0), (8, 15.0)]);
        assert_eq!(m.alpha_ms, 0.0);
        assert!(m.beta_ms > 0.0);
    }
}
