//! The deployment plan: Pareto ladder + switching policies, serializable
//! to JSON so `compass plan` output can be fed to `compass serve`.

use std::collections::BTreeMap;

use crate::configspace::Config;
use crate::serving::pool::PoolSpec;
use crate::util::json::Json;

/// One rung of the Pareto ladder with its AQM thresholds.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigPolicy {
    pub label: String,
    pub config: Config,
    pub accuracy: f64,
    pub mean_ms: f64,
    pub p95_ms: f64,
    /// Queuing slack Δk = L - s95_k (ms).
    pub queue_slack_ms: f64,
    /// N↑k: switch to the faster rung when queue depth exceeds this.
    pub upscale_threshold: u64,
    /// N↓k: may switch to the slower (more accurate) rung k+1 when queue
    /// depth is below this. None on the most accurate rung.
    pub downscale_threshold: Option<u64>,
}

/// A complete switching plan for one (hardware, SLO) deployment.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub slo_ms: f64,
    pub slack_buffer_ms: f64,
    pub up_cooldown_ms: f64,
    pub down_cooldown_ms: f64,
    /// Executor worker count k the thresholds were derived for (M/G/k):
    /// queue-depth thresholds scale with the effective service rate k·μ.
    pub workers: usize,
    /// Executor batch bound B the thresholds were derived for: requests
    /// dequeued per engine dispatch (1 = unbatched seed semantics).
    pub batch: usize,
    /// Per-dispatch fixed cost α (ms) of the batch service-time model
    /// `s̄(B) = α + β·B` the thresholds assume (0 when unprofiled).
    pub batch_alpha_ms: f64,
    /// Heterogeneous pool topology the per-rung thresholds were derived
    /// for (`planner::derive_plan_pools`). Empty = homogeneous plan
    /// (the pre-pool format; `workers` is the whole story).
    pub pools: Vec<PoolSpec>,
    /// Ordered by increasing mean service time (index 0 = fastest).
    pub ladder: Vec<ConfigPolicy>,
}

impl Plan {
    /// Index of the most accurate rung.
    pub fn most_accurate(&self) -> usize {
        self.ladder.len() - 1
    }

    pub fn to_json(&self) -> Json {
        let ladder = self
            .ladder
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("label".into(), Json::str(p.label.clone()));
                m.insert(
                    "config".into(),
                    Json::arr(p.config.iter().map(|&i| Json::num(i as f64))),
                );
                m.insert("accuracy".into(), Json::num(p.accuracy));
                m.insert("mean_ms".into(), Json::num(p.mean_ms));
                m.insert("p95_ms".into(), Json::num(p.p95_ms));
                m.insert("queue_slack_ms".into(), Json::num(p.queue_slack_ms));
                m.insert(
                    "upscale_threshold".into(),
                    Json::num(p.upscale_threshold as f64),
                );
                m.insert(
                    "downscale_threshold".into(),
                    p.downscale_threshold
                        .map(|v| Json::num(v as f64))
                        .unwrap_or(Json::Null),
                );
                Json::Obj(m)
            })
            .collect::<Vec<_>>();
        let pools = self
            .pools
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("name".into(), Json::str(p.name.clone()));
                m.insert("workers".into(), Json::num(p.workers as f64));
                m.insert(
                    "engine_rung_offset".into(),
                    Json::num(p.engine_rung_offset as f64),
                );
                m.insert("speed_factor".into(), Json::num(p.speed_factor));
                Json::Obj(m)
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("slo_ms", Json::num(self.slo_ms)),
            ("slack_buffer_ms", Json::num(self.slack_buffer_ms)),
            ("up_cooldown_ms", Json::num(self.up_cooldown_ms)),
            ("down_cooldown_ms", Json::num(self.down_cooldown_ms)),
            ("workers", Json::num(self.workers as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("batch_alpha_ms", Json::num(self.batch_alpha_ms)),
            ("pools", Json::Arr(pools)),
            ("ladder", Json::Arr(ladder)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Plan> {
        let ladder = j
            .get("ladder")?
            .as_arr()?
            .iter()
            .map(|e| {
                Some(ConfigPolicy {
                    label: e.get("label")?.as_str()?.to_string(),
                    config: e
                        .get("config")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Option<Vec<_>>>()?,
                    accuracy: e.get("accuracy")?.as_f64()?,
                    mean_ms: e.get("mean_ms")?.as_f64()?,
                    p95_ms: e.get("p95_ms")?.as_f64()?,
                    queue_slack_ms: e.get("queue_slack_ms")?.as_f64()?,
                    upscale_threshold: e.get("upscale_threshold")?.as_f64()? as u64,
                    downscale_threshold: match e.get("downscale_threshold")? {
                        Json::Null => None,
                        v => Some(v.as_f64()? as u64),
                    },
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(Plan {
            slo_ms: j.get("slo_ms")?.as_f64()?,
            slack_buffer_ms: j.get("slack_buffer_ms")?.as_f64()?,
            up_cooldown_ms: j.get("up_cooldown_ms")?.as_f64()?,
            down_cooldown_ms: j.get("down_cooldown_ms")?.as_f64()?,
            // Absent in pre-pool plan files: default to one worker.
            workers: j
                .get("workers")
                .and_then(|v| v.as_usize())
                .unwrap_or(1)
                .max(1),
            // Absent in pre-batching plan files: default to unbatched.
            batch: j
                .get("batch")
                .and_then(|v| v.as_usize())
                .unwrap_or(1)
                .max(1),
            batch_alpha_ms: j
                .get("batch_alpha_ms")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
                .max(0.0),
            // Absent in pre-pool plan files: default to a homogeneous
            // (topology-free) plan.
            pools: match j.get("pools") {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|e| {
                        Some(PoolSpec {
                            name: e.get("name")?.as_str()?.to_string(),
                            workers: e.get("workers")?.as_usize()?.max(1),
                            engine_rung_offset: e
                                .get("engine_rung_offset")?
                                .as_usize()?,
                            speed_factor: e.get("speed_factor")?.as_f64()?,
                        })
                    })
                    .collect::<Option<Vec<_>>>()?,
            },
            ladder,
        })
    }

    /// Console rendering of the ladder (Table-I style).
    pub fn render(&self) -> String {
        let mut out = format!(
            "Plan: SLO {:.0} ms, h_s {:.0} ms, t↑ {:.0} ms, t↓ {:.0} ms, workers {}, batch {}{}\n",
            self.slo_ms,
            self.slack_buffer_ms,
            self.up_cooldown_ms,
            self.down_cooldown_ms,
            self.workers,
            self.batch,
            if self.batch > 1 {
                format!(" (α {:.2} ms)", self.batch_alpha_ms)
            } else {
                String::new()
            }
        );
        if !self.pools.is_empty() {
            out.push_str(&format!(
                "  pools: {}\n",
                crate::serving::pool::describe_pools(&self.pools)
            ));
        }
        out.push_str(
            "  idx  label                                     acc     mean      p95    Δk     N↑    N↓\n",
        );
        for (i, p) in self.ladder.iter().enumerate() {
            out.push_str(&format!(
                "  {:>3}  {:<40} {:>6.3} {:>7.1}ms {:>7.1}ms {:>6.0} {:>5} {:>5}\n",
                i,
                p.label,
                p.accuracy,
                p.mean_ms,
                p.p95_ms,
                p.queue_slack_ms,
                p.upscale_threshold,
                p.downscale_threshold
                    .map(|v| v.to_string())
                    .unwrap_or_else(|| "-".into()),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> Plan {
        Plan {
            slo_ms: 300.0,
            slack_buffer_ms: 30.0,
            up_cooldown_ms: 0.0,
            down_cooldown_ms: 1500.0,
            workers: 2,
            batch: 4,
            batch_alpha_ms: 2.5,
            pools: vec![],
            ladder: vec![
                ConfigPolicy {
                    label: "fast".into(),
                    config: vec![0, 1, 2],
                    accuracy: 0.76,
                    mean_ms: 20.0,
                    p95_ms: 30.0,
                    queue_slack_ms: 270.0,
                    upscale_threshold: 13,
                    downscale_threshold: Some(4),
                },
                ConfigPolicy {
                    label: "accurate".into(),
                    config: vec![5, 1, 2],
                    accuracy: 0.85,
                    mean_ms: 90.0,
                    p95_ms: 140.0,
                    queue_slack_ms: 160.0,
                    upscale_threshold: 1,
                    downscale_threshold: None,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let p = plan();
        let j = p.to_json();
        let text = j.to_string();
        let parsed = Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, p);
    }

    #[test]
    fn render_contains_ladder() {
        let r = plan().render();
        assert!(r.contains("fast"));
        assert!(r.contains("accurate"));
        assert!(r.contains("SLO 300 ms"));
        assert!(r.contains("workers 2"));
    }

    #[test]
    fn legacy_plan_json_defaults_to_one_worker() {
        // Plan files written before the worker pool carry no "workers"
        // key; they must still load (as single-server plans).
        let mut p = plan();
        p.workers = 1;
        let mut j = p.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("workers");
        }
        let parsed = Plan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(parsed, p);
    }

    #[test]
    fn legacy_plan_json_defaults_to_unbatched() {
        // Plan files written before the batching executor carry no
        // "batch"/"batch_alpha_ms" keys; they load as unbatched plans.
        let mut p = plan();
        p.batch = 1;
        p.batch_alpha_ms = 0.0;
        let mut j = p.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("batch");
            m.remove("batch_alpha_ms");
        }
        let parsed = Plan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(parsed, p);
    }

    #[test]
    fn render_names_the_batch_bound() {
        let r = plan().render();
        assert!(r.contains("batch 4"));
        assert!(r.contains("α 2.50 ms"));
    }

    #[test]
    fn pooled_plan_json_roundtrip_and_render() {
        let mut p = plan();
        p.workers = 6;
        p.pools = vec![
            PoolSpec::new("fast", 4, 0, 1.0),
            PoolSpec::new("accurate", 2, 1, 2.5),
        ];
        let parsed = Plan::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed, p);
        let r = p.render();
        assert!(r.contains("pools: fast:4@1x+accurate:2@2.5x"), "{r}");
        // A topology-free plan renders no pools line.
        assert!(!plan().render().contains("pools:"));
    }

    #[test]
    fn legacy_plan_json_defaults_to_no_pools() {
        // Plan files written before heterogeneous pools carry no
        // "pools" key; they must load as homogeneous plans.
        let p = plan();
        let mut j = p.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("pools");
        }
        let parsed = Plan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(parsed, p);
        assert!(parsed.pools.is_empty());
    }
}
