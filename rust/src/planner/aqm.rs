//! AQM — analytical queuing-theory model for switching thresholds
//! (paper §V).
//!
//! The server is modeled as an M/G/1 queue with the Pareto ladder
//! `s̄0 < s̄1 < … < s̄n`. For a P95 latency SLO `L`:
//!
//! * **queuing slack** (Eq. 7): `Δk = L - s95_k` — the budget left for
//!   waiting once the request's own tail service time is reserved;
//!   configurations with `Δk <= 0` can never meet the SLO and are
//!   excluded;
//! * **upscale threshold** (Eq. 10): `N↑k = ⌊Δk / s̄k⌋` — the deepest
//!   queue the configuration can drain within its slack (mean service
//!   time as the P95-wait proxy; exact for deterministic service);
//! * **downscale threshold** (Eq. 13): `N↓k = ⌊(Δ(k+1) - h_s) / s̄(k+1)⌋`
//!   — the queue must be shallow enough that the *slower* configuration
//!   `k+1` could absorb it with a safety buffer `h_s` to spare;
//! * **asymmetric temporal hysteresis** (§V-F): upscaling (toward fast)
//!   has ~zero cooldown because violations are immediate; downscaling
//!   (toward accurate) waits out `t↓` of sustained low load.
//!
//! With a pool of `w` executor workers the server is an M/G/w queue and
//! the effective service rate is `w·μ`: a depth-N queue drains in
//! `N·s̄/w`, so both thresholds scale by `w` (`N↑k = ⌊w·Δk / s̄k⌋`, and
//! analogously for `N↓k`). `w = 1` reproduces the paper's equations
//! unchanged. Under the sharded queue discipline the depth these
//! thresholds are compared against is the **total across shards** (the
//! `ShardedQueue`'s lock-free aggregate counter), not any single
//! shard's backlog — the pool still drains N queued requests in
//! `N·s̄/w` regardless of which shard holds them, so the equations
//! carry over unmodified.
//!
//! ## Batch service-time model (`s̄(B) = α + β·B`)
//!
//! When the executor dequeues B requests per dispatch, batch service
//! time is no longer i.i.d. per request: one batch costs
//! `s̄_k(B) = α + β_k·B`, where `α` ([`AqmParams::batch_alpha_ms`]) is
//! the per-dispatch fixed cost (rung resolution, engine call setup,
//! policy observation) — fit by the profiler from batch timings at
//! B ∈ {1, 4, 8} ([`crate::planner::profiler::fit_batch_model`]) — and
//! `β_k = s̄_k(1) - α` is rung k's marginal per-item cost. Two effects
//! enter the threshold equations, and both vanish at B = 1:
//!
//! * **drain rate**: a worker serves requests at the effective
//!   per-request rate `B / s̄_k(B)`, so the effective per-request service
//!   time `s̄_k(B)/B = β_k + α/B` replaces `s̄_k` in Eq. 10/13 — the
//!   deeper the batch, the more dispatch overhead it amortizes;
//! * **tail inflation**: a request completes only when its whole batch
//!   does, so its service tail grows by the batch factor
//!   `s̄_k(B)/s̄_k(1)`; the queuing slack of Eq. 7 becomes
//!   `Δk(B) = L - s95_k·s̄_k(B)/s̄_k(1)` and the SLO-feasibility filter
//!   uses the inflated tail.
//!
//! The trade is explicit in the model: with `α` a large share of
//! `s̄(1)`, batching raises throughput faster than it inflates the tail
//! (thresholds deepen); with `α ≈ 0`, batching only delays completions
//! (`s̄(B) ≈ B·s̄(1)`) — the slack shrinks, rungs drop off the feasible
//! ladder, and the model correctly says "don't batch". `B = 1`
//! reproduces every existing threshold bit-for-bit regardless of `α`.
//!
//! ## Erlang-C thresholds (`--thresholds erlang`)
//!
//! The k-scaled rule above charges an arrival the full drain time of
//! everything queued ahead of it: `N` queued requests cost `N·s̄/k`, so
//! `N↑ = ⌊k·Δ/s̄⌋`. That is the *conditional* wait — conditioned on the
//! arrival actually having to queue. For a k-server pool the
//! unconditional picture is kinder: by Erlang-C, an arrival to an
//! M/M/k at offered load `a = kρ` waits at all only with probability
//! `C(k, a)` ([`crate::sim::theory::erlang_c`]), and `C` falls fast as
//! servers are added at fixed ρ. The SLO is a P95 over *all* requests,
//! so when `C < 1` part of the tail mass is already covered by the
//! never-waiting fraction and the depth budget grows by `1/C`:
//!
//! ```text
//! N↑k = ⌊ k·Δk / (s̄k · C(k, k·ρ̂)) ⌋        (ErlangC mode, Eq. 10')
//! ```
//!
//! with the operating utilization `ρ̂` = [`AqmParams::target_rho`] (the
//! paper's fixed 0.45 operating point by default) and the analogous
//! substitution in Eq. 13. At `k = 1`, `C(1, ρ̂) = ρ̂`, so even a single
//! server gains headroom over the legacy rule — which is why **legacy
//! stays the default**: [`ThresholdMode::Legacy`] keeps every seed
//! threshold bit-for-bit, and Erlang-C mode is validated against the
//! DES by `tests/theory_validation.rs` (the waiting-probability and
//! mean-wait checks) rather than assumed. This is an approximation —
//! service is G, not M, and ρ̂ is an assumption, not a measurement — but
//! it accounts for multi-server waiting probability directly instead of
//! pretending k servers are one k-times-faster server.
//!
//! ## Per-pool thresholds ([`derive_plan_pools`])
//!
//! On a heterogeneous fleet the rung bands partition the ladder across
//! pools (see [`crate::serving::pool`]): rung `r` is drained by the pool
//! that owns it, with that pool's `workers` and `speed_factor`. Its
//! thresholds are therefore derived from the *owning pool's* parameters
//! — service times scaled by `speed_factor`, `w` = the pool's worker
//! count, and (in Erlang-C mode) `C` computed for that pool's size —
//! because the per-pool depth signal the policy observes under pooled
//! serving is that pool's backlog, drained by that pool alone (spill is
//! a scavenging path, not provisioned capacity, so the derivation
//! conservatively ignores it). A single reference pool (speed 1, offset
//! 0, `workers = k`) reproduces [`derive_plan`] threshold-for-threshold.

use super::pareto::ProfiledConfig;
use super::plan::{ConfigPolicy, Plan};
use crate::serving::pool::{pool_of_rung, validate_pools, PoolSpec};
use crate::sim::theory::erlang_c;

/// How queue-depth thresholds account for the worker pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThresholdMode {
    /// The seed rule: thresholds scale linearly with k (`N↑ = ⌊k·Δ/s̄⌋`).
    /// Bit-for-bit the pre-pool derivation — the default.
    Legacy,
    /// Erlang-C waiting-probability thresholds (`N↑ = ⌊k·Δ/(s̄·C)⌋`,
    /// Eq. 10' above).
    ErlangC,
}

impl ThresholdMode {
    /// Parse a CLI spelling (`legacy` | `erlang`).
    pub fn parse(s: &str) -> Option<ThresholdMode> {
        match s {
            "legacy" | "linear" => Some(ThresholdMode::Legacy),
            "erlang" | "erlang-c" | "erlangc" => Some(ThresholdMode::ErlangC),
            _ => None,
        }
    }

    /// Display name (reports/CSV headers).
    pub fn name(&self) -> &'static str {
        match self {
            ThresholdMode::Legacy => "legacy",
            ThresholdMode::ErlangC => "erlang",
        }
    }
}

/// AQM derivation parameters.
#[derive(Clone, Copy, Debug)]
pub struct AqmParams {
    /// P95 latency SLO target `L` in ms.
    pub slo_ms: f64,
    /// Slack buffer `h_s` (ms) protecting downscale transitions.
    pub slack_buffer_ms: f64,
    /// Upscale cooldown `t↑` (ms): zero / near-zero.
    pub up_cooldown_ms: f64,
    /// Downscale cooldown `t↓` (ms): several seconds.
    pub down_cooldown_ms: f64,
    /// Executor worker count k (M/G/k): thresholds scale with the
    /// effective service rate k·μ.
    pub workers: usize,
    /// Executor batch bound B: requests dequeued per engine dispatch.
    /// 1 = unbatched (the paper's testbed).
    pub batch: usize,
    /// Per-dispatch fixed cost α (ms) of the batch service-time model
    /// `s̄(B) = α + β·B`, fit by the profiler; clamped per rung into
    /// `[0, s̄_k(1)]` at derivation. Irrelevant at `batch == 1`.
    pub batch_alpha_ms: f64,
    /// Threshold derivation rule (legacy k-scaling by default; see the
    /// module docs for the Erlang-C alternative).
    pub thresholds: ThresholdMode,
    /// Assumed operating utilization ρ̂ for Erlang-C mode (the paper's
    /// fixed 0.45 operating point by default). Ignored under
    /// [`ThresholdMode::Legacy`].
    pub target_rho: f64,
}

impl AqmParams {
    /// Paper defaults, scaled to an SLO: `h_s` = 10% of L, `t↑` = 0,
    /// `t↓` = 5 s scaled by L/1000 (the paper's 5 s at a 1000 ms SLO).
    /// Single-server, unbatched (the paper's testbed).
    pub fn for_slo(slo_ms: f64) -> AqmParams {
        AqmParams {
            slo_ms,
            slack_buffer_ms: 0.10 * slo_ms,
            up_cooldown_ms: 0.0,
            down_cooldown_ms: 5.0 * slo_ms,
            workers: 1,
            batch: 1,
            batch_alpha_ms: 0.0,
            thresholds: ThresholdMode::Legacy,
            target_rho: 0.45,
        }
    }

    /// Paper defaults for a pool of `workers` executors.
    pub fn for_slo_workers(slo_ms: f64, workers: usize) -> AqmParams {
        AqmParams { workers: workers.max(1), ..AqmParams::for_slo(slo_ms) }
    }

    /// Same params with the executor batch bound and the profiled
    /// per-dispatch fixed cost α of `s̄(B) = α + β·B`.
    pub fn with_batch(self, batch: usize, batch_alpha_ms: f64) -> AqmParams {
        AqmParams {
            batch: batch.max(1),
            batch_alpha_ms: batch_alpha_ms.max(0.0),
            ..self
        }
    }

    /// Same params under another threshold derivation rule.
    pub fn with_thresholds(self, thresholds: ThresholdMode) -> AqmParams {
        AqmParams { thresholds, ..self }
    }

    /// Same params with an assumed operating utilization for Erlang-C
    /// mode (clamped into `(0, 0.99]` at derivation).
    pub fn with_rho(self, target_rho: f64) -> AqmParams {
        AqmParams { target_rho, ..self }
    }
}

/// Eq. 10's linear depth budget in its raw form: `w` workers draining a
/// mean service of `eff_mean_ms` can absorb `w·Δ/s̄` queued requests
/// within a slack of `slack_ms`. This is the kernel every admission
/// threshold in the system derives from — the AQM's per-rung switching
/// thresholds ([`depth_budget`], which divides by the Erlang-C waiting
/// probability in [`ThresholdMode::ErlangC`]) and the overload plane's
/// per-class shed budgets ([`crate::serving::overload::OverloadConfig`],
/// which substitutes the class deadline for the SLO slack).
pub fn admission_depth_budget(w: f64, slack_ms: f64, eff_mean_ms: f64) -> f64 {
    w * slack_ms / eff_mean_ms.max(1e-9)
}

/// Depth budget of one rung: how many queued requests its pool can
/// absorb within the slack. Legacy: the linear k-scaling (Eq. 10).
/// Erlang-C: the same budget divided by the pool's waiting probability
/// `C(k, k·ρ̂)` (Eq. 10', module docs); `C ≤ 1`, so Erlang-C thresholds
/// are never shallower than legacy at the same (w, slack, s̄).
fn depth_budget(params: &AqmParams, w: f64, slack: f64, eff_mean: f64) -> f64 {
    let linear = admission_depth_budget(w, slack, eff_mean);
    match params.thresholds {
        ThresholdMode::Legacy => linear,
        ThresholdMode::ErlangC => {
            let k = (w as usize).max(1);
            let rho = params.target_rho.clamp(0.01, 0.99);
            let c = erlang_c(k, k as f64 * rho).max(1e-9);
            linear / c
        }
    }
}

/// Derive the switching plan from a Pareto ladder (ordered by increasing
/// mean service time). Configurations whose queuing slack is non-positive
/// are dropped (paper: "configurations with Δk <= 0 cannot satisfy the
/// SLO and are excluded") — except that the *fastest* surviving
/// configuration is always kept if the ladder would otherwise be empty,
/// so the system degrades to best-effort rather than refusing to serve.
///
/// This is the homogeneous-fleet case of [`derive_plan_pools`]: one
/// reference pool of `params.workers` executors (the delegation is
/// exact — thresholds are bit-for-bit the pre-pool derivation).
pub fn derive_plan(front: &[ProfiledConfig], params: AqmParams) -> Plan {
    let mut plan = derive_plan_pools(
        front,
        params,
        &[PoolSpec::uniform(params.workers.max(1))],
    );
    // The homogeneous derivation produces a topology-free plan.
    plan.pools = Vec::new();
    plan
}

/// Derive the switching plan for a heterogeneous fleet of named worker
/// pools: each rung's thresholds come from the pool that owns its band
/// (that pool's worker count, speed-scaled service times and — under
/// [`ThresholdMode::ErlangC`] — that pool's waiting probability). See
/// the module docs; a single reference pool reproduces [`derive_plan`]
/// threshold-for-threshold.
pub fn derive_plan_pools(
    front: &[ProfiledConfig],
    params: AqmParams,
    pools: &[PoolSpec],
) -> Plan {
    assert!(!front.is_empty(), "empty pareto front");
    validate_pools(pools).expect("invalid pool topology");
    for w in front.windows(2) {
        assert!(
            w[0].latency.mean_ms <= w[1].latency.mean_ms,
            "front must be ordered by mean service time"
        );
    }

    let b = params.batch.max(1) as f64;
    // Batch service-time model per rung under its executing pool:
    // s̄(B) = α + β·B with β = s̄(1) - α (α clamped into [0, s̄(1)] of the
    // pool-scaled service time). Returns the effective per-request
    // service time s̄(B)/B (Eq. 10/13's drain-rate term) and the
    // batch-inflated service tail s95·s̄(B)/s̄(1) (Eq. 7's reservation).
    // Both reduce to the pool-scaled (mean, p95) exactly at B = 1, and
    // to the raw profile on a reference pool.
    let batched = |c: &ProfiledConfig, speed: f64| -> (f64, f64) {
        let lat = c.latency.scaled(speed);
        let mean = lat.mean_ms;
        let alpha = params.batch_alpha_ms.clamp(0.0, mean);
        let sbar_b = alpha + (mean - alpha) * b; // s̄(B)
        (sbar_b / b, lat.p95_ms * (sbar_b / mean))
    };
    let speed_of_rung =
        |rung: usize| pools[pool_of_rung(pools, rung)].speed_factor;
    let workers_of_rung =
        |rung: usize| pools[pool_of_rung(pools, rung)].workers.max(1) as f64;

    // Exclude configurations that cannot meet the SLO at all — against
    // the batch-inflated tail of the pool that would execute them (a
    // request completes only when its whole batch does). The owning
    // pool of a candidate is resolved at the ladder position it would
    // occupy, so bands stay aligned with the surviving ladder.
    let mut ladder: Vec<&ProfiledConfig> = Vec::new();
    for c in front {
        let speed = speed_of_rung(ladder.len());
        if params.slo_ms - batched(c, speed).1 > 0.0 {
            ladder.push(c);
        }
    }
    if ladder.is_empty() {
        // Degraded mode: keep the fastest configuration only.
        ladder.push(&front[0]);
    }

    let mut policies: Vec<ConfigPolicy> = Vec::with_capacity(ladder.len());
    for (k, c) in ladder.iter().enumerate() {
        let w = workers_of_rung(k);
        let (eff_mean, eff_p95) = batched(c, speed_of_rung(k));
        let slack = params.slo_ms - eff_p95; // Δk(B) (Eq. 7)
        let upscale = if slack > 0.0 {
            // Eq. 10 (legacy) / Eq. 10' (Erlang-C), effective
            // per-request rate w·B/s̄(B) of the owning pool.
            depth_budget(&params, w, slack, eff_mean).floor().max(0.0) as u64
        } else {
            0
        };
        // Downscale threshold of config k governs the k -> k+1 move and is
        // computed from the *slower* config k+1 (Eq. 13) under the pool
        // that would drain it.
        let downscale = if k + 1 < ladder.len() {
            let w_next = workers_of_rung(k + 1);
            let (next_eff_mean, next_eff_p95) =
                batched(ladder[k + 1], speed_of_rung(k + 1));
            let next_slack = params.slo_ms - next_eff_p95;
            let fill = depth_budget(
                &params,
                w_next,
                next_slack - params.slack_buffer_ms,
                next_eff_mean,
            );
            Some(fill.floor().max(0.0) as u64)
        } else {
            None
        };
        policies.push(ConfigPolicy {
            label: c.label.clone(),
            config: c.config.clone(),
            accuracy: c.accuracy,
            mean_ms: c.latency.mean_ms,
            p95_ms: c.latency.p95_ms,
            queue_slack_ms: slack,
            upscale_threshold: upscale,
            downscale_threshold: downscale,
        });
    }

    Plan {
        slo_ms: params.slo_ms,
        slack_buffer_ms: params.slack_buffer_ms,
        up_cooldown_ms: params.up_cooldown_ms,
        down_cooldown_ms: params.down_cooldown_ms,
        workers: crate::serving::pool::total_workers(pools),
        batch: params.batch.max(1),
        batch_alpha_ms: params.batch_alpha_ms.max(0.0),
        pools: pools.to_vec(),
        ladder: policies,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::profiler::LatencyProfile;

    fn pc(acc: f64, mean: f64, p95: f64) -> ProfiledConfig {
        ProfiledConfig {
            config: vec![],
            label: format!("c-{mean}"),
            accuracy: acc,
            latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
        }
    }

    fn front3() -> Vec<ProfiledConfig> {
        vec![
            pc(0.76, 20.0, 30.0),
            pc(0.82, 45.0, 70.0),
            pc(0.85, 90.0, 140.0),
        ]
    }

    #[test]
    fn thresholds_match_equations() {
        let plan = derive_plan(&front3(), AqmParams::for_slo(300.0));
        // Δ0 = 300-30 = 270, N↑0 = floor(270/20) = 13.
        assert_eq!(plan.ladder[0].upscale_threshold, 13);
        // Δ1 = 230, N↑1 = floor(230/45) = 5.
        assert_eq!(plan.ladder[1].upscale_threshold, 5);
        // Δ2 = 160, N↑2 = floor(160/90) = 1.
        assert_eq!(plan.ladder[2].upscale_threshold, 1);
        // N↓0 (to config 1): floor((230 - 30)/45) = 4.
        assert_eq!(plan.ladder[0].downscale_threshold, Some(4));
        // N↓1 (to config 2): floor((160 - 30)/90) = 1.
        assert_eq!(plan.ladder[1].downscale_threshold, Some(1));
        assert_eq!(plan.ladder[2].downscale_threshold, None);
    }

    #[test]
    fn faster_configs_tolerate_deeper_queues() {
        // Paper Eq. 11: N↑0 > N↑1 > … > N↑n.
        let plan = derive_plan(&front3(), AqmParams::for_slo(300.0));
        let ups: Vec<u64> =
            plan.ladder.iter().map(|p| p.upscale_threshold).collect();
        for w in ups.windows(2) {
            assert!(w[0] > w[1], "{ups:?}");
        }
    }

    #[test]
    fn excludes_infeasible_configs() {
        // SLO below the slowest config's p95 -> it is dropped.
        let plan = derive_plan(&front3(), AqmParams::for_slo(100.0));
        assert_eq!(plan.ladder.len(), 2);
        assert_eq!(plan.ladder.last().unwrap().label, "c-45");
    }

    #[test]
    fn degraded_mode_keeps_fastest() {
        // SLO below every p95: keep only the fastest, best-effort.
        let plan = derive_plan(&front3(), AqmParams::for_slo(10.0));
        assert_eq!(plan.ladder.len(), 1);
        assert_eq!(plan.ladder[0].label, "c-20");
        assert_eq!(plan.ladder[0].upscale_threshold, 0);
    }

    #[test]
    fn worker_pool_scales_thresholds() {
        // k workers drain a depth-N queue k times faster, so every
        // threshold scales by k (Eq. 10 with effective rate k·μ).
        let p1 = derive_plan(&front3(), AqmParams::for_slo(300.0));
        let p4 = derive_plan(&front3(), AqmParams::for_slo_workers(300.0, 4));
        assert_eq!(p4.workers, 4);
        // floor(4·270/20) = 54, floor(4·230/45) = 20, floor(4·160/90) = 7.
        assert_eq!(p4.ladder[0].upscale_threshold, 54);
        assert_eq!(p4.ladder[1].upscale_threshold, 20);
        assert_eq!(p4.ladder[2].upscale_threshold, 7);
        // N↓0 = floor(4·(230-30)/45) = 17, N↓1 = floor(4·(160-30)/90) = 5.
        assert_eq!(p4.ladder[0].downscale_threshold, Some(17));
        assert_eq!(p4.ladder[1].downscale_threshold, Some(5));
        // k = 1 must reproduce the paper's numbers unchanged.
        assert_eq!(p1.workers, 1);
        assert_eq!(p1.ladder[0].upscale_threshold, 13);
        for (a, b) in p1.ladder.iter().zip(&p4.ladder) {
            assert!(b.upscale_threshold >= 4 * a.upscale_threshold);
            assert!(b.upscale_threshold < 4 * (a.upscale_threshold + 1));
        }
    }

    #[test]
    fn hysteresis_is_asymmetric() {
        let p = AqmParams::for_slo(1000.0);
        assert_eq!(p.up_cooldown_ms, 0.0);
        assert!(p.down_cooldown_ms >= 1000.0);
    }

    #[test]
    fn legacy_mode_is_the_default_and_stays_bit_for_bit() {
        // The seed pin: the default params carry Legacy mode, and an
        // explicit Legacy request changes nothing — thresholds, slack
        // bits, ladder — at any worker count.
        for k in [1usize, 4] {
            let seed = derive_plan(&front3(), AqmParams::for_slo_workers(300.0, k));
            let explicit = derive_plan(
                &front3(),
                AqmParams::for_slo_workers(300.0, k).with_thresholds(ThresholdMode::Legacy),
            );
            assert_eq!(seed, explicit);
        }
        assert_eq!(AqmParams::for_slo(300.0).thresholds, ThresholdMode::Legacy);
    }

    #[test]
    fn erlang_thresholds_match_the_formula_by_hand() {
        // k = 4, ρ̂ = 0.45: a = 1.8, C(4, 1.8) via the Erlang-B
        // recurrence; rung 0 (Δ = 270, s̄ = 20): N↑ = ⌊4·270/(20·C)⌋.
        let params = AqmParams::for_slo_workers(300.0, 4)
            .with_thresholds(ThresholdMode::ErlangC);
        let plan = derive_plan(&front3(), params);
        let c = crate::sim::theory::erlang_c(4, 4.0 * 0.45);
        let expect = (4.0 * 270.0 / 20.0 / c).floor() as u64;
        assert_eq!(plan.ladder[0].upscale_threshold, expect);
        assert!(expect > 54, "must deepen past the legacy ⌊4·270/20⌋ = 54");
        // Downscale of rung 0 follows rung 1's numbers: ⌊4·(230-30)/45/C⌋.
        let expect_down = (4.0 * 200.0 / 45.0 / c).floor() as u64;
        assert_eq!(plan.ladder[0].downscale_threshold, Some(expect_down));
    }

    #[test]
    fn erlang_thresholds_are_never_shallower_and_deepen_with_pool_size() {
        // C ≤ 1 ⇒ every Erlang-C threshold ≥ its legacy counterpart, and
        // C falls as servers are added at fixed ρ ⇒ the per-worker depth
        // budget N↑/k grows with k (the multi-server waiting-probability
        // effect the linear rule cannot see). Eq. 11 monotonicity must
        // survive the new rule.
        let mut last_per_worker = 0.0f64;
        for k in [1usize, 2, 4, 8] {
            let legacy = derive_plan(&front3(), AqmParams::for_slo_workers(300.0, k));
            let erl = derive_plan(
                &front3(),
                AqmParams::for_slo_workers(300.0, k).with_thresholds(ThresholdMode::ErlangC),
            );
            for (a, b) in legacy.ladder.iter().zip(&erl.ladder) {
                assert!(
                    b.upscale_threshold >= a.upscale_threshold,
                    "k={k}: erlang {} < legacy {}",
                    b.upscale_threshold,
                    a.upscale_threshold
                );
            }
            let ups: Vec<u64> = erl.ladder.iter().map(|p| p.upscale_threshold).collect();
            for w in ups.windows(2) {
                assert!(w[0] >= w[1], "Eq. 11 violated under Erlang-C at k={k}: {ups:?}");
            }
            let per_worker = erl.ladder[0].upscale_threshold as f64 / k as f64;
            assert!(
                per_worker >= last_per_worker - 1.0, // floor() granularity
                "per-worker budget shrank at k={k}: {per_worker} < {last_per_worker}"
            );
            last_per_worker = per_worker;
        }
    }

    #[test]
    fn single_reference_pool_reproduces_derive_plan_thresholds() {
        // The parity pin on the planner side: one homogeneous pool
        // (speed 1, offset 0) must reproduce the k-worker derivation
        // threshold-for-threshold, slack bits included, in both modes.
        use crate::serving::pool::PoolSpec;
        for mode in [ThresholdMode::Legacy, ThresholdMode::ErlangC] {
            for k in [1usize, 4] {
                let params = AqmParams::for_slo_workers(300.0, k)
                    .with_batch(4, 6.0)
                    .with_thresholds(mode);
                let flat = derive_plan(&front3(), params);
                let pooled = derive_plan_pools(&front3(), params, &[PoolSpec::uniform(k)]);
                assert_eq!(flat.ladder.len(), pooled.ladder.len());
                for (a, b) in flat.ladder.iter().zip(&pooled.ladder) {
                    assert_eq!(a.upscale_threshold, b.upscale_threshold, "{mode:?} k={k}");
                    assert_eq!(a.downscale_threshold, b.downscale_threshold);
                    assert_eq!(a.queue_slack_ms.to_bits(), b.queue_slack_ms.to_bits());
                }
                assert_eq!(pooled.workers, k);
                assert_eq!(pooled.pools, vec![PoolSpec::uniform(k)]);
                assert!(flat.pools.is_empty(), "homogeneous plans stay topology-free");
            }
        }
    }

    #[test]
    fn per_pool_thresholds_use_the_owning_pools_parameters() {
        // fast:4 owns rung 0; accurate:2 at 2x speed owns rungs 1+.
        // Rung 0 keeps the 4-worker reference numbers; rungs 1 and 2
        // shrink to the slower pool's 2 workers and doubled service
        // times (rung 2's doubled tail of 280 ms leaves slack 20 —
        // feasible, but with a zero depth budget).
        use crate::serving::pool::parse_pools;
        let pools = parse_pools("fast:4:1.0,accurate:2:2.0").unwrap();
        let plan = derive_plan_pools(&front3(), AqmParams::for_slo(300.0), &pools);
        assert_eq!(plan.ladder.len(), 3);
        // Rung 0 (fast pool, 4 workers, speed 1): ⌊4·270/20⌋ = 54.
        assert_eq!(plan.ladder[0].upscale_threshold, 54);
        // Rung 1 (accurate pool, 2 workers, speed 2): scaled mean 90,
        // p95 140, slack 160, ⌊2·160/90⌋ = 3.
        assert_eq!(plan.ladder[1].upscale_threshold, 3);
        // Rung 2: scaled mean 180, p95 280, slack 20, ⌊2·20/180⌋ = 0.
        assert_eq!(plan.ladder[2].upscale_threshold, 0);
        assert!((plan.ladder[2].queue_slack_ms - 20.0).abs() < 1e-9);
        // Downscale of rung 0 follows rung 1 under ITS pool:
        // ⌊2·(160-30)/90⌋ = 2; rung 1's follows rung 2: slack-h_s < 0 → 0.
        assert_eq!(plan.ladder[0].downscale_threshold, Some(2));
        assert_eq!(plan.ladder[1].downscale_threshold, Some(0));
        assert_eq!(plan.workers, 6, "plan records the fleet total");
        assert_eq!(plan.pools.len(), 2);
    }

    #[test]
    fn batch_one_reproduces_seed_thresholds_exactly() {
        // B = 1 must be bit-for-bit the unbatched derivation regardless
        // of the fitted α (the batch model degenerates to s̄(1)).
        let seed = derive_plan(&front3(), AqmParams::for_slo(300.0));
        for alpha in [0.0, 3.0, 12.5, 1e6] {
            let b1 = derive_plan(&front3(), AqmParams::for_slo(300.0).with_batch(1, alpha));
            assert_eq!(b1.ladder.len(), seed.ladder.len());
            for (a, b) in seed.ladder.iter().zip(&b1.ladder) {
                assert_eq!(a.upscale_threshold, b.upscale_threshold);
                assert_eq!(a.downscale_threshold, b.downscale_threshold);
                assert_eq!(a.queue_slack_ms.to_bits(), b.queue_slack_ms.to_bits());
            }
        }
        // And the seed numbers themselves stay pinned (Eq. 10/13).
        assert_eq!(seed.ladder[0].upscale_threshold, 13);
        assert_eq!(seed.ladder[1].upscale_threshold, 5);
        assert_eq!(seed.ladder[2].upscale_threshold, 1);
        assert_eq!(seed.ladder[0].downscale_threshold, Some(4));
        assert_eq!(seed.ladder[1].downscale_threshold, Some(1));
    }

    #[test]
    fn batch_thresholds_match_the_model_by_hand() {
        // B = 4, α = 10: rung 0 (mean 20, p95 30): s̄(4) = 10 + 10·4 =
        // 50, eff mean 12.5, inflated p95 = 30·50/20 = 75, slack 225,
        // N↑ = floor(225/12.5) = 18.
        let plan = derive_plan(&front3(), AqmParams::for_slo(300.0).with_batch(4, 10.0));
        assert_eq!(plan.batch, 4);
        assert_eq!(plan.batch_alpha_ms, 10.0);
        assert_eq!(plan.ladder[0].upscale_threshold, 18);
        assert!((plan.ladder[0].queue_slack_ms - 225.0).abs() < 1e-9);
        // Rung 1 (mean 45, p95 70): s̄(4) = 10 + 35·4 = 150, eff 37.5,
        // p95·150/45 = 233.33 -> slack 66.67, N↑ = floor(66.67/37.5) = 1.
        assert_eq!(plan.ladder[1].upscale_threshold, 1);
        // Rung 2 (mean 90, p95 140): inflated p95 = 140·330/90 ≈ 513 >
        // SLO -> dropped from the feasible ladder at this batch depth.
        assert_eq!(plan.ladder.len(), 2, "batch tail drops rung 2");
        // Downscale of rung 0 follows rung 1's batched numbers:
        // floor((66.67 - 30)/37.5) = 0.
        assert_eq!(plan.ladder[0].downscale_threshold, Some(0));
    }

    #[test]
    fn thresholds_monotone_non_increasing_along_ladder_at_any_batch() {
        // Eq. 11 (N↑0 ≥ N↑1 ≥ …) must survive the batch model: the
        // inflation factor grows with the rung's service time, so slower
        // rungs only lose more slack.
        for b in [1usize, 2, 4, 8, 16] {
            for alpha in [0.0, 2.0, 8.0, 15.0] {
                let plan = derive_plan(&front3(), AqmParams::for_slo(600.0).with_batch(b, alpha));
                let ups: Vec<u64> = plan.ladder.iter().map(|p| p.upscale_threshold).collect();
                for w in ups.windows(2) {
                    assert!(w[0] >= w[1], "Eq. 11 violated at B={b} α={alpha}: {ups:?}");
                }
            }
        }
    }

    #[test]
    fn zero_alpha_batching_only_hurts_the_tail() {
        // With no fixed dispatch cost the effective per-request service
        // time is unchanged but the tail inflates by B: thresholds can
        // only tighten, and deep batches push rungs off the ladder.
        let b1 = derive_plan(&front3(), AqmParams::for_slo(300.0));
        let b4 = derive_plan(&front3(), AqmParams::for_slo(300.0).with_batch(4, 0.0));
        assert!(b4.ladder.len() <= b1.ladder.len());
        for (a, b) in b1.ladder.iter().zip(&b4.ladder) {
            assert!(b.upscale_threshold <= a.upscale_threshold);
        }
    }

    #[test]
    fn high_alpha_batching_deepens_thresholds() {
        // With α = 75% of the fastest rung's s̄(1), B = 8 drains ~3.3x
        // faster per request: the fast rung's upscale threshold must
        // grow despite the inflated tail.
        let b1 = derive_plan(&front3(), AqmParams::for_slo(300.0));
        let b8 = derive_plan(&front3(), AqmParams::for_slo(300.0).with_batch(8, 15.0));
        assert!(
            b8.ladder[0].upscale_threshold > b1.ladder[0].upscale_threshold,
            "B=8 α=15: {} should exceed unbatched {}",
            b8.ladder[0].upscale_threshold,
            b1.ladder[0].upscale_threshold
        );
    }
}
