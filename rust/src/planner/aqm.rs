//! AQM — analytical queuing-theory model for switching thresholds
//! (paper §V).
//!
//! The server is modeled as an M/G/1 queue with the Pareto ladder
//! `s̄0 < s̄1 < … < s̄n`. For a P95 latency SLO `L`:
//!
//! * **queuing slack** (Eq. 7): `Δk = L - s95_k` — the budget left for
//!   waiting once the request's own tail service time is reserved;
//!   configurations with `Δk <= 0` can never meet the SLO and are
//!   excluded;
//! * **upscale threshold** (Eq. 10): `N↑k = ⌊Δk / s̄k⌋` — the deepest
//!   queue the configuration can drain within its slack (mean service
//!   time as the P95-wait proxy; exact for deterministic service);
//! * **downscale threshold** (Eq. 13): `N↓k = ⌊(Δ(k+1) - h_s) / s̄(k+1)⌋`
//!   — the queue must be shallow enough that the *slower* configuration
//!   `k+1` could absorb it with a safety buffer `h_s` to spare;
//! * **asymmetric temporal hysteresis** (§V-F): upscaling (toward fast)
//!   has ~zero cooldown because violations are immediate; downscaling
//!   (toward accurate) waits out `t↓` of sustained low load.
//!
//! With a pool of `w` executor workers the server is an M/G/w queue and
//! the effective service rate is `w·μ`: a depth-N queue drains in
//! `N·s̄/w`, so both thresholds scale by `w` (`N↑k = ⌊w·Δk / s̄k⌋`, and
//! analogously for `N↓k`). `w = 1` reproduces the paper's equations
//! unchanged. Under the sharded queue discipline the depth these
//! thresholds are compared against is the **total across shards** (the
//! `ShardedQueue`'s lock-free aggregate counter), not any single
//! shard's backlog — the pool still drains N queued requests in
//! `N·s̄/w` regardless of which shard holds them, so the equations
//! carry over unmodified.

use super::pareto::ProfiledConfig;
use super::plan::{ConfigPolicy, Plan};

/// AQM derivation parameters.
#[derive(Clone, Copy, Debug)]
pub struct AqmParams {
    /// P95 latency SLO target `L` in ms.
    pub slo_ms: f64,
    /// Slack buffer `h_s` (ms) protecting downscale transitions.
    pub slack_buffer_ms: f64,
    /// Upscale cooldown `t↑` (ms): zero / near-zero.
    pub up_cooldown_ms: f64,
    /// Downscale cooldown `t↓` (ms): several seconds.
    pub down_cooldown_ms: f64,
    /// Executor worker count k (M/G/k): thresholds scale with the
    /// effective service rate k·μ.
    pub workers: usize,
}

impl AqmParams {
    /// Paper defaults, scaled to an SLO: `h_s` = 10% of L, `t↑` = 0,
    /// `t↓` = 5 s scaled by L/1000 (the paper's 5 s at a 1000 ms SLO).
    /// Single-server (the paper's testbed).
    pub fn for_slo(slo_ms: f64) -> AqmParams {
        AqmParams {
            slo_ms,
            slack_buffer_ms: 0.10 * slo_ms,
            up_cooldown_ms: 0.0,
            down_cooldown_ms: 5.0 * slo_ms,
            workers: 1,
        }
    }

    /// Paper defaults for a pool of `workers` executors.
    pub fn for_slo_workers(slo_ms: f64, workers: usize) -> AqmParams {
        AqmParams { workers: workers.max(1), ..AqmParams::for_slo(slo_ms) }
    }
}

/// Derive the switching plan from a Pareto ladder (ordered by increasing
/// mean service time). Configurations whose queuing slack is non-positive
/// are dropped (paper: "configurations with Δk <= 0 cannot satisfy the
/// SLO and are excluded") — except that the *fastest* surviving
/// configuration is always kept if the ladder would otherwise be empty,
/// so the system degrades to best-effort rather than refusing to serve.
pub fn derive_plan(front: &[ProfiledConfig], params: AqmParams) -> Plan {
    assert!(!front.is_empty(), "empty pareto front");
    for w in front.windows(2) {
        assert!(
            w[0].latency.mean_ms <= w[1].latency.mean_ms,
            "front must be ordered by mean service time"
        );
    }

    // Exclude configurations that cannot meet the SLO at all.
    let mut ladder: Vec<&ProfiledConfig> = front
        .iter()
        .filter(|c| params.slo_ms - c.latency.p95_ms > 0.0)
        .collect();
    if ladder.is_empty() {
        // Degraded mode: keep the fastest configuration only.
        ladder.push(&front[0]);
    }

    let w = params.workers.max(1) as f64;
    let mut policies: Vec<ConfigPolicy> = Vec::with_capacity(ladder.len());
    for (k, c) in ladder.iter().enumerate() {
        let slack = params.slo_ms - c.latency.p95_ms; // Δk (Eq. 7)
        let upscale = if slack > 0.0 {
            // Eq. 10, effective service rate w·μ.
            (w * slack / c.latency.mean_ms).floor().max(0.0) as u64
        } else {
            0
        };
        // Downscale threshold of config k governs the k -> k+1 move and is
        // computed from the *slower* config k+1 (Eq. 13).
        let downscale = if k + 1 < ladder.len() {
            let next = ladder[k + 1];
            let next_slack = params.slo_ms - next.latency.p95_ms;
            let n = (w * (next_slack - params.slack_buffer_ms)
                / next.latency.mean_ms)
                .floor();
            Some(n.max(0.0) as u64)
        } else {
            None
        };
        policies.push(ConfigPolicy {
            label: c.label.clone(),
            config: c.config.clone(),
            accuracy: c.accuracy,
            mean_ms: c.latency.mean_ms,
            p95_ms: c.latency.p95_ms,
            queue_slack_ms: slack,
            upscale_threshold: upscale,
            downscale_threshold: downscale,
        });
    }

    Plan {
        slo_ms: params.slo_ms,
        slack_buffer_ms: params.slack_buffer_ms,
        up_cooldown_ms: params.up_cooldown_ms,
        down_cooldown_ms: params.down_cooldown_ms,
        workers: params.workers.max(1),
        ladder: policies,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::profiler::LatencyProfile;

    fn pc(acc: f64, mean: f64, p95: f64) -> ProfiledConfig {
        ProfiledConfig {
            config: vec![],
            label: format!("c-{mean}"),
            accuracy: acc,
            latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
        }
    }

    fn front3() -> Vec<ProfiledConfig> {
        vec![
            pc(0.76, 20.0, 30.0),
            pc(0.82, 45.0, 70.0),
            pc(0.85, 90.0, 140.0),
        ]
    }

    #[test]
    fn thresholds_match_equations() {
        let plan = derive_plan(&front3(), AqmParams::for_slo(300.0));
        // Δ0 = 300-30 = 270, N↑0 = floor(270/20) = 13.
        assert_eq!(plan.ladder[0].upscale_threshold, 13);
        // Δ1 = 230, N↑1 = floor(230/45) = 5.
        assert_eq!(plan.ladder[1].upscale_threshold, 5);
        // Δ2 = 160, N↑2 = floor(160/90) = 1.
        assert_eq!(plan.ladder[2].upscale_threshold, 1);
        // N↓0 (to config 1): floor((230 - 30)/45) = 4.
        assert_eq!(plan.ladder[0].downscale_threshold, Some(4));
        // N↓1 (to config 2): floor((160 - 30)/90) = 1.
        assert_eq!(plan.ladder[1].downscale_threshold, Some(1));
        assert_eq!(plan.ladder[2].downscale_threshold, None);
    }

    #[test]
    fn faster_configs_tolerate_deeper_queues() {
        // Paper Eq. 11: N↑0 > N↑1 > … > N↑n.
        let plan = derive_plan(&front3(), AqmParams::for_slo(300.0));
        let ups: Vec<u64> =
            plan.ladder.iter().map(|p| p.upscale_threshold).collect();
        for w in ups.windows(2) {
            assert!(w[0] > w[1], "{ups:?}");
        }
    }

    #[test]
    fn excludes_infeasible_configs() {
        // SLO below the slowest config's p95 -> it is dropped.
        let plan = derive_plan(&front3(), AqmParams::for_slo(100.0));
        assert_eq!(plan.ladder.len(), 2);
        assert_eq!(plan.ladder.last().unwrap().label, "c-45");
    }

    #[test]
    fn degraded_mode_keeps_fastest() {
        // SLO below every p95: keep only the fastest, best-effort.
        let plan = derive_plan(&front3(), AqmParams::for_slo(10.0));
        assert_eq!(plan.ladder.len(), 1);
        assert_eq!(plan.ladder[0].label, "c-20");
        assert_eq!(plan.ladder[0].upscale_threshold, 0);
    }

    #[test]
    fn worker_pool_scales_thresholds() {
        // k workers drain a depth-N queue k times faster, so every
        // threshold scales by k (Eq. 10 with effective rate k·μ).
        let p1 = derive_plan(&front3(), AqmParams::for_slo(300.0));
        let p4 = derive_plan(&front3(), AqmParams::for_slo_workers(300.0, 4));
        assert_eq!(p4.workers, 4);
        // floor(4·270/20) = 54, floor(4·230/45) = 20, floor(4·160/90) = 7.
        assert_eq!(p4.ladder[0].upscale_threshold, 54);
        assert_eq!(p4.ladder[1].upscale_threshold, 20);
        assert_eq!(p4.ladder[2].upscale_threshold, 7);
        // N↓0 = floor(4·(230-30)/45) = 17, N↓1 = floor(4·(160-30)/90) = 5.
        assert_eq!(p4.ladder[0].downscale_threshold, Some(17));
        assert_eq!(p4.ladder[1].downscale_threshold, Some(5));
        // k = 1 must reproduce the paper's numbers unchanged.
        assert_eq!(p1.workers, 1);
        assert_eq!(p1.ladder[0].upscale_threshold, 13);
        for (a, b) in p1.ladder.iter().zip(&p4.ladder) {
            assert!(b.upscale_threshold >= 4 * a.upscale_threshold);
            assert!(b.upscale_threshold < 4 * (a.upscale_threshold + 1));
        }
    }

    #[test]
    fn hysteresis_is_asymmetric() {
        let p = AqmParams::for_slo(1000.0);
        assert_eq!(p.up_cooldown_ms, 0.0);
        assert!(p.down_cooldown_ms >= 1000.0);
    }
}
