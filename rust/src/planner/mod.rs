//! Deployment planning (paper §III-A, §V): profile the feasible set on
//! target hardware, keep the Pareto-optimal configurations, and derive
//! AQM switching thresholds for the Elastico controller.
//!
//! Planning depends only on the deployment hardware: re-running this
//! stage (not the task optimization) is sufficient when the system moves
//! to new infrastructure.

pub mod aqm;
pub mod pareto;
pub mod plan;
pub mod profiler;

pub use aqm::{derive_plan, derive_plan_pools, AqmParams, ThresholdMode};
pub use pareto::{pareto_front, ProfiledConfig};
pub use plan::{ConfigPolicy, Plan};
pub use profiler::{
    fit_batch_model, profile_config, BatchServiceModel, ConfigRunner,
    LatencyProfile, BATCH_PROFILE_SIZES,
};
