//! Scenario matrix: sweep composable workload shapes × dispatch
//! topologies × policies — with failure injection — through the unified
//! DES (or the live server under `--live`) and emit per-cell SLO /
//! latency / dispatch metrics as `BENCH_scenarios.json` plus
//! `results/scenarios.csv`.
//!
//! Each cell draws its arrivals from a seeded [`ScenarioSpec`], so every
//! scenario replays bit-identically (and identically across the live
//! and simulated executors, which both consume the same `&[f64]`
//! arrival vector). `docs/SCENARIOS.md` is the cookbook: one entry per
//! scenario with the exact CLI invocation and the statistical signature
//! to expect; `ci/scenario_gate.py` checks the emitted JSON on every CI
//! run.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::common::{
    ctx_base_qps, make_policy, offline_phase_ctx, simulate_ctx_replan, ExperimentCtx,
    SLO_FACTORS,
};
use crate::metrics::RunSummary;
use crate::planner::{Plan, ThresholdMode};
use crate::runtime::artifacts_dir;
use crate::serving::executor::{MockEngine, WorkflowEngine};
use crate::serving::{
    parse_pools, serve, ClassSpec, Discipline, OverloadConfig, ReplanConfig, ResilienceConfig,
    ServeOptions,
};
use crate::sim::{LognormalService, ParetoService};
use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use crate::workflows::rag::RagWorkflow;
use crate::workload::trace::{load_trace, save_request_log_overload, save_trace};
use crate::workload::{Fault, FaultPlan, Generator, Pattern, ScenarioSpec};

/// Schema tag of `BENCH_scenarios.json` (checked by the CI gate).
pub const SCHEMA: &str = "compass.scenarios.v1";

/// Every scenario shape of the matrix, in cookbook order.
pub const SCENARIOS: [&str; 17] = [
    "steady",
    "diurnal",
    "flash_crowd",
    "mmpp",
    "heavy_tail",
    "correlated_surge",
    "pool_dark",
    "slowdown",
    "squeeze",
    "dark_recover",
    "dark_drain",
    "flaky",
    "overload_sustained",
    "overload_tail_drop",
    "overload_flash",
    "drift_replan",
    "drift_static",
];

/// The CI smoke subset: the steady baseline, both burst families, every
/// fault path the gate asserts on, the chaos cells (the windowed dark
/// failover/drain pair — which the ratio invariant compares on
/// identical arrivals — plus the flaky-engine retry cell), and the
/// overload pair (deadline-aware shedding vs its tail-drop twin on
/// identical ~1.5× arrivals), and the drift pair (online re-planning vs
/// the static plan under the same mid-run service drift).
pub const SMOKE_SCENARIOS: [&str; 12] = [
    "steady",
    "flash_crowd",
    "mmpp",
    "pool_dark",
    "squeeze",
    "dark_recover",
    "dark_drain",
    "flaky",
    "overload_sustained",
    "overload_tail_drop",
    "drift_replan",
    "drift_static",
];

/// Named dispatch topologies of the matrix.
pub const TOPOLOGIES: [&str; 3] = ["central-k1", "uniform-k4", "pooled-2x2"];

/// The CI smoke subset: the sharded uniform fleet and the
/// heterogeneous pools (the two shapes faults discriminate between).
pub const SMOKE_TOPOLOGIES: [&str; 2] = ["uniform-k4", "pooled-2x2"];

/// Policies of the full sweep (the smoke matrix drops Static-Fast).
pub const SWEEP_POLICIES: [&str; 3] = ["Elastico", "Static-Fast", "Static-Accurate"];

/// Policies of the smoke matrix.
pub const SMOKE_POLICIES: [&str; 2] = ["Elastico", "Static-Accurate"];

/// Sweep options beyond the shared [`ExperimentCtx`] knobs.
#[derive(Clone, Debug)]
pub struct ScenarioOpts {
    /// Run the reduced CI matrix ([`SMOKE_SCENARIOS`] ×
    /// [`SMOKE_TOPOLOGIES`] × [`SMOKE_POLICIES`]).
    pub smoke: bool,
    /// Explicit scenario names (empty = the smoke/full default set).
    pub scenarios: Vec<String>,
    /// Explicit topology names (empty = the smoke/full default set).
    pub topos: Vec<String>,
    /// Explicit policy names (empty = the smoke/full default set).
    pub policies: Vec<String>,
    /// SLO override in ms (default: 2.2× the slowest rung's mean, the
    /// paper's middle target).
    pub slo_ms: Option<f64>,
    /// Output path of the JSON artifact.
    pub out: PathBuf,
    /// Record a full request log per cell under this directory.
    pub log_dir: Option<PathBuf>,
    /// Replay a recorded arrival trace instead of generating arrivals
    /// (the one `replay` scenario then runs in every cell, so
    /// topologies/policies are compared on *identical* arrivals).
    pub replay: Option<PathBuf>,
    /// Fault-plan override applied to every cell (default: each
    /// scenario's own [`faults_for`] plan).
    pub faults: Option<FaultPlan>,
    /// Resilience override applied to every cell (default: each
    /// scenario's own [`resilience_for`] profile).
    pub resilience: Option<ResilienceConfig>,
    /// Overload-plane override applied to every cell (default: each
    /// scenario's own [`overload_for`] profile).
    pub overload: Option<OverloadConfig>,
    /// SLO class mix override (`--classes`) applied to whatever
    /// overload profile each cell runs.
    pub classes: Option<Vec<ClassSpec>>,
    /// Re-plan override applied to every cell (default: each scenario's
    /// own [`replan_for`] profile).
    pub replan: Option<ReplanConfig>,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts {
            smoke: false,
            scenarios: Vec::new(),
            topos: Vec::new(),
            policies: Vec::new(),
            slo_ms: None,
            out: PathBuf::from("BENCH_scenarios.json"),
            log_dir: None,
            replay: None,
            faults: None,
            resilience: None,
            overload: None,
            classes: None,
            replan: None,
        }
    }
}

/// FNV-1a over the scenario name: a stable per-scenario arrival-seed
/// salt, so scenarios decorrelate without any ordering coupling (adding
/// a scenario never changes another scenario's arrivals).
pub fn name_salt(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The arrival-seed salt a scenario actually uses. Almost always its
/// own [`name_salt`]; the exceptions are the salted *pairs*, which
/// share a salt so both cells run on *identical* arrivals and the
/// scenario-gate ratio invariants compare them head-to-head: the
/// windowed-dark pair `dark_recover` / `dark_drain` (failover vs
/// drain-reject) and the overload pair `overload_sustained` /
/// `overload_tail_drop` (deadline-aware shedding vs tail-drop).
pub fn arrival_salt(name: &str) -> u64 {
    match name {
        "dark_recover" | "dark_drain" => name_salt("dark_window"),
        "overload_sustained" | "overload_tail_drop" => name_salt("overload_pair"),
        "drift_replan" | "drift_static" => name_salt("drift_pair"),
        other => name_salt(other),
    }
}

/// The generator of a named scenario at base rate `qps` over `dur`
/// seconds. Shapes are expressed relative to the run length so the same
/// scenario stresses a 30 s smoke cell and a 180 s nightly cell alike.
pub fn generator_for(name: &str, qps: f64, dur: f64) -> Result<Generator> {
    Ok(match name {
        // Poisson baseline at the reference operating point (ρ ≈ 0.45).
        "steady" | "heavy_tail" | "pool_dark" | "slowdown" | "dark_recover" | "dark_drain"
        | "flaky" | "drift_replan" | "drift_static" => Generator::Constant { qps },
        // One full sinusoidal swing ±60% around the base rate.
        "diurnal" => Generator::Diurnal {
            qps,
            amplitude: 0.6,
            period_s: dur / 2.0,
            phase_s: 0.0,
        },
        // 5× flash crowd: ramp over 5% of the run, hold for 20%.
        "flash_crowd" => Generator::FlashCrowd {
            qps,
            peak_factor: 5.0,
            at_s: 0.4 * dur,
            ramp_s: 0.05 * dur,
            hold_s: 0.2 * dur,
        },
        // Two-state MMPP: calm 0.4× vs burst 2.5×, mean CV > 1.
        "mmpp" => Generator::Mmpp {
            qps: vec![0.4 * qps, 2.5 * qps],
            mean_dwell_s: vec![0.12 * dur, 0.05 * dur],
        },
        // Four clients whose 4× surges all fire in the same windows.
        "correlated_surge" => Generator::CorrelatedSurge {
            sources: 4,
            qps_per_source: qps / 4.0,
            peak_factor: 4.0,
            mean_gap_s: 0.15 * dur,
            surge_s: (0.03 * dur, 0.08 * dur),
        },
        // The seed-era bursty pattern feeding the admission squeeze.
        "squeeze" => Generator::Legacy { base_qps: qps, pattern: Pattern::paper_bursty() },
        // Sustained overload: the base rate targets ρ ≈ 0.45, so 10/3×
        // base is ρ ≈ 1.5 — constant ~1.5× the fleet's capacity for the
        // whole run. The queue only grows, and the admission policy
        // (deadline-aware vs the tail-drop twin, same arrivals) decides
        // who survives.
        "overload_sustained" | "overload_tail_drop" => {
            Generator::Constant { qps: 10.0 / 3.0 * qps }
        }
        // Flash overload: a 6× crowd held over a third of the run —
        // brownout and shedding engage and must *disengage* again.
        "overload_flash" => Generator::FlashCrowd {
            qps,
            peak_factor: 6.0,
            at_s: 0.3 * dur,
            ramp_s: 0.05 * dur,
            hold_s: 0.3 * dur,
        },
        other => bail!("unknown scenario {other}; known: {SCENARIOS:?}"),
    })
}

/// The overload profile a named scenario runs with: the overload cells
/// enable the plane (`overload_tail_drop` in tail mode — the twin the
/// gate's ratio invariant compares against); every other cell runs
/// disabled, which is pinned bit-identical to the pre-overload runtime.
pub fn overload_for(name: &str) -> OverloadConfig {
    match name {
        "overload_sustained" | "overload_flash" => OverloadConfig::enabled(),
        "overload_tail_drop" => OverloadConfig::tail_drop(),
        _ => OverloadConfig::default(),
    }
}

/// The fault plan a named scenario injects on a fleet of `n_pools`.
/// `pool_dark` darkens the *last* (most accurate) pool and therefore
/// needs a second pool to absorb the backlog — on a single-pool
/// topology the cell runs fault-free (and says so in its row).
pub fn faults_for(name: &str, dur: f64, n_pools: usize) -> FaultPlan {
    match name {
        "pool_dark" if n_pools > 1 => FaultPlan::none().with(Fault::PoolDark {
            pool: n_pools - 1,
            at_s: 0.4 * dur,
            until_s: None,
        }),
        // The windowed-dark pair: the same dark window over the middle
        // third of the run; `dark_recover` serves it with the
        // resilience plane on (failover + recovery), `dark_drain` with
        // it off (the PR-6 pause-out-the-window behavior) — identical
        // arrivals (see [`arrival_salt`]), so the gate's ratio
        // invariant compares exactly the resilience response.
        "dark_recover" | "dark_drain" if n_pools > 1 => {
            FaultPlan::none().with(Fault::PoolDark {
                pool: n_pools - 1,
                at_s: dur / 3.0,
                until_s: Some(2.0 * dur / 3.0),
            })
        }
        // A quarter of the first pool's requests fail over the middle
        // third of the run: the retry/breaker driver.
        "flaky" => FaultPlan::none().with(Fault::EngineFlaky {
            pool: 0,
            rate: 0.25,
            from_s: dur / 3.0,
            to_s: 2.0 * dur / 3.0,
        }),
        "slowdown" => FaultPlan::none().with(Fault::Slowdown {
            pool: 0,
            factor: 2.5,
            from_s: dur / 3.0,
            to_s: 2.0 * dur / 3.0,
        }),
        "squeeze" => FaultPlan::none().with(Fault::QueueSqueeze {
            capacity: 8,
            from_s: 0.4 * dur,
            to_s: 0.7 * dur,
        }),
        // The drift pair: the *last* (most accurate / slowest) pool's
        // service times shift ×2.5 a third into the run and never
        // recover — the regime change the online re-planner adapts to.
        // Identical plans in both cells ([`arrival_salt`] pairs the
        // arrivals too); `drift_replan` runs with the re-plan loop on,
        // `drift_static` with it off, so the gate's ratio invariant
        // compares exactly the adaptation response.
        "drift_replan" | "drift_static" => FaultPlan::none().with(Fault::Drift {
            pool: n_pools.saturating_sub(1),
            factor: 2.5,
            from_s: dur / 3.0,
            to_s: None,
        }),
        _ => FaultPlan::none(),
    }
}

/// The resilience profile a named scenario runs with. The chaos cells
/// that exercise the response (`dark_recover`, `flaky`) enable the
/// plane; every other cell — including `dark_drain`, the drain-reject
/// baseline of the ratio invariant — runs disabled, which is pinned
/// bit-identical to the pre-resilience runtime.
pub fn resilience_for(name: &str) -> ResilienceConfig {
    match name {
        "dark_recover" | "flaky" => ResilienceConfig::enabled(),
        _ => ResilienceConfig::default(),
    }
}

/// The re-plan profile a named scenario runs with: `drift_replan`
/// closes the adaptation loop (short fit gate so a 30 s smoke cell
/// converges well inside its drifted window); every other cell —
/// including `drift_static`, the stale-plan baseline of the ratio
/// invariant — runs disabled, which is pinned bit-identical to the
/// static runtime.
pub fn replan_for(name: &str) -> ReplanConfig {
    match name {
        "drift_replan" => ReplanConfig {
            enabled: true,
            min_samples: 8,
            ..ReplanConfig::default()
        },
        _ => ReplanConfig::default(),
    }
}

/// Resolve a named topology into an experiment ctx (duration, seed,
/// live flag, batch and out dir inherited from `base`).
pub fn topo_ctx(name: &str, base: &ExperimentCtx) -> Result<ExperimentCtx> {
    let mut ctx = base.clone();
    ctx.pools = Vec::new();
    ctx.spill_margin = 0.0;
    ctx.thresholds = ThresholdMode::Legacy;
    ctx.shards = 0;
    match name {
        "central-k1" => {
            ctx.workers = 1;
            ctx.discipline = Discipline::CentralFifo;
        }
        "uniform-k4" => {
            ctx.workers = 4;
            ctx.discipline = Discipline::ShardedSteal;
        }
        "pooled-2x2" => {
            ctx.workers = 1;
            ctx.discipline = Discipline::ShardedSteal;
            ctx.pools = parse_pools("fast:2:1.0,accurate:2:2.5")?;
            ctx.thresholds = ThresholdMode::ErlangC;
        }
        other => bail!("unknown topology {other}; known: {TOPOLOGIES:?}"),
    }
    Ok(ctx)
}

/// One swept cell's metrics: a row of the CSV, an object in the JSON.
#[derive(Clone, Debug)]
pub struct CellOut {
    pub scenario: String,
    pub topo: String,
    pub policy: String,
    pub arrivals: usize,
    pub served: usize,
    pub rejected: usize,
    pub slo_compliance: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_accuracy: f64,
    pub switches: usize,
    pub steals: u64,
    pub spills: u64,
    pub n_pools: usize,
    pub faults: String,
    /// Terminal failures (extended conservation:
    /// `served + rejected + failed == arrivals`).
    pub failed: usize,
    pub retries: u64,
    pub panics_recovered: u64,
    pub timeouts: u64,
    pub breaker_trips: u64,
    pub failovers: u64,
    /// SLO-compliant *goodput*: `slo_compliance · served / arrivals`.
    /// Unlike plain compliance — computed over survivors only, which
    /// flatters a cell that rejects its hardest requests — goodput
    /// charges every lost request, so it is the failover-vs-drain
    /// comparison metric the ratio invariant gates on.
    pub slo_goodput: f64,
    /// `on`/`off` — the cell's resilience profile.
    pub resilience: String,
    /// Arrivals shed by overload admission (conservation extends to
    /// `served + rejected + failed + shed + expired == arrivals`).
    pub shed: usize,
    /// Queued requests expired at pop time (lazy in-queue expiry).
    pub expired: usize,
    /// Brownout rung-degradation steps taken over the run.
    pub brownout_steps: u64,
    /// Highest-class SLO compliance *per offered arrival* of that class
    /// (a shed or expired gold request counts against it) — the metric
    /// the overload-pair ratio invariant gates on. With the plane off
    /// this is the one implicit class, i.e. `slo_goodput`-style overall
    /// compliance per arrival.
    pub gold_compliance: f64,
    /// `deadline`/`tail`/`off` — the cell's overload profile.
    pub overload: String,
    /// Re-derived plans the policy adopted over the run (0 with the
    /// loop off, and ≥ 1 is what the drift-pair gate asserts on).
    pub replans: u64,
    /// `on`/`off` — the cell's re-plan profile.
    pub replan: String,
}

impl CellOut {
    /// Cell key in the JSON `cells` object.
    pub fn key(&self) -> String {
        format!("{}|{}|{}", self.scenario, self.topo, self.policy)
    }

    /// The JSON object of one cell in `BENCH_scenarios.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrivals", Json::num(self.arrivals as f64)),
            ("served", Json::num(self.served as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("slo_compliance", Json::num(self.slo_compliance)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("mean_accuracy", Json::num(self.mean_accuracy)),
            ("switches", Json::num(self.switches as f64)),
            ("steals", Json::num(self.steals as f64)),
            ("spills", Json::num(self.spills as f64)),
            ("n_pools", Json::num(self.n_pools as f64)),
            ("faults", Json::str(self.faults.clone())),
            ("failed", Json::num(self.failed as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("panics_recovered", Json::num(self.panics_recovered as f64)),
            ("timeouts", Json::num(self.timeouts as f64)),
            ("breaker_trips", Json::num(self.breaker_trips as f64)),
            ("failovers", Json::num(self.failovers as f64)),
            ("slo_goodput", Json::num(self.slo_goodput)),
            ("resilience", Json::str(self.resilience.clone())),
            ("shed", Json::num(self.shed as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("brownout_steps", Json::num(self.brownout_steps as f64)),
            ("gold_compliance", Json::num(self.gold_compliance)),
            ("overload", Json::str(self.overload.clone())),
            ("replans", Json::num(self.replans as f64)),
            ("replan", Json::str(self.replan.clone())),
        ])
    }
}

const CSV_HEADER: [&str; 31] = [
    "scenario",
    "topo",
    "policy",
    "arrivals",
    "served",
    "rejected",
    "slo_compliance",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "mean_accuracy",
    "switches",
    "steals",
    "spills",
    "n_pools",
    "faults",
    "failed",
    "retries",
    "panics_recovered",
    "timeouts",
    "breaker_trips",
    "failovers",
    "slo_goodput",
    "resilience",
    "shed",
    "expired",
    "brownout_steps",
    "gold_compliance",
    "overload",
    "replans",
    "replan",
];

/// Run one scenario × topology × policy cell — the DES by default, the
/// live server under `ctx.live` — and summarize it. The same arrival
/// vector, fault plan and overload profile feed both executors (the
/// live server additionally receives the plan ladder's means as its
/// admission-budget hint, the same numbers the DES reads directly).
#[allow(clippy::too_many_arguments)]
pub fn run_matrix_cell(
    ctx: &ExperimentCtx,
    space: &crate::configspace::ConfigSpace,
    plan: &Plan,
    scenario: &str,
    topo_name: &str,
    policy_name: &str,
    arrivals: &[f64],
    faults: &FaultPlan,
    resilience: &ResilienceConfig,
    overload: &OverloadConfig,
    replan: &ReplanConfig,
    slo_ms: f64,
    log_dir: Option<&Path>,
) -> Result<CellOut> {
    let topo = ctx.topology()?;
    let mut policy = make_policy(plan, policy_name);
    let rung_means: Vec<f64> = plan.ladder.iter().map(|r| r.mean_ms).collect();
    let ov = overload.clone().with_rung_means(rung_means);
    // The live re-planner needs the base plan it re-derives attached to
    // the config; the DES receives the plan directly.
    let rp = if replan.enabled {
        replan.clone().with_plan(plan.clone())
    } else {
        replan.clone()
    };
    let (records, switches, rejected, steals, spills, counters) = if ctx.live {
        let opts = ServeOptions {
            workers: ctx.workers.max(1),
            discipline: ctx.discipline,
            shards: ctx.shards,
            batch: ctx.batch.max(1),
            pools: ctx.pools.clone(),
            spill_margin: ctx.spill_margin,
            faults: faults.clone(),
            resilience: resilience.clone(),
            overload: ov.clone(),
            replan: rp.clone(),
            backend: ctx.backend,
            ..ServeOptions::default()
        };
        let out = if artifacts_dir().exists() {
            let space2 = space.clone();
            let plan2 = plan.clone();
            let seed = ctx.seed;
            serve(
                move || {
                    let configs: Vec<_> =
                        plan2.ladder.iter().map(|p| p.config.clone()).collect();
                    let wf = RagWorkflow::load_subset(
                        &artifacts_dir(),
                        &space2,
                        &configs,
                        seed,
                    )?;
                    Ok(WorkflowEngine::new(wf, space2.clone(), plan2.clone()))
                },
                policy,
                arrivals,
                &opts,
            )?
        } else {
            // No PJRT artifacts on this machine (e.g. CI): serve the
            // plan ladder through a scripted engine instead — each rung
            // busy-waits its profiled mean. The queueing plane (backend,
            // shards, batches, AQM, faults) is exercised for real; only
            // the workflow compute is replayed.
            let service_ms: Vec<f64> = plan.ladder.iter().map(|r| r.mean_ms).collect();
            let accuracy: Vec<f64> = plan.ladder.iter().map(|r| r.accuracy).collect();
            serve(
                move || {
                    Ok(MockEngine {
                        service_ms: service_ms.clone(),
                        accuracy: accuracy.clone(),
                        dispatch_ms: 0.0,
                    })
                },
                policy,
                arrivals,
                &opts,
            )?
        };
        (
            out.records,
            out.switches,
            out.rejected,
            out.steals,
            out.spills,
            (
                out.failed,
                out.retries,
                out.panics_recovered,
                out.timeouts,
                out.breaker_trips,
                out.failovers,
                out.shed,
                out.expired,
                out.brownout_steps,
                out.replans,
            ),
        )
    } else {
        // Heavy-tailed cells swap the lognormal service model for a
        // Pareto tail (α = 2.05: finite mean, near-infinite variance).
        let out = if scenario == "heavy_tail" {
            let svc = ParetoService::from_plan(plan, 2.05);
            simulate_ctx_replan(
                ctx, arrivals, plan, &mut policy, &svc, faults, resilience, &ov, &rp,
            )?
        } else {
            let svc = LognormalService::from_plan(plan, 0.10);
            simulate_ctx_replan(
                ctx, arrivals, plan, &mut policy, &svc, faults, resilience, &ov, &rp,
            )?
        };
        (
            out.records,
            out.switches,
            out.rejected,
            out.steals,
            out.spills,
            (
                out.failed,
                out.retries,
                out.panics_recovered,
                out.timeouts,
                out.breaker_trips,
                out.failovers,
                out.shed,
                out.expired,
                out.brownout_steps,
                out.replans,
            ),
        )
    };
    let (
        failed,
        retries,
        panics_recovered,
        timeouts,
        breaker_trips,
        failovers,
        shed,
        expired,
        bsteps,
        replans,
    ) = counters;
    if let Some(dir) = log_dir {
        let file = format!("{scenario}__{topo_name}__{policy_name}.csv");
        save_request_log_overload(&dir.join(file), &records, &topo, &ov)?;
    }
    let summary = RunSummary::compute(&records, &switches, slo_ms, plan.ladder.len());
    let slo_goodput = if arrivals.is_empty() {
        0.0
    } else {
        summary.slo_compliance * records.len() as f64 / arrivals.len() as f64
    };
    // Highest class first: class 0's per-arrival compliance (the one
    // implicit class when the plane is off).
    let by_class = ov.class_compliance(&records, arrivals.len(), slo_ms);
    let gold_compliance = by_class.first().copied().unwrap_or(1.0);
    Ok(CellOut {
        scenario: scenario.into(),
        topo: topo_name.into(),
        policy: policy_name.into(),
        arrivals: arrivals.len(),
        served: records.len(),
        rejected,
        slo_compliance: summary.slo_compliance,
        p50_ms: summary.latency.p50,
        p95_ms: summary.latency.p95,
        p99_ms: summary.latency.p99,
        mean_accuracy: summary.mean_accuracy,
        switches: switches.len(),
        steals,
        spills,
        n_pools: topo.n_pools(),
        faults: faults.describe(),
        failed,
        retries,
        panics_recovered,
        timeouts,
        breaker_trips,
        failovers,
        slo_goodput,
        resilience: if resilience.enabled { "on".into() } else { "off".into() },
        shed,
        expired,
        brownout_steps: bsteps,
        gold_compliance,
        overload: if !ov.enabled {
            "off".into()
        } else if ov.deadline_aware {
            "deadline".into()
        } else {
            "tail".into()
        },
        replans,
        replan: if rp.enabled { "on".into() } else { "off".into() },
    })
}

/// Generate one scenario's arrival trace (at the named topology's base
/// rate) and save it as a replayable CSV (`--replay` feeds it back).
pub fn save_scenario_trace(
    ctx: &ExperimentCtx,
    scenario: &str,
    topo_name: &str,
    path: &Path,
) -> Result<()> {
    let tctx = topo_ctx(topo_name, ctx)?;
    let (_space, full) = offline_phase_ctx(&tctx, 0.75, 1e9, false)?;
    let qps = ctx_base_qps(&tctx, &full);
    let spec = ScenarioSpec {
        generator: generator_for(scenario, qps, ctx.duration_s)?,
        duration_s: ctx.duration_s,
        seed: ctx.seed ^ arrival_salt(scenario),
    };
    let arrivals = spec.arrivals();
    save_trace(path, &arrivals)?;
    println!("wrote {} ({} arrivals, scenario {scenario})", path.display(), arrivals.len());
    Ok(())
}

/// Entry for `compass experiment scenarios`: the full matrix with
/// default options.
pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    run_sweep(ctx, &ScenarioOpts::default())
}

/// Run the scenario sweep; write `BENCH_scenarios.json`, the CSV and a
/// console table.
pub fn run_sweep(ctx: &ExperimentCtx, opts: &ScenarioOpts) -> Result<()> {
    let replayed: Option<Vec<f64>> = match &opts.replay {
        Some(path) => Some(load_trace(path)?),
        None => None,
    };
    let scenarios: Vec<String> = if replayed.is_some() {
        vec!["replay".into()]
    } else if !opts.scenarios.is_empty() {
        opts.scenarios.clone()
    } else if opts.smoke {
        SMOKE_SCENARIOS.iter().map(|s| s.to_string()).collect()
    } else {
        SCENARIOS.iter().map(|s| s.to_string()).collect()
    };
    let topos: Vec<String> = if !opts.topos.is_empty() {
        opts.topos.clone()
    } else if opts.smoke {
        SMOKE_TOPOLOGIES.iter().map(|s| s.to_string()).collect()
    } else {
        TOPOLOGIES.iter().map(|s| s.to_string()).collect()
    };
    let policies: Vec<String> = if !opts.policies.is_empty() {
        opts.policies.clone()
    } else if opts.smoke {
        SMOKE_POLICIES.iter().map(|s| s.to_string()).collect()
    } else {
        SWEEP_POLICIES.iter().map(|s| s.to_string()).collect()
    };

    // One probe fixes the ladder and the SLO; each topology then
    // re-derives worker/pool-aware thresholds over the same front.
    let (_probe_space, probe) = offline_phase_ctx(ctx, 0.75, 1e9, ctx.live)?;
    let default_slo = SLO_FACTORS[1] * probe.ladder.last().unwrap().mean_ms;
    let slo = opts.slo_ms.unwrap_or(default_slo);

    let mut csv = CsvWriter::create(&ctx.out_dir.join("scenarios.csv"), &CSV_HEADER)?;
    let mut cells: Vec<CellOut> = Vec::new();
    println!(
        "Scenario matrix: {} scenario(s) x {} topolog(y/ies) x {} policy(ies), \
         SLO {slo:.0} ms, {:.0} s cells{}",
        scenarios.len(),
        topos.len(),
        policies.len(),
        ctx.duration_s,
        if ctx.live { " (live)" } else { " (DES)" }
    );
    for topo_name in &topos {
        let tctx = topo_ctx(topo_name, ctx)?;
        let (space, full) = offline_phase_ctx(&tctx, 0.75, 1e9, false)?;
        let (_s2, plan) = offline_phase_ctx(&tctx, 0.75, slo, false)?;
        let qps = ctx_base_qps(&tctx, &full);
        let n_pools = tctx.topology()?.n_pools();
        for scenario in &scenarios {
            let arrivals = match &replayed {
                Some(a) => a.clone(),
                None => ScenarioSpec {
                    generator: generator_for(scenario, qps, ctx.duration_s)?,
                    duration_s: ctx.duration_s,
                    seed: ctx.seed ^ arrival_salt(scenario),
                }
                .arrivals(),
            };
            let faults = match &opts.faults {
                Some(f) => f.clone(),
                None => faults_for(scenario, ctx.duration_s, n_pools),
            };
            let resilience = match &opts.resilience {
                Some(r) => r.clone(),
                None => resilience_for(scenario),
            };
            let overload = match &opts.overload {
                Some(o) => o.clone(),
                None => overload_for(scenario),
            };
            let overload = match &opts.classes {
                Some(c) => overload.with_classes(c.clone()),
                None => overload,
            };
            let replan = match &opts.replan {
                Some(r) => r.clone(),
                None => replan_for(scenario),
            };
            for policy in &policies {
                // As everywhere: Elastico adapts over the SLO-filtered
                // ladder, the static baselines keep their full-front rung.
                let policy_plan = if policy == "Elastico" { &plan } else { &full };
                let cell = run_matrix_cell(
                    &tctx,
                    &space,
                    policy_plan,
                    scenario,
                    topo_name,
                    policy,
                    &arrivals,
                    &faults,
                    &resilience,
                    &overload,
                    &replan,
                    slo,
                    opts.log_dir.as_deref(),
                )?;
                println!(
                    "  {:<17} {:<11} {:<15} comp {:>5.1}%  p95 {:>8.1} ms  \
                     rej {:>5}  fail {:>4}  retry {:>4}  steal {:>6}  spill {:>5}",
                    cell.scenario,
                    cell.topo,
                    cell.policy,
                    cell.slo_compliance * 100.0,
                    cell.p95_ms,
                    cell.rejected,
                    cell.failed,
                    cell.retries,
                    cell.steals,
                    cell.spills
                );
                csv.row(&[
                    cell.scenario.clone(),
                    cell.topo.clone(),
                    cell.policy.clone(),
                    cell.arrivals.to_string(),
                    cell.served.to_string(),
                    cell.rejected.to_string(),
                    format!("{:.4}", cell.slo_compliance),
                    format!("{:.2}", cell.p50_ms),
                    format!("{:.2}", cell.p95_ms),
                    format!("{:.2}", cell.p99_ms),
                    format!("{:.4}", cell.mean_accuracy),
                    cell.switches.to_string(),
                    cell.steals.to_string(),
                    cell.spills.to_string(),
                    cell.n_pools.to_string(),
                    cell.faults.clone(),
                    cell.failed.to_string(),
                    cell.retries.to_string(),
                    cell.panics_recovered.to_string(),
                    cell.timeouts.to_string(),
                    cell.breaker_trips.to_string(),
                    cell.failovers.to_string(),
                    format!("{:.4}", cell.slo_goodput),
                    cell.resilience.clone(),
                    cell.shed.to_string(),
                    cell.expired.to_string(),
                    cell.brownout_steps.to_string(),
                    format!("{:.4}", cell.gold_compliance),
                    cell.overload.clone(),
                    cell.replans.to_string(),
                    cell.replan.clone(),
                ])?;
                cells.push(cell);
            }
        }
    }
    csv.flush()?;

    let keys: Vec<String> = cells.iter().map(CellOut::key).collect();
    let cell_obj = Json::obj(
        keys.iter()
            .zip(&cells)
            .map(|(k, c)| (k.as_str(), c.to_json()))
            .collect(),
    );
    let doc = Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("duration_s", Json::num(ctx.duration_s)),
        ("seed", Json::num(ctx.seed as f64)),
        ("slo_ms", Json::num(slo)),
        ("cells", cell_obj),
    ]);
    std::fs::write(&opts.out, doc.to_string())?;
    println!("-> {} ({} cells) and results/scenarios.csv", opts.out.display(), cells.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn salts_are_stable_and_distinct() {
        assert_eq!(name_salt("steady"), name_salt("steady"));
        let mut seen = std::collections::BTreeSet::new();
        for s in SCENARIOS {
            assert!(seen.insert(name_salt(s)), "salt collision on {s}");
        }
    }

    #[test]
    fn every_scenario_has_a_generator() {
        for s in SCENARIOS {
            generator_for(s, 5.0, 60.0).unwrap();
        }
        assert!(generator_for("nope", 5.0, 60.0).is_err());
    }

    #[test]
    fn pool_dark_needs_a_second_pool() {
        assert!(faults_for("pool_dark", 60.0, 1).is_empty());
        assert!(!faults_for("pool_dark", 60.0, 2).is_empty());
        assert!(!faults_for("slowdown", 60.0, 1).is_empty());
        assert!(!faults_for("squeeze", 60.0, 1).is_empty());
        assert!(faults_for("steady", 60.0, 4).is_empty());
        // The windowed-dark pair also needs a survivor pool; flaky
        // works on any fleet (it targets pool 0's engine, not routing).
        assert!(faults_for("dark_recover", 60.0, 1).is_empty());
        assert!(!faults_for("dark_recover", 60.0, 2).is_empty());
        assert!(!faults_for("flaky", 60.0, 1).is_empty());
    }

    #[test]
    fn the_dark_pair_shares_arrivals_and_differs_only_in_resilience() {
        // Identical fault plans + identical arrival salts: the ratio
        // invariant compares the two cells on the same offered load.
        assert_eq!(arrival_salt("dark_recover"), arrival_salt("dark_drain"));
        assert_ne!(arrival_salt("dark_recover"), name_salt("dark_recover"));
        assert_eq!(
            faults_for("dark_recover", 60.0, 2).describe(),
            faults_for("dark_drain", 60.0, 2).describe()
        );
        assert!(resilience_for("dark_recover").enabled);
        assert!(!resilience_for("dark_drain").enabled);
        assert!(resilience_for("flaky").enabled);
        assert!(!resilience_for("steady").enabled);
        // Every scenario outside the salted pairs keeps its own salt.
        let paired = [
            "dark_recover",
            "dark_drain",
            "overload_sustained",
            "overload_tail_drop",
            "drift_replan",
            "drift_static",
        ];
        for s in SCENARIOS {
            if !paired.contains(&s) {
                assert_eq!(arrival_salt(s), name_salt(s));
            }
        }
    }

    #[test]
    fn the_overload_pair_shares_arrivals_and_differs_only_in_shed_mode() {
        // Same offered load, same classes; the only difference is how the
        // admission gate picks a victim (deadline-aware vs tail drop).
        assert_eq!(arrival_salt("overload_sustained"), arrival_salt("overload_tail_drop"));
        assert_ne!(arrival_salt("overload_sustained"), name_salt("overload_sustained"));
        let aware = overload_for("overload_sustained");
        let tail = overload_for("overload_tail_drop");
        assert!(aware.enabled && aware.deadline_aware);
        assert!(tail.enabled && !tail.deadline_aware);
        assert_eq!(aware.classes, tail.classes);
        assert!(overload_for("overload_flash").enabled);
        assert!(!overload_for("steady").enabled);
        // The twins see byte-identical arrival processes.
        let a = generator_for("overload_sustained", 8.0, 60.0).unwrap();
        let b = generator_for("overload_tail_drop", 8.0, 60.0).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn the_drift_pair_shares_arrivals_and_differs_only_in_replanning() {
        // Identical arrivals, identical fault plans: the ratio invariant
        // compares exactly the adaptation response.
        assert_eq!(arrival_salt("drift_replan"), arrival_salt("drift_static"));
        assert_ne!(arrival_salt("drift_replan"), name_salt("drift_replan"));
        assert_eq!(
            faults_for("drift_replan", 60.0, 2).describe(),
            faults_for("drift_static", 60.0, 2).describe()
        );
        // The drift targets the last (slowest) pool and never recovers.
        assert!(faults_for("drift_replan", 60.0, 2).any_drift());
        assert!(replan_for("drift_replan").enabled);
        assert!(!replan_for("drift_static").enabled);
        assert!(!replan_for("steady").enabled);
        // The off profile is the inert default (bit-identity pin rides
        // on this in tests/replan.rs).
        assert_eq!(replan_for("drift_static"), ReplanConfig::default());
    }

    #[test]
    fn topologies_resolve_to_dispatch_shapes() {
        let base = ExperimentCtx::default();
        let shapes: Vec<(usize, usize)> = TOPOLOGIES
            .iter()
            .map(|t| {
                let topo = topo_ctx(t, &base).unwrap().topology().unwrap();
                (topo.n_pools(), topo.n_workers())
            })
            .collect();
        assert_eq!(shapes, vec![(1, 1), (1, 4), (2, 4)]);
        assert!(topo_ctx("nope", &base).is_err());
    }
}
