//! Fig. 6 — latency CDFs under the middle SLO target (≙ 1000 ms), spike
//! pattern, all four policies.

use anyhow::Result;

use super::common::{
    ctx_base_qps, offline_phase_ctx, run_cell, Cell, ExperimentCtx, POLICIES,
    SLO_FACTORS,
};
use crate::metrics::latency_cdf;
use crate::util::csv::CsvWriter;
use crate::workload::Pattern;

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    let k = ctx.total_workers();
    let b = ctx.batch.max(1);
    let (_s, full) = offline_phase_ctx(ctx, 0.75, 1e9, ctx.live)?;
    let slo = SLO_FACTORS[1] * full.ladder.last().unwrap().mean_ms;
    let (space, plan) = offline_phase_ctx(ctx, 0.75, slo, false)?;
    let qps = ctx_base_qps(ctx, &full);

    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("fig6_cdf.csv"),
        &["policy", "latency_ms", "fraction"],
    )?;

    println!(
        "Fig.6: latency CDFs, spike pattern, SLO {slo:.0} ms, {k} worker(s), \
         {}, batch {b}",
        ctx.dispatch_desc()
    );
    for policy in POLICIES {
        let cell = Cell {
            pattern_name: "spike",
            pattern: Pattern::paper_spike(),
            slo_ms: slo,
            policy_name: policy.into(),
            base_qps: qps,
        };
        let policy_plan = if policy == "Elastico" { &plan } else { &full };
        let (records, _sw, summary) = run_cell(ctx, &space, policy_plan, &cell)?;
        let cdf = latency_cdf(&records, 200);
        for (lat, frac) in &cdf {
            csv.row(&[
                policy.into(),
                format!("{lat:.2}"),
                format!("{frac:.4}"),
            ])?;
        }
        // The paper's reading: fraction of requests within the SLO.
        let within = records
            .iter()
            .filter(|r| r.latency_ms() <= slo)
            .count() as f64
            / records.len().max(1) as f64;
        println!(
            "  {:<16} P(T<=SLO) {:>5.1}%  p50 {:>8.1}ms  p95 {:>8.1}ms  max {:>9.1}ms",
            policy,
            within * 100.0,
            summary.latency.p50,
            summary.latency.p95,
            summary.latency.max
        );
    }
    csv.flush()?;
    println!("-> results/fig6_cdf.csv");
    Ok(())
}
