//! Shared experiment machinery: context, latency models, plan building,
//! and the serving-cell runner (simulated or live).

use std::path::PathBuf;

use anyhow::Result;

use crate::configspace::{rag_space, Config, ConfigSpace};
use crate::metrics::{RequestRecord, RunSummary, SwitchEvent};
use crate::oracle::rag::RagLandscape;
use crate::oracle::{Landscape, RagOracle};
use crate::planner::{
    derive_plan, derive_plan_pools, pareto_front, profile_config, AqmParams,
    LatencyProfile, Plan, ProfiledConfig, ThresholdMode,
};
use crate::runtime::artifacts_dir;
use crate::search::{CompassV, CompassVParams};
use crate::serving::executor::WorkflowEngine;
use crate::serving::pool::{capacity_factor, total_workers, PoolSpec};
use crate::serving::{
    serve, Discipline, ElasticoPolicy, QueueBackend, ScalingPolicy, ServeOptions, StaticPolicy,
    Topology,
};
use crate::sim::LognormalService;
use crate::util::results_dir;
use crate::workflows::rag::RagWorkflow;
use crate::workload::{generate_arrivals, Pattern, WorkloadSpec};

/// Options shared by every experiment.
#[derive(Clone, Debug)]
pub struct ExperimentCtx {
    /// Run serving cells on the live PJRT server (default: discrete-event
    /// simulation from live-profiled latencies — same controller code).
    pub live: bool,
    /// Serving run duration per cell, seconds (paper: 180).
    pub duration_s: f64,
    /// Master seed.
    pub seed: u64,
    /// Executor worker pool size k (M/G/k; 1 = the paper's testbed).
    /// Plans are derived with worker-aware thresholds and serving cells
    /// run k executors (live) or k simulated servers.
    pub workers: usize,
    /// Queue discipline for serving cells (live and simulated): central
    /// FIFO (the paper's testbed) or per-worker shards + work stealing.
    pub discipline: Discipline,
    /// Shard count under the sharded discipline (0 = one per worker).
    pub shards: usize,
    /// Executor batch bound B: requests dequeued/executed per dispatch
    /// (1 = the paper's unbatched testbed). Plans are derived with the
    /// batch-aware AQM model and serving cells (live and simulated)
    /// dispatch in batches of up to B.
    pub batch: usize,
    /// Heterogeneous pool topology for serving cells (empty = the
    /// homogeneous `workers` runtime). Plans are derived with per-pool
    /// thresholds and cells run the pooled server/DES.
    pub pools: Vec<PoolSpec>,
    /// Cost-aware spill margin (0 = spill-when-dry; see
    /// [`crate::serving::Topology::spill_allowed`]). Only meaningful on
    /// a multi-pool topology.
    pub spill_margin: f64,
    /// Threshold derivation rule (legacy k-scaling by default; `erlang`
    /// = Erlang-C waiting-probability thresholds).
    pub thresholds: ThresholdMode,
    /// Shard storage backend for live serving cells (`--queue
    /// mutex|ring`): locked `VecDeque` shards (the seed default) or the
    /// lock-free bounded MPMC rings. Simulated cells are unaffected —
    /// the DES has no locks to replace.
    pub backend: QueueBackend,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
}

impl Default for ExperimentCtx {
    fn default() -> Self {
        ExperimentCtx {
            live: false,
            duration_s: 180.0,
            seed: 7,
            workers: 1,
            discipline: Discipline::CentralFifo,
            shards: 0,
            batch: 1,
            pools: Vec::new(),
            spill_margin: 0.0,
            thresholds: ThresholdMode::Legacy,
            backend: QueueBackend::Mutex,
            out_dir: results_dir(),
        }
    }
}

impl ExperimentCtx {
    /// Total executor workers of the cell's fleet.
    pub fn total_workers(&self) -> usize {
        if self.pools.is_empty() {
            self.workers.max(1)
        } else {
            total_workers(&self.pools)
        }
    }

    /// The dispatch [`Topology`] of this ctx's serving cells — the one
    /// decision core both the live server and the DES engine execute:
    /// a uniform pool honoring `workers`/`discipline`/`shards`, or the
    /// explicit heterogeneous pools with the ctx's spill margin.
    pub fn topology(&self) -> Result<Topology> {
        if self.pools.is_empty() {
            let workers = self.workers.max(1);
            let shards = self.discipline.effective_shards(workers, self.shards);
            Ok(Topology::uniform(workers, shards))
        } else {
            Topology::from_pools(&self.pools, self.spill_margin)
        }
    }

    /// One-line dispatch description for experiment headers.
    pub fn dispatch_desc(&self) -> String {
        if self.pools.is_empty() {
            format!("{} dispatch", self.discipline.name())
        } else if self.spill_margin > 0.0 {
            format!(
                "pools {} ({} thresholds, spill margin {})",
                crate::serving::pool::describe_pools(&self.pools),
                self.thresholds.name(),
                self.spill_margin
            )
        } else {
            format!(
                "pools {} ({} thresholds)",
                crate::serving::pool::describe_pools(&self.pools),
                self.thresholds.name()
            )
        }
    }
}

// ---------------------------------------------------------------------
// Latency models
// ---------------------------------------------------------------------

/// Per-generator mean service cost (ms), measured on this testbed via
/// `compass profile` (see EXPERIMENTS.md §Setup). Used by the *modeled*
/// planner path; `--live` re-measures everything.
pub const GEN_MS: [f64; 6] = [1.0, 1.8, 5.1, 10.7, 22.8, 42.2];
/// Per-reranker cost per batch of 5 candidates (ms).
pub const RR_BATCH_MS: [f64; 3] = [0.85, 2.0, 8.0];
/// Retriever cost (ms).
pub const RETRIEVER_MS: f64 = 0.25;
/// Modeled p95/mean inflation (measured dispersion of the live stack).
pub const P95_FACTOR: f64 = 1.10;
/// Modeled per-dispatch fixed cost α (ms) of the batch service-time
/// model `s̄(B) = α + β·B`: rung resolution + engine call setup + the
/// policy observation, measured on this testbed via the B∈{1,4,8}
/// profile sweep (`compass profile`). `--live` re-fits it through
/// [`crate::planner::fit_batch_model`].
pub const DISPATCH_MS: f64 = 0.5;

/// Modeled mean latency of a RAG configuration on this testbed.
pub fn modeled_latency_ms(space: &ConfigSpace, cfg: &Config) -> f64 {
    let gen = space.named_value(cfg, "generator").to_string();
    let rr = space.named_value(cfg, "reranker").to_string();
    let k = space.named_value(cfg, "retriever_k").as_f64().unwrap();
    let gi = crate::workflows::rag::GENERATOR_NAMES
        .iter()
        .position(|n| *n == gen)
        .unwrap();
    let ri = crate::workflows::rag::RERANKER_NAMES
        .iter()
        .position(|n| *n == rr)
        .unwrap();
    let batches = (k / 5.0).ceil().max(1.0);
    RETRIEVER_MS + GEN_MS[gi] + batches * RR_BATCH_MS[ri]
}

/// Profile a config: live workflow when available, modeled otherwise.
pub fn latency_profile(
    space: &ConfigSpace,
    cfg: &Config,
    live: Option<&mut RagWorkflow>,
    runs: usize,
) -> LatencyProfile {
    match live {
        Some(wf) => profile_config(wf, space, cfg, 1, runs),
        None => {
            let mean = modeled_latency_ms(space, cfg);
            LatencyProfile {
                mean_ms: mean,
                p50_ms: mean,
                p95_ms: mean * P95_FACTOR,
                runs: 0,
            }
        }
    }
}

// ---------------------------------------------------------------------
// Offline phase: search + profile + plan
// ---------------------------------------------------------------------

/// The candidate sub-grid profiled for serving plans: all generators and
/// rerankers at three retrieval settings (the latency-relevant axes).
/// Carries the search's accuracy estimate per configuration.
pub fn plan_candidates(
    space: &ConfigSpace,
    feasible: &[(Config, f64)],
) -> Vec<(Config, f64)> {
    let mut picked = Vec::new();
    for (cfg, est) in feasible {
        let k = space.named_value(cfg, "retriever_k").as_f64().unwrap();
        let rk = space.named_value(cfg, "rerank_k").as_f64().unwrap();
        if (k == 5.0 || k == 20.0 || k == 50.0) && (rk == 3.0 || rk == 1.0) {
            picked.push((cfg.clone(), *est));
        }
    }
    picked
}

/// Run the full offline phase for the RAG workflow at threshold τ on a
/// single-server deployment — see [`offline_phase_k`].
pub fn offline_phase(
    tau: f64,
    slo_ms: f64,
    seed: u64,
    live: bool,
) -> Result<(ConfigSpace, Plan)> {
    offline_phase_k(tau, slo_ms, seed, live, 1)
}

/// Run the full offline phase for the RAG workflow at threshold τ for a
/// pool of `workers` unbatched executors — see [`offline_phase_kb`].
pub fn offline_phase_k(
    tau: f64,
    slo_ms: f64,
    seed: u64,
    live: bool,
    workers: usize,
) -> Result<(ConfigSpace, Plan)> {
    offline_phase_kb(tau, slo_ms, seed, live, workers, 1)
}

/// Run the full offline phase for the RAG workflow at threshold τ:
/// COMPASS-V search on the oracle, profile candidates (live or modeled),
/// Pareto-reduce, derive the AQM plan at `slo_ms` for a pool of
/// `workers` executors dispatching batches of up to `batch` requests
/// (worker- and batch-aware queue-depth thresholds). At `batch > 1` the
/// per-dispatch fixed cost α of `s̄(B) = α + β·B` is fit live through
/// the B∈{1,4,8} batch profile, or taken from the modeled
/// [`DISPATCH_MS`] otherwise.
pub fn offline_phase_kb(
    tau: f64,
    slo_ms: f64,
    seed: u64,
    live: bool,
    workers: usize,
    batch: usize,
) -> Result<(ConfigSpace, Plan)> {
    offline_phase_full(tau, slo_ms, seed, live, workers, batch, ThresholdMode::Legacy, &[])
}

/// The fully-general offline phase: [`offline_phase_kb`] plus the
/// threshold derivation rule and an optional heterogeneous pool
/// topology (`pools` non-empty ⇒ per-pool thresholds via
/// [`derive_plan_pools`]; `workers` is then ignored in favor of the
/// pool worker counts).
#[allow(clippy::too_many_arguments)]
pub fn offline_phase_full(
    tau: f64,
    slo_ms: f64,
    seed: u64,
    live: bool,
    workers: usize,
    batch: usize,
    thresholds: ThresholdMode,
    pools: &[PoolSpec],
) -> Result<(ConfigSpace, Plan)> {
    let space = rag_space();
    let mut oracle = RagOracle::new_rag(seed);
    let result = CompassV::new(CompassVParams {
        seed,
        ..CompassVParams::default()
    })
    .run(&space, tau, &mut oracle);

    let candidates = plan_candidates(&space, &result.feasible);
    let mut wf = if live {
        Some(RagWorkflow::load(&artifacts_dir(), seed)?)
    } else {
        None
    };
    // Rung accuracy: the landscape value — the Planner re-evaluates the
    // feasible set on the full dataset before profiling (search estimates
    // carry Wilson-level noise that would scramble Pareto dominance).
    // Configurations whose re-evaluation falls clearly below τ (lucky
    // search noise) are dropped from the ladder.
    let landscape = RagLandscape;
    let profiled: Vec<ProfiledConfig> = candidates
        .iter()
        .filter(|(cfg, _)| landscape.true_accuracy(&space, cfg) >= tau - 0.005)
        .map(|(cfg, _est)| ProfiledConfig {
            label: space.display(cfg),
            accuracy: landscape.true_accuracy(&space, cfg),
            latency: latency_profile(&space, cfg, wf.as_mut(), 5),
            config: cfg.clone(),
        })
        .collect();
    let front = pareto_front(profiled);
    // Batch service-time model: fit α live over the fastest front
    // config (the rung batching matters most for); modeled testbeds use
    // the measured DISPATCH_MS constant. Inert at batch == 1.
    let alpha_ms = if batch > 1 {
        match (&mut wf, front.first()) {
            (Some(w), Some(c)) => {
                crate::planner::fit_batch_model(
                    w,
                    &space,
                    &c.config,
                    &crate::planner::BATCH_PROFILE_SIZES,
                    3,
                )
                .alpha_ms
            }
            _ => DISPATCH_MS,
        }
    } else {
        0.0
    };
    let workers_eff = if pools.is_empty() {
        workers
    } else {
        total_workers(pools)
    };
    let params = AqmParams::for_slo_workers(slo_ms, workers_eff)
        .with_batch(batch, alpha_ms)
        .with_thresholds(thresholds);
    let plan = if pools.is_empty() {
        derive_plan(&front, params)
    } else {
        derive_plan_pools(&front, params, pools)
    };
    Ok((space, plan))
}

/// [`offline_phase_full`] with the serving knobs of an experiment ctx.
pub fn offline_phase_ctx(
    ctx: &ExperimentCtx,
    tau: f64,
    slo_ms: f64,
    live: bool,
) -> Result<(ConfigSpace, Plan)> {
    offline_phase_full(
        tau,
        slo_ms,
        ctx.seed,
        live,
        ctx.workers.max(1),
        ctx.batch.max(1),
        ctx.thresholds,
        &ctx.pools,
    )
}

/// The three SLO targets, as multiples of the slowest rung's mean (the
/// paper's 500/1000/1500 ms at a ~450 ms slowest mean ≙ ~1.1x/2.2x/3.3x).
pub const SLO_FACTORS: [f64; 3] = [1.1, 2.2, 3.3];

/// Paper base load: utilization ≈ 0.45 of the most accurate rung of the
/// *full* front — fixed across SLO targets, like the paper's 1.5 QPS.
pub fn base_qps(full_plan: &Plan) -> f64 {
    0.45 / (full_plan.ladder.last().unwrap().mean_ms / 1000.0)
}

/// Paper base load scaled to a k-worker pool: ρ ≈ 0.45 of the most
/// accurate rung *across the pool*, so the per-worker operating point of
/// the paper's figures is preserved at every k.
pub fn base_qps_k(full_plan: &Plan, workers: usize) -> f64 {
    workers.max(1) as f64 * base_qps(full_plan)
}

/// Base load for a fleet: the homogeneous k-scaling, or — on a
/// heterogeneous topology — the pool capacity factor `Σ wₚ/speedₚ`, so
/// slower pools contribute proportionally less offered load and the
/// reference per-worker operating point is preserved. The single copy
/// of this fallback: the experiment ctx ([`ctx_base_qps`]) and the
/// `serve` CLI both resolve through it.
pub fn base_qps_pools(full_plan: &Plan, workers: usize, pools: &[PoolSpec]) -> f64 {
    if pools.is_empty() {
        base_qps_k(full_plan, workers)
    } else {
        capacity_factor(pools) * base_qps(full_plan)
    }
}

/// [`base_qps_pools`] with the fleet of an experiment ctx.
pub fn ctx_base_qps(ctx: &ExperimentCtx, full_plan: &Plan) -> f64 {
    base_qps_pools(full_plan, ctx.workers.max(1), &ctx.pools)
}

// ---------------------------------------------------------------------
// Serving cells
// ---------------------------------------------------------------------

/// Identifier of one serving run configuration.
#[derive(Clone, Debug)]
pub struct Cell {
    pub pattern_name: &'static str,
    pub pattern: Pattern,
    pub slo_ms: f64,
    pub policy_name: String,
    /// Base arrival rate (fixed across the SLO sweep).
    pub base_qps: f64,
}

/// Build the policy ladder for a cell.
pub fn make_policy(plan: &Plan, name: &str) -> Box<dyn ScalingPolicy> {
    match name {
        "Elastico" => Box::new(ElasticoPolicy::new(plan.clone())),
        "Static-Fast" => Box::new(StaticPolicy::new(0, "Static-Fast")),
        "Static-Medium" => {
            Box::new(StaticPolicy::new(plan.ladder.len() / 2, "Static-Medium"))
        }
        "Static-Accurate" => Box::new(StaticPolicy::new(
            plan.ladder.len() - 1,
            "Static-Accurate",
        )),
        other => panic!("unknown policy {other}"),
    }
}

/// The four policies of Fig. 5/6.
pub const POLICIES: [&str; 4] =
    ["Elastico", "Static-Fast", "Static-Medium", "Static-Accurate"];

/// Run one serving cell; returns (records, switches, summary).
///
/// `plan` is the ladder the *policy* runs over: the SLO-filtered plan for
/// Elastico, the full front for the static baselines (which, as in the
/// paper, keep their configuration regardless of the SLO under test).
pub fn run_cell(
    ctx: &ExperimentCtx,
    space: &ConfigSpace,
    plan: &Plan,
    cell: &Cell,
) -> Result<(Vec<RequestRecord>, Vec<SwitchEvent>, RunSummary)> {
    let spec = WorkloadSpec {
        base_qps: cell.base_qps,
        duration_s: ctx.duration_s,
        pattern: cell.pattern.clone(),
        seed: ctx.seed ^ 0x5EED,
    };
    let arrivals = generate_arrivals(&spec);
    let policy = make_policy(plan, &cell.policy_name);

    let (records, switches) = if ctx.live {
        let space2 = space.clone();
        let plan2 = plan.clone();
        let seed = ctx.seed;
        // On a heterogeneous topology every pool shares the one live
        // engine factory (real compute cannot be speed-scaled; the
        // PoolSpec's speed factor is advisory live) but each pool still
        // resolves its own band rung inside serve().
        let out = serve(
            move || {
                let configs: Vec<Config> =
                    plan2.ladder.iter().map(|p| p.config.clone()).collect();
                let wf = RagWorkflow::load_subset(
                    &artifacts_dir(),
                    &space2,
                    &configs,
                    seed,
                )?;
                Ok(WorkflowEngine::new(wf, space2.clone(), plan2.clone()))
            },
            policy,
            &arrivals,
            &ServeOptions {
                workers: ctx.workers.max(1),
                discipline: ctx.discipline,
                shards: ctx.shards,
                batch: ctx.batch.max(1),
                pools: ctx.pools.clone(),
                spill_margin: ctx.spill_margin,
                backend: ctx.backend,
                ..ServeOptions::default()
            },
        )?;
        (out.records, out.switches)
    } else {
        let svc = LognormalService::from_plan(plan, 0.10);
        let mut policy = policy;
        let out = simulate_ctx(ctx, &arrivals, plan, &mut policy, &svc)?;
        (out.records, out.switches)
    };
    let summary = RunSummary::compute(&records, &switches, cell.slo_ms, plan.ladder.len());
    Ok((records, switches, summary))
}

/// `simulate` over a boxed policy (object safety helper — the M/G/1
/// central-FIFO shape, used by tests and figure benches).
pub fn simulate_boxed(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut Box<dyn ScalingPolicy>,
    svc: &LognormalService,
    seed: u64,
) -> crate::sim::SimOutcome {
    let mut shim = Shim(policy);
    crate::sim::simulate(arrivals, plan, &mut shim, svc, seed)
}

/// Boxed-policy shim (object safety: `Box<dyn ScalingPolicy>` does not
/// itself implement the trait the generic engine wants).
struct Shim<'a>(&'a mut Box<dyn ScalingPolicy>);
impl ScalingPolicy for Shim<'_> {
    fn decide(&mut self, now_ms: f64, depth: usize) -> usize {
        self.0.decide(now_ms, depth)
    }
    fn current(&self) -> usize {
        self.0.current()
    }
    fn name(&self) -> String {
        self.0.name()
    }
    fn no_switch_band(&self) -> Option<(usize, usize)> {
        self.0.no_switch_band()
    }
    fn replace_plan(&mut self, plan: crate::planner::Plan) -> bool {
        // Without this forward the re-plan loop would silently no-op on
        // every boxed policy (the trait default declines).
        self.0.replace_plan(plan)
    }
}

/// Run the unified DES engine with the serving knobs of an experiment
/// ctx — the single simulation entry every experiment cell uses
/// (formerly the `simulate_boxed_k` / `simulate_boxed_disc` /
/// `simulate_boxed_pools` family, one copy per topology shape). The
/// ctx's [`ExperimentCtx::topology`] decides the fleet; workers,
/// discipline, shards, pools, spill margin and batch all flow from it.
pub fn simulate_ctx(
    ctx: &ExperimentCtx,
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut Box<dyn ScalingPolicy>,
    svc: &LognormalService,
) -> Result<crate::sim::SimOutcome> {
    simulate_ctx_faults(ctx, arrivals, plan, policy, svc, &crate::workload::FaultPlan::none())
}

/// [`simulate_ctx`] generalized: any [`ServiceModel`] (the scenario
/// sweep swaps in heavy-tailed Pareto service) and a
/// [`crate::workload::FaultPlan`] applied by the engine. The empty plan
/// reproduces [`simulate_ctx`] bit-for-bit.
pub fn simulate_ctx_faults<S: crate::sim::ServiceModel>(
    ctx: &ExperimentCtx,
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut Box<dyn ScalingPolicy>,
    svc: &S,
    faults: &crate::workload::FaultPlan,
) -> Result<crate::sim::SimOutcome> {
    simulate_ctx_resilient(
        ctx,
        arrivals,
        plan,
        policy,
        svc,
        faults,
        &crate::serving::ResilienceConfig::default(),
    )
}

/// [`simulate_ctx_faults`] with the resilience plane configured — the
/// chaos-cell entry point. The disabled config reproduces
/// [`simulate_ctx_faults`] bit-for-bit (which delegates here).
pub fn simulate_ctx_resilient<S: crate::sim::ServiceModel>(
    ctx: &ExperimentCtx,
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut Box<dyn ScalingPolicy>,
    svc: &S,
    faults: &crate::workload::FaultPlan,
    resilience: &crate::serving::ResilienceConfig,
) -> Result<crate::sim::SimOutcome> {
    simulate_ctx_overload(
        ctx,
        arrivals,
        plan,
        policy,
        svc,
        faults,
        resilience,
        &crate::serving::OverloadConfig::default(),
    )
}

/// [`simulate_ctx_resilient`] with the overload plane configured — the
/// overload-cell entry point, and the single ctx-driven path into
/// [`crate::sim::simulate_topology_overload`]. The disabled config
/// reproduces [`simulate_ctx_resilient`] bit-for-bit (which delegates
/// here).
#[allow(clippy::too_many_arguments)]
pub fn simulate_ctx_overload<S: crate::sim::ServiceModel>(
    ctx: &ExperimentCtx,
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut Box<dyn ScalingPolicy>,
    svc: &S,
    faults: &crate::workload::FaultPlan,
    resilience: &crate::serving::ResilienceConfig,
    overload: &crate::serving::OverloadConfig,
) -> Result<crate::sim::SimOutcome> {
    simulate_ctx_replan(
        ctx,
        arrivals,
        plan,
        policy,
        svc,
        faults,
        resilience,
        overload,
        &crate::serving::ReplanConfig::default(),
    )
}

/// [`simulate_ctx_overload`] with the online re-plan loop configured —
/// the drift-cell entry point, and the single ctx-driven path into
/// [`crate::sim::simulate_topology_replan`]. The disabled config
/// reproduces [`simulate_ctx_overload`] bit-for-bit (which delegates
/// here).
#[allow(clippy::too_many_arguments)]
pub fn simulate_ctx_replan<S: crate::sim::ServiceModel>(
    ctx: &ExperimentCtx,
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut Box<dyn ScalingPolicy>,
    svc: &S,
    faults: &crate::workload::FaultPlan,
    resilience: &crate::serving::ResilienceConfig,
    overload: &crate::serving::OverloadConfig,
    replan: &crate::serving::ReplanConfig,
) -> Result<crate::sim::SimOutcome> {
    let topo = ctx.topology()?;
    let mut shim = Shim(policy);
    Ok(crate::sim::simulate_topology_replan(
        arrivals,
        plan,
        &mut shim,
        svc,
        ctx.seed,
        &topo,
        ctx.batch.max(1),
        faults,
        resilience,
        overload,
        replan,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_latency_monotone_in_generator() {
        let space = rag_space();
        let mut prev = 0.0;
        for g in 0..6 {
            let cfg = vec![g, 1, 1, 0];
            let m = modeled_latency_ms(&space, &cfg);
            assert!(m > prev);
            prev = m;
        }
    }

    #[test]
    fn offline_phase_modeled_builds_plan() {
        let (_space, plan) = offline_phase(0.75, 1000.0, 3, false).unwrap();
        assert!(plan.ladder.len() >= 3, "ladder {:?}", plan.ladder.len());
        // Ladder ordered and thresholds non-increasing (Eq. 11; ties
        // happen when adjacent rungs have near-identical service times).
        for w in plan.ladder.windows(2) {
            assert!(w[0].mean_ms < w[1].mean_ms);
            assert!(w[0].accuracy < w[1].accuracy);
            assert!(w[0].upscale_threshold >= w[1].upscale_threshold);
        }
        // Everything on the τ=0.75 front clears the threshold up to the
        // evaluation noise of the final re-estimate.
        for p in &plan.ladder {
            assert!(p.accuracy >= 0.75 - 0.02, "rung acc {}", p.accuracy);
        }
    }

    #[test]
    fn base_qps_targets_utilization() {
        let (_s, plan) = offline_phase(0.75, 1000.0, 3, false).unwrap();
        let qps = base_qps(&plan);
        let rho = qps * plan.ladder.last().unwrap().mean_ms / 1000.0;
        assert!((rho - 0.45).abs() < 1e-9);
        // Pool load keeps the per-worker operating point.
        let rho4 = base_qps_k(&plan, 4) * plan.ladder.last().unwrap().mean_ms
            / 1000.0
            / 4.0;
        assert!((rho4 - 0.45).abs() < 1e-9);
    }

    #[test]
    fn offline_phase_kb_batch_one_is_offline_phase_k() {
        // batch = 1 must leave the plan identical (thresholds, ladder,
        // serialized form) to the unbatched derivation.
        let (_s1, p1) = offline_phase_k(0.75, 1000.0, 3, false, 2).unwrap();
        let (_s2, pb) = offline_phase_kb(0.75, 1000.0, 3, false, 2, 1).unwrap();
        assert_eq!(p1, pb);
        assert_eq!(pb.batch, 1);
        assert_eq!(pb.batch_alpha_ms, 0.0);
    }

    #[test]
    fn offline_phase_kb_carries_the_batch_model() {
        let (_s, pb) = offline_phase_kb(0.75, 1000.0, 3, false, 1, 8).unwrap();
        assert_eq!(pb.batch, 8);
        assert_eq!(pb.batch_alpha_ms, DISPATCH_MS);
        assert!(!pb.ladder.is_empty());
        // Eq. 11 must hold under the batch model too.
        for w in pb.ladder.windows(2) {
            assert!(w[0].upscale_threshold >= w[1].upscale_threshold);
        }
    }

    #[test]
    fn offline_phase_full_defaults_reproduce_offline_phase_kb() {
        // Legacy thresholds + no pools must be byte-equal to the
        // pre-pool offline phase (the `--thresholds legacy` default
        // keeps every existing figure baseline unchanged).
        let (_s1, a) = offline_phase_kb(0.75, 1000.0, 3, false, 2, 4).unwrap();
        let (_s2, b) = offline_phase_full(
            0.75, 1000.0, 3, false, 2, 4, ThresholdMode::Legacy, &[],
        )
        .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn offline_phase_full_pooled_carries_the_topology() {
        let pools =
            crate::serving::pool::parse_pools("fast:4:1.0,accurate:2:2.5").unwrap();
        let (_s, plan) = offline_phase_full(
            0.75, 1500.0, 3, false, 1, 1, ThresholdMode::ErlangC, &pools,
        )
        .unwrap();
        assert_eq!(plan.pools, pools);
        assert_eq!(plan.workers, 6);
        assert!(!plan.ladder.is_empty());
        // Eq. 11 must hold across pool band boundaries too.
        for w in plan.ladder.windows(2) {
            assert!(w[0].upscale_threshold >= w[1].upscale_threshold);
        }
    }

    #[test]
    fn ctx_topology_resolves_the_dispatch_shapes() {
        // The ctx-driven sim entry must execute the same shapes the
        // live ServeOptions resolve: central = 1 shard / k workers,
        // sharded = k shards, pools = per-worker shards + the margin.
        let central = ExperimentCtx { workers: 4, ..ExperimentCtx::default() };
        let t = central.topology().unwrap();
        assert_eq!((t.n_pools(), t.n_shards(), t.n_workers()), (1, 1, 4));
        let sharded = ExperimentCtx {
            workers: 4,
            discipline: Discipline::ShardedSteal,
            ..ExperimentCtx::default()
        };
        let t = sharded.topology().unwrap();
        assert_eq!((t.n_shards(), t.n_workers()), (4, 4));
        let pooled = ExperimentCtx {
            pools: crate::serving::pool::parse_pools("fast:3:1.0,acc:2:2.0").unwrap(),
            spill_margin: 1.5,
            ..ExperimentCtx::default()
        };
        let t = pooled.topology().unwrap();
        assert_eq!((t.n_pools(), t.n_shards(), t.n_workers()), (2, 5, 5));
        assert_eq!(t.spill_margin(), 1.5);
    }

    #[test]
    fn ctx_base_qps_uses_the_pool_capacity_factor() {
        let (_s, plan) = offline_phase(0.75, 1000.0, 3, false).unwrap();
        let mut ctx = ExperimentCtx { workers: 4, ..ExperimentCtx::default() };
        assert!((ctx_base_qps(&ctx, &plan) - base_qps_k(&plan, 4)).abs() < 1e-12);
        // fast:2@1x + slow:2@2x = 3 reference-workers of capacity.
        ctx.pools = crate::serving::pool::parse_pools("fast:2:1.0,slow:2:2.0").unwrap();
        assert!((ctx_base_qps(&ctx, &plan) - 3.0 * base_qps(&plan)).abs() < 1e-9);
        assert_eq!(ctx.total_workers(), 4);
    }

    #[test]
    fn offline_phase_k_scales_thresholds_only() {
        // Same ladder, k-scaled queue thresholds: the Pareto front and
        // accuracy/latency profile must not depend on the pool size.
        let (_s1, p1) = offline_phase(0.75, 1000.0, 3, false).unwrap();
        let (_s4, p4) = offline_phase_k(0.75, 1000.0, 3, false, 4).unwrap();
        assert_eq!(p1.workers, 1);
        assert_eq!(p4.workers, 4);
        assert_eq!(p1.ladder.len(), p4.ladder.len());
        for (a, b) in p1.ladder.iter().zip(&p4.ladder) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.mean_ms, b.mean_ms);
            assert!(b.upscale_threshold >= 4 * a.upscale_threshold);
        }
    }
}
