//! Fig. 3 — COMPASS-V anytime convergence across eight accuracy SLOs
//! (RAG workflow): feasible configurations discovered vs samples used,
//! against the grid-search best/worst envelope.

use anyhow::Result;

use super::common::ExperimentCtx;
use crate::configspace::rag_space;
use crate::oracle::RagOracle;
use crate::search::trace::grid_envelope;
use crate::search::{grid_search, CompassV, CompassVParams};
use crate::util::csv::CsvWriter;

/// The paper's eight RAG thresholds.
pub const RAG_TAUS: [f64; 8] = [0.30, 0.40, 0.50, 0.60, 0.70, 0.75, 0.80, 0.85];

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    let space = rag_space();
    let n = space.enumerate_valid().len();
    let b_max = CompassVParams::default().schedule.b_max();

    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("fig3_convergence.csv"),
        &["tau", "series", "samples", "found"],
    )?;

    println!(
        "Fig.3: COMPASS-V convergence on RAG ({n} configs, B_max={b_max})"
    );
    println!(
        "{:>5} {:>9} {:>9} {:>10} {:>10} {:>7}",
        "tau", "feasible", "frac%", "samples", "exhaustive", "recall%"
    );

    for tau in RAG_TAUS {
        // Ground truth: exhaustive grid at full budget, identical draws.
        let mut gt_oracle = RagOracle::new_rag(ctx.seed);
        let grid = grid_search(&space, b_max, &mut gt_oracle);
        let gt: std::collections::HashSet<usize> = grid
            .feasible(tau)
            .iter()
            .map(|(c, _)| space.flat_id(c))
            .collect();

        let mut oracle = RagOracle::new_rag(ctx.seed);
        let result = CompassV::new(CompassVParams { seed: ctx.seed, ..Default::default() })
            .run(&space, tau, &mut oracle);
        let found: std::collections::HashSet<usize> = result
            .feasible
            .iter()
            .map(|(c, _)| space.flat_id(c))
            .collect();
        let recall = if gt.is_empty() {
            1.0
        } else {
            gt.intersection(&found).count() as f64 / gt.len() as f64
        };

        for p in &result.trace {
            csv.row(&[
                format!("{tau}"),
                "compassv".into(),
                p.samples.to_string(),
                p.found.to_string(),
            ])?;
        }
        let (best, worst) = grid_envelope(n, gt.len(), b_max);
        for (series, tr) in [("grid_best", best), ("grid_worst", worst)] {
            for p in tr {
                csv.row(&[
                    format!("{tau}"),
                    series.into(),
                    p.samples.to_string(),
                    p.found.to_string(),
                ])?;
            }
        }

        println!(
            "{:>5.2} {:>9} {:>8.1}% {:>10} {:>10} {:>6.1}%",
            tau,
            gt.len(),
            100.0 * gt.len() as f64 / n as f64,
            result.samples_used,
            n as u64 * b_max as u64,
            recall * 100.0
        );
    }
    csv.flush()?;
    println!("-> results/fig3_convergence.csv");
    Ok(())
}
