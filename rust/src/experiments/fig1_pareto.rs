//! Fig. 1 — the RAG accuracy/latency Pareto front.
//!
//! Profiles the paper's 72-configuration subset (6 generators x 3
//! retriever-k x 2 rerank-k x 2 rerankers), marks the Pareto-optimal
//! points, and reports the paper's headline observation: the latency
//! reduction and accuracy drop when stepping from the most accurate
//! configuration to an efficient frontier alternative.

use anyhow::Result;

use super::common::{latency_profile, ExperimentCtx};
use crate::configspace::rag_space;
use crate::oracle::rag::RagLandscape;
use crate::oracle::Landscape;
use crate::planner::{pareto_front, ProfiledConfig};
use crate::runtime::artifacts_dir;
use crate::util::csv::CsvWriter;
use crate::workflows::rag::RagWorkflow;

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    let space = rag_space();
    let landscape = RagLandscape;

    // The 72-config subset: every generator/reranker, coarse k grid.
    let gens = 0..6usize;
    let ks = [0usize, 2, 4]; // k = 3, 10, 50
    let rks = [0usize, 1]; // rk = 1, 3
    let rrs = [0usize, 2]; // rr-48, rr-160
    let mut subset = Vec::new();
    for g in gens {
        for &k in &ks {
            for &rk in &rks {
                for &rr in &rrs {
                    let cfg = vec![g, k, rk, rr];
                    if space.valid(&cfg) {
                        subset.push(cfg);
                    }
                }
            }
        }
    }
    println!("Fig.1: profiling {} configurations ({})", subset.len(),
        if ctx.live { "live PJRT" } else { "modeled; pass --live to re-measure" });

    let mut wf = if ctx.live {
        Some(RagWorkflow::load(&artifacts_dir(), ctx.seed)?)
    } else {
        None
    };
    let profiled: Vec<ProfiledConfig> = subset
        .iter()
        .map(|cfg| ProfiledConfig {
            label: space.display(cfg),
            accuracy: landscape.true_accuracy(&space, cfg),
            latency: latency_profile(&space, cfg, wf.as_mut(), 3),
            config: cfg.clone(),
        })
        .collect();

    let front = pareto_front(profiled.clone());
    let front_ids: std::collections::HashSet<usize> =
        front.iter().map(|c| space.flat_id(&c.config)).collect();

    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("fig1_pareto.csv"),
        &["config", "accuracy", "mean_ms", "p95_ms", "on_front"],
    )?;
    for p in &profiled {
        csv.row(&[
            p.label.clone(),
            format!("{:.4}", p.accuracy),
            format!("{:.2}", p.latency.mean_ms),
            format!("{:.2}", p.latency.p95_ms),
            front_ids.contains(&space.flat_id(&p.config)).to_string(),
        ])?;
    }
    csv.flush()?;

    println!("Pareto front ({} of {} configs):", front.len(), profiled.len());
    for p in &front {
        println!(
            "  {:<36} acc {:.3}  p95 {:>8.1} ms",
            p.label, p.accuracy, p.latency.p95_ms
        );
    }

    // Paper: "switching from the highest quality configuration to an
    // efficient alternative yields a 1.6x reduction in P95 latency with
    // only a 2% drop in F1 score."
    if front.len() >= 2 {
        let best = front.last().unwrap();
        // The efficient alternative: cheapest rung within 2.5% accuracy.
        let alt = front
            .iter()
            .find(|p| p.accuracy >= best.accuracy - 0.025)
            .unwrap();
        println!(
            "Headline: {:.2}x P95 reduction for {:.1}% accuracy drop \
             (paper: 1.6x for 2%)",
            best.latency.p95_ms / alt.latency.p95_ms,
            (best.accuracy - alt.accuracy) * 100.0
        );
    }
    println!("-> results/fig1_pareto.csv");
    Ok(())
}
