//! Fig. 7 — temporal adaptation behavior: per-request latency colored by
//! active configuration + switch events over the spike run (Elastico,
//! middle SLO target).

use anyhow::Result;

use super::common::{
    ctx_base_qps, offline_phase_ctx, run_cell, Cell, ExperimentCtx, SLO_FACTORS,
};
use crate::metrics::report::{write_records_csv, write_switches_csv};
use crate::workload::Pattern;

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    let k = ctx.total_workers();
    let b = ctx.batch.max(1);
    let (_s, full) = offline_phase_ctx(ctx, 0.75, 1e9, ctx.live)?;
    let slo = SLO_FACTORS[1] * full.ladder.last().unwrap().mean_ms;
    let (space, plan) = offline_phase_ctx(ctx, 0.75, slo, false)?;

    let cell = Cell {
        pattern_name: "spike",
        pattern: Pattern::paper_spike(),
        slo_ms: slo,
        policy_name: "Elastico".into(),
        base_qps: ctx_base_qps(ctx, &full),
    };
    let (records, switches, summary) = run_cell(ctx, &space, &plan, &cell)?;

    write_records_csv(&ctx.out_dir.join("fig7_requests.csv"), &records)?;
    write_switches_csv(&ctx.out_dir.join("fig7_switches.csv"), &switches)?;

    let dur_ms = ctx.duration_s * 1000.0;
    let spike = (dur_ms / 3.0, 2.0 * dur_ms / 3.0);
    println!(
        "Fig.7: Elastico timeline, spike during [{:.0}s, {:.0}s], SLO {slo:.0} ms, \
         {k} worker(s), {}, batch {b}",
        spike.0 / 1000.0,
        spike.1 / 1000.0,
        ctx.dispatch_desc()
    );
    println!("  switches ({} total):", switches.len());
    for s in switches.iter().take(20) {
        println!(
            "    t={:>7.1}s  {} -> {}  ({})",
            s.at_ms / 1000.0,
            plan.ladder[s.from_idx].label,
            plan.ladder[s.to_idx].label,
            if s.to_idx < s.from_idx { "faster" } else { "more accurate" }
        );
    }
    if switches.len() > 20 {
        println!("    … ({} more)", switches.len() - 20);
    }

    // Phase-resolved usage: the paper's key observations.
    let phase = |lo: f64, hi: f64| {
        let rs: Vec<_> = records
            .iter()
            .filter(|r| r.arrival_ms >= lo && r.arrival_ms < hi)
            .collect();
        let n = rs.len().max(1) as f64;
        let fast_frac = rs
            .iter()
            .filter(|r| r.config_idx == 0)
            .count() as f64
            / n;
        let acc_frac = rs
            .iter()
            .filter(|r| r.config_idx == plan.ladder.len() - 1)
            .count() as f64
            / n;
        (fast_frac, acc_frac)
    };
    let (f_pre, a_pre) = phase(0.0, spike.0);
    let (f_in, a_in) = phase(spike.0, spike.1);
    let (f_post, a_post) = phase(spike.1, dur_ms);
    println!("  usage  pre-spike: fast {:.0}% / accurate {:.0}%", f_pre * 100.0, a_pre * 100.0);
    println!("  usage  in-spike : fast {:.0}% / accurate {:.0}%", f_in * 100.0, a_in * 100.0);
    println!("  usage post-spike: fast {:.0}% / accurate {:.0}%", f_post * 100.0, a_post * 100.0);
    println!(
        "  run: {} requests, compliance {:.1}%, mean accuracy {:.3}",
        summary.requests,
        summary.slo_compliance * 100.0,
        summary.mean_accuracy
    );
    println!("-> results/fig7_requests.csv, results/fig7_switches.csv");
    Ok(())
}
