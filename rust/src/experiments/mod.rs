//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§VI). One module per artifact; `compass experiment <id>`
//! dispatches here. Console output mirrors the paper's rows/series; raw
//! data lands as CSV under `results/` (DESIGN.md §4 experiment index).

pub mod ablation;
pub mod common;
pub mod fig1_pareto;
pub mod fig3_convergence;
pub mod fig4_efficiency;
pub mod fig5_tradeoff;
pub mod fig6_cdf;
pub mod fig7_timeline;
pub mod scenarios;
pub mod table1_baselines;

pub use common::ExperimentCtx;

/// All experiment ids, in paper order.
pub const ALL: [&str; 7] =
    ["fig1", "fig3", "fig4", "table1", "fig5", "fig6", "fig7"];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &ExperimentCtx) -> anyhow::Result<()> {
    match id {
        "fig1" => fig1_pareto::run(ctx),
        "fig3" => fig3_convergence::run(ctx),
        "fig4" => fig4_efficiency::run(ctx),
        "table1" => table1_baselines::run(ctx).map(|_| ()),
        "fig5" => fig5_tradeoff::run(ctx),
        "fig6" => fig6_cdf::run(ctx),
        "fig7" => fig7_timeline::run(ctx),
        "ablation" => ablation::run(ctx),
        "scenarios" => scenarios::run(ctx),
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other}; try: {:?}, ablation, scenarios, or all",
            ALL
        ),
    }
}
