//! Fig. 5 — SLO compliance and accuracy across {spike, bursty} x three
//! SLO targets x four policies, plus the paper's headline aggregates
//! (+71.6% compliance vs Static-Accurate, +3-5 accuracy points vs
//! Static-Fast, 90-98% compliance overall).

use anyhow::Result;

use super::common::{
    ctx_base_qps, offline_phase_ctx, run_cell, Cell, ExperimentCtx, POLICIES,
    SLO_FACTORS,
};
use crate::util::csv::CsvWriter;
use crate::workload::Pattern;

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    // Offline phase once: the full front drives the static baselines and
    // the (SLO-independent) base load; per-SLO plans re-derive thresholds
    // for Elastico. Both carry the cell's fleet topology so the
    // thresholds and load match the pool(s) run_cell drives.
    let b = ctx.batch.max(1);
    let (_s, full) = offline_phase_ctx(ctx, 0.75, 1e9, ctx.live)?;
    let slowest_mean = full.ladder.last().unwrap().mean_ms;
    let qps = ctx_base_qps(ctx, &full);

    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("fig5_tradeoff.csv"),
        &[
            "pattern", "slo_ms", "policy", "slo_compliance_pct",
            "mean_accuracy", "p95_ms", "switches", "requests",
        ],
    )?;

    println!(
        "Fig.5: serving cells ({}; {}s per cell, base utilization 0.45, \
         {}, batch {b})",
        if ctx.live { "LIVE serving" } else { "discrete-event sim of live profiles" },
        ctx.duration_s,
        ctx.dispatch_desc()
    );

    // Aggregates for the headline claims.
    let mut ela_minus_acc: Vec<f64> = Vec::new(); // compliance gain
    let mut ela_acc_gain: Vec<f64> = Vec::new(); // accuracy vs fast
    let mut ela_compliance: Vec<f64> = Vec::new();

    for (pattern_name, pattern) in [
        ("spike", Pattern::paper_spike()),
        ("bursty", Pattern::paper_bursty()),
    ] {
        for factor in SLO_FACTORS {
            let slo = factor * slowest_mean;
            let (space, plan) = offline_phase_ctx(ctx, 0.75, slo, false)?;
            println!(
                "\n-- pattern={pattern_name} SLO={slo:.0}ms (Elastico ladder {} rungs) --",
                plan.ladder.len()
            );
            let mut cells: std::collections::BTreeMap<String, _> =
                Default::default();
            for policy in POLICIES {
                let cell = Cell {
                    pattern_name,
                    pattern: pattern.clone(),
                    slo_ms: slo,
                    policy_name: policy.into(),
                    base_qps: qps,
                };
                // Statics keep their full-front configuration regardless
                // of the SLO (paper Table I baselines).
                let policy_plan = if policy == "Elastico" { &plan } else { &full };
                let (_r, _s2, summary) = run_cell(ctx, &space, policy_plan, &cell)?;
                println!(
                    "  {}",
                    crate::metrics::report::summary_row(policy, &summary)
                );
                csv.row(&[
                    pattern_name.into(),
                    format!("{slo:.0}"),
                    policy.into(),
                    format!("{:.2}", summary.slo_compliance * 100.0),
                    format!("{:.4}", summary.mean_accuracy),
                    format!("{:.1}", summary.latency.p95),
                    summary.switches.to_string(),
                    summary.requests.to_string(),
                ])?;
                cells.insert(policy.to_string(), summary);
            }
            let ela = &cells["Elastico"];
            let fast = &cells["Static-Fast"];
            let acc = &cells["Static-Accurate"];
            ela_minus_acc
                .push((ela.slo_compliance - acc.slo_compliance) * 100.0);
            ela_acc_gain
                .push((ela.mean_accuracy - fast.mean_accuracy) * 100.0);
            ela_compliance.push(ela.slo_compliance * 100.0);
        }
    }
    csv.flush()?;

    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let min = |v: &[f64]| v.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = |v: &[f64]| v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!("\nHeadline:");
    println!(
        "  Elastico SLO compliance: {:.0}-{:.0}%        (paper: 90-98%)",
        min(&ela_compliance),
        max(&ela_compliance)
    );
    println!(
        "  compliance gain vs Static-Accurate: avg {:+.1} pts, max {:+.1} pts (paper: +71.6)",
        avg(&ela_minus_acc),
        max(&ela_minus_acc)
    );
    println!(
        "  accuracy gain vs Static-Fast: {:+.1}..{:+.1} pts (paper: +3-5)",
        min(&ela_acc_gain),
        max(&ela_acc_gain)
    );
    println!("-> results/fig5_tradeoff.csv");
    Ok(())
}
