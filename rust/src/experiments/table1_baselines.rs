//! Table I — baseline configurations on the generated Pareto front
//! (τ = 0.75): the Fast / Medium / Accurate rungs with their accuracy and
//! P95 latency, plus the full derived switching plan.

use anyhow::Result;

use super::common::{offline_phase_ctx, ExperimentCtx, SLO_FACTORS};
use crate::planner::Plan;
use crate::util::csv::CsvWriter;

pub fn run(ctx: &ExperimentCtx) -> Result<Plan> {
    // SLO used for threshold display: the middle target (≙ paper 1000ms).
    // The ctx-aware offline phase keeps the rendered thresholds
    // consistent with the batch/threshold-mode/pool flags of the run.
    let (_space, probe) = offline_phase_ctx(ctx, 0.75, 1e9, ctx.live)?;
    let slowest = probe.ladder.last().unwrap().mean_ms;
    let slo = SLO_FACTORS[1] * slowest;
    let (_space, plan) = offline_phase_ctx(ctx, 0.75, slo, ctx.live)?;

    println!(
        "Table I: Pareto front at tau=0.75 ({}; SLO for thresholds: {:.0} ms)",
        if ctx.live { "live profiling" } else { "modeled latencies" },
        slo
    );
    print!("{}", plan.render());

    let named = [
        ("Fast", 0usize),
        ("Medium", plan.ladder.len() / 2),
        ("Accurate", plan.ladder.len() - 1),
    ];
    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("table1_baselines.csv"),
        &["name", "config", "accuracy_f1", "p95_ms"],
    )?;
    println!("\nBaselines (paper Table I shape):");
    for (name, idx) in named {
        let p = &plan.ladder[idx];
        println!(
            "  {:<9} {:<38} F1 {:.3}  P95 ~{:.0} ms",
            name, p.label, p.accuracy, p.p95_ms
        );
        csv.row(&[
            name.into(),
            p.label.clone(),
            format!("{:.4}", p.accuracy),
            format!("{:.1}", p.p95_ms),
        ])?;
    }
    csv.flush()?;
    println!("-> results/table1_baselines.csv");
    Ok(plan)
}
