//! Ablations of the design choices DESIGN.md calls out (not a paper
//! figure; supports the analysis sections):
//!
//! * **search navigation** — COMPASS-V vs random-order search with the
//!   same progressive budgeting (isolates gradient guidance + lateral
//!   expansion from Wilson early stopping);
//! * **progressive budgeting** — COMPASS-V with the full B_max per
//!   configuration (isolates early stopping);
//! * **hysteresis** — Elastico with/without the asymmetric cooldown, and
//!   the predictive extension (§VIII), on the spike workload;
//! * **LHS seeding** — recall sensitivity to `n_init`.

use anyhow::Result;

use super::common::{
    ctx_base_qps, make_policy, offline_phase_ctx, simulate_ctx, ExperimentCtx,
};
use crate::configspace::rag_space;
use crate::metrics::RunSummary;
use crate::oracle::RagOracle;
use crate::search::{
    random_search, BudgetSchedule, CompassV, CompassVParams,
};
use crate::serving::{PredictivePolicy, ScalingPolicy};
use crate::sim::LognormalService;
use crate::util::csv::CsvWriter;
use crate::workload::{generate_arrivals, Pattern, WorkloadSpec};

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    search_ablation(ctx)?;
    seeding_ablation(ctx)?;
    controller_ablation(ctx)?;
    Ok(())
}

fn search_ablation(ctx: &ExperimentCtx) -> Result<()> {
    let space = rag_space();
    let n = space.enumerate_valid().len();
    let b_max = BudgetSchedule::rag().b_max();
    let tau = 0.80;

    let mut oracle = RagOracle::new_rag(ctx.seed);
    let full = CompassV::new(CompassVParams { seed: ctx.seed, ..Default::default() })
        .run(&space, tau, &mut oracle);

    // No early stopping: single-level schedule at B_max.
    let mut oracle = RagOracle::new_rag(ctx.seed);
    let no_early = CompassV::new(CompassVParams {
        seed: ctx.seed,
        schedule: BudgetSchedule::new(vec![b_max]),
        ..Default::default()
    })
    .run(&space, tau, &mut oracle);

    // No navigation: random order, same budgeting.
    let mut oracle = RagOracle::new_rag(ctx.seed);
    let random = random_search(
        &space,
        tau,
        &BudgetSchedule::rag(),
        1.96,
        ctx.seed,
        None,
        &mut oracle,
    );

    println!("Ablation A — search components (tau={tau}, |C|={n}):");
    println!("  {:<34} {:>9} {:>9} {:>9}", "variant", "found", "samples", "savings%");
    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("ablation_search.csv"),
        &["variant", "found", "samples", "savings_pct"],
    )?;
    for (name, r) in [
        ("COMPASS-V (full)", &full),
        ("no early stopping", &no_early),
        ("no navigation (random order)", &random),
    ] {
        let savings = r.savings_vs_exhaustive(n, b_max) * 100.0;
        println!(
            "  {:<34} {:>9} {:>9} {:>8.1}%",
            name,
            r.feasible.len(),
            r.samples_used,
            savings
        );
        csv.row(&[
            name.into(),
            r.feasible.len().to_string(),
            r.samples_used.to_string(),
            format!("{savings:.1}"),
        ])?;
    }
    csv.flush()?;
    Ok(())
}

fn seeding_ablation(ctx: &ExperimentCtx) -> Result<()> {
    let space = rag_space();
    let tau = 0.85; // tight: seeding matters most here
    println!("\nAblation B — LHS seeding (tau={tau}):");
    for n_init in [4usize, 8, 16, 32] {
        let mut oracle = RagOracle::new_rag(ctx.seed);
        let r = CompassV::new(CompassVParams {
            seed: ctx.seed,
            n_init,
            ..Default::default()
        })
        .run(&space, tau, &mut oracle);
        println!(
            "  n_init={n_init:<3} found {:>3} with {:>6} samples",
            r.feasible.len(),
            r.samples_used
        );
    }
    Ok(())
}

fn controller_ablation(ctx: &ExperimentCtx) -> Result<()> {
    // The same offline phase as fig5/6/7: the derived plan carries the
    // ctx's batch model, threshold mode and pool topology, so the
    // ablation cells stay comparable to the figure cells of one run.
    let k = ctx.total_workers();
    let (_s, full) = offline_phase_ctx(ctx, 0.75, 1e9, false)?;
    let slo = 2.2 * full.ladder.last().unwrap().mean_ms;
    let (_s2, plan) = offline_phase_ctx(ctx, 0.75, slo, false)?;
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: ctx_base_qps(ctx, &full),
        duration_s: ctx.duration_s,
        pattern: Pattern::paper_spike(),
        seed: ctx.seed,
    });
    let svc = LognormalService::from_plan(&plan, 0.10);

    println!(
        "\nAblation C — controller variants (spike, SLO {slo:.0} ms, {k} worker(s), {}):",
        ctx.dispatch_desc()
    );
    let mut variants: Vec<(&str, Box<dyn ScalingPolicy>)> = vec![
        ("Elastico (asymmetric hysteresis)", make_policy(&plan, "Elastico")),
        ("Predictive extension (§VIII)", Box::new(PredictivePolicy::new(plan.clone()))),
        ("no hysteresis (t↓ = 0)", {
            let mut p = plan.clone();
            p.down_cooldown_ms = 0.0;
            Box::new(crate::serving::ElasticoPolicy::new(p))
        }),
    ];
    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("ablation_controller.csv"),
        &["variant", "slo_compliance_pct", "mean_accuracy", "switches"],
    )?;
    for (name, policy) in variants.iter_mut() {
        let mut boxed: Box<dyn ScalingPolicy> = std::mem::replace(
            policy,
            Box::new(crate::serving::StaticPolicy::new(0, "placeholder")),
        );
        let out = simulate_ctx(ctx, &arrivals, &plan, &mut boxed, &svc)?;
        let s = RunSummary::compute(&out.records, &out.switches, slo, plan.ladder.len());
        println!(
            "  {:<36} SLO {:>5.1}%  acc {:.3}  switches {:>4}",
            name,
            s.slo_compliance * 100.0,
            s.mean_accuracy,
            s.switches
        );
        csv.row(&[
            (*name).into(),
            format!("{:.1}", s.slo_compliance * 100.0),
            format!("{:.4}", s.mean_accuracy),
            s.switches.to_string(),
        ])?;
    }
    csv.flush()?;
    println!("-> results/ablation_search.csv, results/ablation_controller.csv");
    Ok(())
}
