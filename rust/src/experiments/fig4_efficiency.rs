//! Fig. 4 — COMPASS-V sample-efficiency across the SLO spectrum, both
//! workflows: % evaluation savings vs the feasible fraction, plus the
//! paper's headline aggregates (100% recall, 57.5% average savings,
//! 95.3% max at tight thresholds).

use anyhow::Result;

use super::common::ExperimentCtx;
use super::fig3_convergence::RAG_TAUS;
use crate::configspace::{detection_space, rag_space, ConfigSpace};
use crate::oracle::{DetectionOracle, LandscapeEvaluator, Landscape, RagOracle};
use crate::search::{grid_search, BudgetSchedule, CompassV, CompassVParams};
use crate::util::csv::CsvWriter;

/// The paper's eight detection thresholds.
pub const DET_TAUS: [f64; 8] = [0.55, 0.59, 0.62, 0.66, 0.70, 0.73, 0.76, 0.80];

struct Row {
    workflow: &'static str,
    tau: f64,
    feasible_frac: f64,
    savings: f64,
    recall: f64,
    /// Recall over the noise-free ground truth (GT-feasible configs whose
    /// *latent* accuracy also clears τ) — excludes sampling-noise islands
    /// that only exhaustive search can stumble on.
    recall_clean: f64,
}

fn sweep<L: Landscape, F: Fn(u64) -> LandscapeEvaluator<L>>(
    workflow: &'static str,
    space: &ConfigSpace,
    taus: &[f64],
    schedule: BudgetSchedule,
    make_oracle: F,
    seed: u64,
) -> Vec<Row> {
    let n = space.enumerate_valid().len();
    let b_max = schedule.b_max();
    taus.iter()
        .map(|&tau| {
            let mut gt_oracle = make_oracle(seed);
            let grid = grid_search(space, b_max, &mut gt_oracle);
            let gt: std::collections::HashSet<usize> = grid
                .feasible(tau)
                .iter()
                .map(|(c, _)| space.flat_id(c))
                .collect();
            // Noise-free subset: latent accuracy also clears τ.
            let gt_clean: std::collections::HashSet<usize> = grid
                .feasible(tau)
                .iter()
                .filter(|(c, _)| gt_oracle.true_accuracy(space, c) >= tau)
                .map(|(c, _)| space.flat_id(c))
                .collect();

            let mut oracle = make_oracle(seed);
            let result = CompassV::new(CompassVParams {
                seed,
                schedule: schedule.clone(),
                ..Default::default()
            })
            .run(space, tau, &mut oracle);
            let found: std::collections::HashSet<usize> = result
                .feasible
                .iter()
                .map(|(c, _)| space.flat_id(c))
                .collect();
            Row {
                workflow,
                tau,
                feasible_frac: gt.len() as f64 / n as f64,
                savings: result.savings_vs_exhaustive(n, b_max),
                recall: if gt.is_empty() {
                    1.0
                } else {
                    gt.intersection(&found).count() as f64 / gt.len() as f64
                },
                recall_clean: if gt_clean.is_empty() {
                    1.0
                } else {
                    gt_clean.intersection(&found).count() as f64
                        / gt_clean.len() as f64
                },
            }
        })
        .collect()
}

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    let rag = sweep(
        "rag",
        &rag_space(),
        &RAG_TAUS,
        BudgetSchedule::rag(),
        RagOracle::new_rag,
        ctx.seed,
    );
    let det = sweep(
        "detection",
        &detection_space(),
        &DET_TAUS,
        BudgetSchedule::detection(),
        DetectionOracle::new_detection,
        ctx.seed,
    );

    let mut csv = CsvWriter::create(
        &ctx.out_dir.join("fig4_efficiency.csv"),
        &[
            "workflow", "tau", "feasible_frac", "savings_pct", "recall_pct",
            "recall_clean_pct",
        ],
    )?;
    println!(
        "{:<10} {:>5} {:>10} {:>10} {:>8} {:>8}",
        "workflow", "tau", "feasible%", "savings%", "recall%", "clean%"
    );
    let all: Vec<&Row> = rag.iter().chain(det.iter()).collect();
    for r in &all {
        csv.row(&[
            r.workflow.into(),
            format!("{}", r.tau),
            format!("{:.4}", r.feasible_frac),
            format!("{:.2}", r.savings * 100.0),
            format!("{:.1}", r.recall * 100.0),
            format!("{:.1}", r.recall_clean * 100.0),
        ])?;
        println!(
            "{:<10} {:>5.2} {:>9.1}% {:>9.1}% {:>7.1}% {:>7.1}%",
            r.workflow,
            r.tau,
            r.feasible_frac * 100.0,
            r.savings * 100.0,
            r.recall * 100.0,
            r.recall_clean * 100.0
        );
    }
    csv.flush()?;

    let avg_savings =
        all.iter().map(|r| r.savings).sum::<f64>() / all.len() as f64;
    let max_savings = all.iter().map(|r| r.savings).fold(0.0, f64::max);
    let min_recall = all.iter().map(|r| r.recall).fold(1.0, f64::min);
    let min_clean = all.iter().map(|r| r.recall_clean).fold(1.0, f64::min);
    println!(
        "\nHeadline: recall(min) {:.1}% (noise-free GT: {:.1}%) | avg savings {:.1}% | max savings {:.1}%",
        min_recall * 100.0,
        min_clean * 100.0,
        avg_savings * 100.0,
        max_savings * 100.0
    );
    println!("(paper:   recall 100% | avg savings 57.5% | max 95.3%)");
    println!("-> results/fig4_efficiency.csv");
    Ok(())
}
