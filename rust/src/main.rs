//! `compass` — CLI for the Compass reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap offline — DESIGN.md §6):
//!
//! * `search   [--workflow rag|detection] [--tau T]` — run COMPASS-V vs
//!   grid ground truth, print recall/savings.
//! * `plan     [--tau T] [--slo MS] [--live] [--out plan.json]` — offline
//!   phase: search + profile + Pareto + AQM thresholds.
//! * `serve    [--slo MS] [--duration S] [--pattern spike|bursty|steady]
//!   [--policy NAME] [--workers K] [--discipline central|sharded]
//!   [--shards N] [--batch B]` — one live serving run, report summary.
//! * `experiment <fig1|fig3|fig4|table1|fig5|fig6|fig7|all> [--live]
//!   [--duration S]` — regenerate paper artifacts (CSV under results/).
//! * `scenario [--smoke] [--scenarios a,b] [--topos x,y] [--policies p,q]
//!   [--faults SPEC] [--overload SPEC] [--classes SPEC] [--replay FILE]
//!   [--save-trace FILE] [--log DIR]` —
//!   scenario matrix sweep -> BENCH_scenarios.json (docs/SCENARIOS.md).
//! * `profile  [--live]` — per-component latency table.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use compass::configspace::{detection_space, rag_space};
use compass::experiments::{self, ExperimentCtx};
use compass::oracle::{DetectionOracle, RagOracle};
use compass::planner::{profile_config, ThresholdMode};
use compass::runtime::artifacts_dir;
use compass::search::{grid_search, BudgetSchedule, CompassV, CompassVParams};
use compass::serving::executor::WorkflowEngine;
use compass::serving::{
    parse_pools, serve, Discipline, PoolSpec, QueueBackend, ServeOptions,
};
use compass::util::results_dir;
use compass::workflows::rag::RagWorkflow;
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs and flags after the subcommand.
fn parse_opts(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".into());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, opts)
}

fn get_f64(opts: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v}")),
    }
}

/// Parse `--discipline central|sharded` (default central — the paper's
/// testbed; `--shards` picks the shard count under sharded, 0 = auto).
fn get_discipline(opts: &HashMap<String, String>) -> Result<Discipline> {
    match opts.get("discipline") {
        None => Ok(Discipline::CentralFifo),
        Some(v) => Discipline::parse(v).ok_or_else(|| {
            anyhow::anyhow!("--discipline expects central|sharded, got {v}")
        }),
    }
}

/// Parse `--queue mutex|ring` (default mutex — bit-for-bit the seed's
/// locked shards; `ring` swaps in the lock-free bounded MPMC rings).
fn get_backend(opts: &HashMap<String, String>) -> Result<QueueBackend> {
    match opts.get("queue") {
        None => Ok(QueueBackend::Mutex),
        Some(v) => QueueBackend::parse(v).ok_or_else(|| {
            anyhow::anyhow!("--queue expects mutex|ring, got {v}")
        }),
    }
}

/// Parse `--pools name:workers:speed[:offset],...` (empty = homogeneous).
fn get_pools(opts: &HashMap<String, String>) -> Result<Vec<PoolSpec>> {
    match opts.get("pools") {
        None => Ok(Vec::new()),
        Some(v) => parse_pools(v),
    }
}

/// Parse `--thresholds legacy|erlang` (default legacy — bit-for-bit the
/// seed threshold derivation).
fn get_thresholds(opts: &HashMap<String, String>) -> Result<ThresholdMode> {
    match opts.get("thresholds") {
        None => Ok(ThresholdMode::Legacy),
        Some(v) => ThresholdMode::parse(v).ok_or_else(|| {
            anyhow::anyhow!("--thresholds expects legacy|erlang, got {v}")
        }),
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let (pos, opts) = parse_opts(&args[1..]);
    let seed = get_f64(&opts, "seed", 7.0)? as u64;

    match cmd.as_str() {
        "search" => cmd_search(&opts, seed),
        "plan" => cmd_plan(&opts, seed),
        "serve" => cmd_serve(&opts, seed),
        "experiment" => {
            let id = pos.first().map(String::as_str).unwrap_or("all");
            let ctx = ExperimentCtx {
                live: opts.contains_key("live"),
                duration_s: get_f64(&opts, "duration", 180.0)?,
                seed,
                workers: get_f64(&opts, "workers", 1.0)?.max(1.0) as usize,
                discipline: get_discipline(&opts)?,
                shards: get_f64(&opts, "shards", 0.0)?.max(0.0) as usize,
                batch: get_f64(&opts, "batch", 1.0)?.max(1.0) as usize,
                pools: get_pools(&opts)?,
                spill_margin: get_f64(&opts, "spill-margin", 0.0)?.max(0.0),
                thresholds: get_thresholds(&opts)?,
                backend: get_backend(&opts)?,
                out_dir: results_dir(),
            };
            experiments::run(id, &ctx)
        }
        "scenario" => cmd_scenario(&opts, seed),
        "profile" => cmd_profile(&opts, seed),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other}; run `compass help`"),
    }
}

fn print_help() {
    println!(
        "compass — Compound AI workflow optimization & dynamic adaptation\n\
         \n\
         USAGE: compass <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 search      COMPASS-V feasible-set search vs exhaustive ground truth\n\
         \x20             [--workflow rag|detection] [--tau T] [--seed N]\n\
         \x20 plan        offline phase: search + profile + Pareto + AQM plan\n\
         \x20             [--tau T] [--slo MS] [--workers K] [--batch B] [--live]\n\
         \x20             [--pools n:w:speed[:rung],...] [--thresholds legacy|erlang]\n\
         \x20             [--out FILE]\n\
         \x20 serve       one live serving run over the AOT artifacts\n\
         \x20             [--slo MS] [--duration S] [--pattern spike|bursty|steady]\n\
         \x20             [--policy Elastico|Static-Fast|Static-Medium|Static-Accurate]\n\
         \x20             [--workers K] [--discipline central|sharded] [--shards N]\n\
         \x20             [--batch B] [--pools fast:4:1.0,accurate:2:2.5]\n\
         \x20             [--spill-margin M] [--thresholds legacy|erlang]\n\
         \x20             [--queue mutex|ring]\n\
         \x20             [--replan on|off|on,interval_ms=2000,bmax=8]\n\
         \x20             [--faults drift:0x2@20 ...]\n\
         \x20 experiment  regenerate paper figures/tables -> results/*.csv\n\
         \x20             <fig1|fig3|fig4|table1|fig5|fig6|fig7|all> [--live] [--duration S]\n\
         \x20             [--workers K] [--discipline central|sharded] [--shards N]\n\
         \x20             [--batch B] [--pools n:w:speed[:rung],...]\n\
         \x20             [--spill-margin M] [--thresholds legacy|erlang]\n\
         \x20             [--queue mutex|ring]\n\
         \x20 scenario    scenario matrix sweep -> BENCH_scenarios.json + results/scenarios.csv\n\
         \x20             [--smoke] [--duration S] [--slo MS] [--seed N] [--live]\n\
         \x20             [--batch B] [--queue mutex|ring]\n\
         \x20             [--scenarios a,b,..] [--topos x,y,..] [--policies p,q,..]\n\
         \x20             [--faults dark:1@24-60,slow:0x2.5@20-40,flaky:0x0.25@20-40]\n\
         \x20             [--resilience on|off|on,max_retries=3,timeout_ms=500]\n\
         \x20             [--overload on|off|on,shed=deadline|tail,shed_depth=256]\n\
         \x20             [--replan on|off|on,interval_ms=2000,bmax=8]\n\
         \x20             [--classes gold:0.2:500,silver:0.5:2000,bronze:0.3:0]\n\
         \x20             [--out FILE] [--log DIR] [--replay FILE] [--save-trace FILE]\n\
         \x20             [--list]  (cookbook: docs/SCENARIOS.md)\n\
         \x20 profile     per-component latency table over the artifacts [--live]\n"
    );
}

fn cmd_search(opts: &HashMap<String, String>, seed: u64) -> Result<()> {
    let workflow = opts.get("workflow").map(String::as_str).unwrap_or("rag");
    let (space, schedule, tau_default) = match workflow {
        "rag" => (rag_space(), BudgetSchedule::rag(), 0.75),
        "detection" => (detection_space(), BudgetSchedule::detection(), 0.70),
        other => bail!("unknown workflow {other}"),
    };
    let tau = get_f64(opts, "tau", tau_default)?;
    let n = space.enumerate_valid().len();
    let b_max = schedule.b_max();

    println!("COMPASS-V on {workflow}: {} valid configs, tau={tau}", n);
    let result = match workflow {
        "rag" => {
            let mut oracle = RagOracle::new_rag(seed);
            CompassV::new(CompassVParams {
                seed,
                schedule: schedule.clone(),
                ..Default::default()
            })
            .run(&space, tau, &mut oracle)
        }
        _ => {
            let mut oracle = DetectionOracle::new_detection(seed);
            CompassV::new(CompassVParams {
                seed,
                schedule: schedule.clone(),
                ..Default::default()
            })
            .run(&space, tau, &mut oracle)
        }
    };
    let savings = result.savings_vs_exhaustive(n, b_max);

    // Ground truth for recall.
    let gt = match workflow {
        "rag" => {
            let mut o = RagOracle::new_rag(seed);
            grid_search(&space, b_max, &mut o).feasible(tau)
        }
        _ => {
            let mut o = DetectionOracle::new_detection(seed);
            grid_search(&space, b_max, &mut o).feasible(tau)
        }
    };
    let gt_ids: std::collections::HashSet<usize> =
        gt.iter().map(|(c, _)| space.flat_id(c)).collect();
    let hit = result
        .feasible
        .iter()
        .filter(|(c, _)| gt_ids.contains(&space.flat_id(c)))
        .count();
    println!("  feasible found: {} (ground truth {})", result.feasible.len(), gt.len());
    println!(
        "  samples used:   {} (exhaustive {})",
        result.samples_used,
        n as u64 * b_max as u64
    );
    println!("  savings:        {:.1}%", savings * 100.0);
    println!(
        "  recall:         {:.1}%",
        if gt.is_empty() { 100.0 } else { 100.0 * hit as f64 / gt.len() as f64 }
    );
    Ok(())
}

fn cmd_plan(opts: &HashMap<String, String>, seed: u64) -> Result<()> {
    let tau = get_f64(opts, "tau", 0.75)?;
    let live = opts.contains_key("live");
    let workers = get_f64(opts, "workers", 1.0)?.max(1.0) as usize;
    let batch = get_f64(opts, "batch", 1.0)?.max(1.0) as usize;
    let pools = get_pools(opts)?;
    let thresholds = get_thresholds(opts)?;
    // Default SLO: 2.2x the slowest rung (≙ the paper's 1000 ms target).
    let slo = match opts.get("slo") {
        Some(v) => v.parse::<f64>()?,
        None => {
            let (_s, probe) =
                compass::experiments::common::offline_phase(tau, 1e9, seed, live)?;
            2.2 * probe.ladder.last().unwrap().mean_ms
        }
    };
    let (_space, plan) = compass::experiments::common::offline_phase_full(
        tau, slo, seed, live, workers, batch, thresholds, &pools,
    )?;
    print!("{}", plan.render());
    if let Some(path) = opts.get("out") {
        std::fs::write(path, plan.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>, seed: u64) -> Result<()> {
    let tau = get_f64(opts, "tau", 0.75)?;
    let duration = get_f64(opts, "duration", 60.0)?;
    let workers = get_f64(opts, "workers", 1.0)?.max(1.0) as usize;
    let discipline = get_discipline(opts)?;
    let shards = get_f64(opts, "shards", 0.0)?.max(0.0) as usize;
    let batch = get_f64(opts, "batch", 1.0)?.max(1.0) as usize;
    let pools = get_pools(opts)?;
    let spill_margin = get_f64(opts, "spill-margin", 0.0)?.max(0.0);
    let thresholds = get_thresholds(opts)?;
    let backend = get_backend(opts)?;
    let policy_name = opts
        .get("policy")
        .cloned()
        .unwrap_or_else(|| "Elastico".into());
    let pattern = match opts.get("pattern").map(String::as_str).unwrap_or("spike") {
        "spike" => Pattern::paper_spike(),
        "bursty" => Pattern::paper_bursty(),
        "steady" => Pattern::Steady,
        other => bail!("unknown pattern {other}"),
    };

    let (_s, probe) =
        compass::experiments::common::offline_phase(tau, 1e9, seed, false)?;
    let slo = match opts.get("slo") {
        Some(v) => v.parse::<f64>()?,
        None => 2.2 * probe.ladder.last().unwrap().mean_ms,
    };
    let (space, plan) = compass::experiments::common::offline_phase_full(
        tau, slo, seed, false, workers, batch, thresholds, &pools,
    )?;
    println!("Serving plan (SLO {slo:.0} ms, {} thresholds):", thresholds.name());
    print!("{}", plan.render());

    // The re-planner needs the base plan it will re-derive; `--replan on`
    // attaches the one computed above.
    let replan = match opts.get("replan") {
        Some(v) => compass::serving::ReplanConfig::parse(v)?.with_plan(plan.clone()),
        None => compass::serving::ReplanConfig::default(),
    };
    let faults = match opts.get("faults") {
        Some(v) => compass::workload::FaultPlan::parse(v)?,
        None => compass::workload::FaultPlan::default(),
    };
    let serve_opts = ServeOptions {
        workers,
        discipline,
        shards,
        batch,
        pools: pools.clone(),
        spill_margin,
        faults,
        replan,
        backend,
        ..ServeOptions::default()
    };
    let total_workers = serve_opts.total_workers();
    let base_qps =
        compass::experiments::common::base_qps_pools(&probe, workers, &pools);
    let spec = WorkloadSpec { base_qps, duration_s: duration, pattern, seed };
    let arrivals = generate_arrivals(&spec);
    println!(
        "Live serving: {} arrivals over {duration}s (base {:.2} qps), \
         policy {policy_name}, {total_workers} worker(s), {}, batch {batch}",
        arrivals.len(),
        spec.base_qps,
        if pools.is_empty() {
            format!("{} dispatch", discipline.name())
        } else {
            format!("pools {}", compass::serving::pool::describe_pools(&pools))
        }
    );

    let policy = compass::experiments::common::make_policy(&plan, &policy_name);
    let space2 = space.clone();
    let plan2 = plan.clone();
    let out = serve(
        move || {
            let configs: Vec<_> =
                plan2.ladder.iter().map(|p| p.config.clone()).collect();
            let wf =
                RagWorkflow::load_subset(&artifacts_dir(), &space2, &configs, seed)?;
            Ok(WorkflowEngine::new(wf, space2.clone(), plan2.clone()))
        },
        policy,
        &arrivals,
        &serve_opts,
    )?;
    let summary = compass::metrics::RunSummary::compute(
        &out.records,
        &out.switches,
        slo,
        plan.ladder.len(),
    );
    println!(
        "{}",
        compass::metrics::report::summary_row(&policy_name, &summary)
    );
    if let Some(rate) = summary.success_rate {
        println!("  measured success rate: {rate:.3}");
    }
    println!(
        "  rejected: {}, steals: {}, spills: {}, final rate {:.2} qps",
        out.rejected, out.steals, out.spills, out.final_rate_qps
    );
    if serve_opts.replan.enabled {
        println!("  re-plans adopted: {}", out.replans);
    }
    if !pools.is_empty() {
        for (p, spec) in pools.iter().enumerate() {
            println!(
                "  pool {:<12} routed {:>6}  served {:>6}",
                spec.name, out.pool_arrivals[p], out.pool_served[p]
            );
        }
    }
    Ok(())
}

fn cmd_scenario(opts: &HashMap<String, String>, seed: u64) -> Result<()> {
    use compass::experiments::scenarios;
    if opts.contains_key("list") {
        println!("scenarios:  {}", scenarios::SCENARIOS.join(", "));
        println!("topologies: {}", scenarios::TOPOLOGIES.join(", "));
        println!("policies:   {}", scenarios::SWEEP_POLICIES.join(", "));
        return Ok(());
    }
    let smoke = opts.contains_key("smoke");
    let ctx = ExperimentCtx {
        live: opts.contains_key("live"),
        duration_s: get_f64(opts, "duration", if smoke { 30.0 } else { 60.0 })?,
        seed,
        batch: get_f64(opts, "batch", 1.0)?.max(1.0) as usize,
        backend: get_backend(opts)?,
        ..ExperimentCtx::default()
    };
    let split = |key: &str| -> Vec<String> {
        match opts.get(key) {
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect(),
            None => Vec::new(),
        }
    };
    let slo_ms = match opts.get("slo") {
        Some(v) => Some(v.parse::<f64>()?),
        None => None,
    };
    let faults = match opts.get("faults") {
        Some(v) => Some(compass::workload::FaultPlan::parse(v)?),
        None => None,
    };
    let resilience = match opts.get("resilience") {
        Some(v) => Some(compass::serving::ResilienceConfig::parse(v)?),
        None => None,
    };
    let overload = match opts.get("overload") {
        Some(v) => Some(compass::serving::OverloadConfig::parse(v)?),
        None => None,
    };
    let replan = match opts.get("replan") {
        Some(v) => Some(compass::serving::ReplanConfig::parse(v)?),
        None => None,
    };
    let classes = match opts.get("classes") {
        Some(v) => Some(compass::serving::parse_classes(v)?),
        None => None,
    };
    let out = opts.get("out").map(String::as_str).unwrap_or("BENCH_scenarios.json");
    let sweep = scenarios::ScenarioOpts {
        smoke,
        scenarios: split("scenarios"),
        topos: split("topos"),
        policies: split("policies"),
        slo_ms,
        out: PathBuf::from(out),
        log_dir: opts.get("log").map(PathBuf::from),
        replay: opts.get("replay").map(PathBuf::from),
        faults,
        resilience,
        overload,
        classes,
        replan,
    };
    if let Some(path) = opts.get("save-trace") {
        let scenario = sweep.scenarios.first().map(String::as_str).unwrap_or("steady");
        let topo = sweep.topos.first().map(String::as_str).unwrap_or("uniform-k4");
        return scenarios::save_scenario_trace(&ctx, scenario, topo, Path::new(path));
    }
    scenarios::run_sweep(&ctx, &sweep)
}

fn cmd_profile(opts: &HashMap<String, String>, seed: u64) -> Result<()> {
    let live = opts.contains_key("live");
    let space = rag_space();
    if !live {
        println!("Modeled per-component costs (pass --live to measure):");
        for (i, name) in compass::workflows::rag::GENERATOR_NAMES.iter().enumerate() {
            println!("  {name:<9} {:>8.1} ms", compass::experiments::common::GEN_MS[i]);
        }
        for (i, name) in compass::workflows::rag::RERANKER_NAMES.iter().enumerate() {
            println!(
                "  {name:<9} {:>8.1} ms / batch of 5",
                compass::experiments::common::RR_BATCH_MS[i]
            );
        }
        return Ok(());
    }
    let mut wf = RagWorkflow::load(&artifacts_dir(), seed)?;
    println!("Live component profile:");
    for g in 0..6 {
        let p = profile_config(&mut wf, &space, &vec![g, 0, 0, 0], 2, 6);
        println!(
            "  {:<9} mean {:>8.1} ms  p95 {:>8.1} ms",
            compass::workflows::rag::GENERATOR_NAMES[g], p.mean_ms, p.p95_ms
        );
    }
    for rr in 0..3 {
        let p = profile_config(&mut wf, &space, &vec![0, 4, 0, rr], 2, 6);
        println!(
            "  {:<9} mean {:>8.1} ms  p95 {:>8.1} ms (k=50 path)",
            compass::workflows::rag::RERANKER_NAMES[rr], p.mean_ms, p.p95_ms
        );
    }
    Ok(())
}
