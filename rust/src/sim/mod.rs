//! Discrete-event M/G/k serving simulator.
//!
//! Replays a workload trace against a service-time model derived from the
//! Planner's latency profiles, driving the *same* [`ScalingPolicy`]
//! implementations as the live server. Used to
//!
//! * validate the AQM thresholds analytically (queued requests stay
//!   within the latency slack — §V),
//! * regenerate the paper's serving figures quickly and deterministically
//!   (180 s x 24 experiment cells replay in milliseconds),
//! * property-test controller invariants over thousands of random loads.
//!
//! Semantics mirror the live executor pool: a single FIFO queue drained
//! by k servers (head-of-line dispatch to the earliest-free server);
//! configuration switches are routing-only and take effect on the *next*
//! dequeue (in-flight requests finish under their old configuration).
//! [`simulate`] is the k = 1 case and reproduces the original M/G/1
//! simulator event-for-event. Known divergence from the live server
//! (inherited from the seed simulator): the arrival-time policy
//! observation here includes the in-service count (≤ k) on top of the
//! queue depth, while the live injector observes queue depth only —
//! kept so k = 1 results stay bit-for-bit with the paper figures.

pub mod service;
pub mod theory;

pub use service::{DeterministicService, LognormalService, ServiceModel};

use crate::metrics::{RequestRecord, SwitchEvent};
use crate::planner::Plan;
use crate::serving::policy::ScalingPolicy;
use crate::util::Rng;

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub records: Vec<RequestRecord>,
    pub switches: Vec<SwitchEvent>,
}

/// Simulate serving `arrivals` (seconds) under `policy` on a single
/// server (the paper's M/G/1 testbed) — see [`simulate_k`].
pub fn simulate<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
) -> SimOutcome {
    simulate_k(arrivals, plan, policy, service, seed, 1)
}

/// Simulate serving `arrivals` (seconds) under `policy` on a pool of
/// `workers` servers draining one FIFO queue (M/G/k).
///
/// `service` samples per-request service times (ms) given a ladder index;
/// `plan` supplies per-rung expected accuracy. The policy is consulted on
/// every arrival and every departure (the live monitor's tick points).
/// The head of the queue is dispatched to the earliest-free server; with
/// `workers == 1` this is bit-for-bit the original M/G/1 simulator.
pub fn simulate_k<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    workers: usize,
) -> SimOutcome {
    let mut rng = Rng::new(seed);
    let mut records = Vec::with_capacity(arrivals.len());
    let mut switches = Vec::new();

    // Queue of (id, arrival_ms); server s is busy until `busy[s]`.
    let mut queue: std::collections::VecDeque<(u64, f64)> =
        std::collections::VecDeque::new();
    let mut busy: Vec<f64> = vec![f64::NEG_INFINITY; workers.max(1)];
    let mut observed = policy.current();

    let observe = |policy: &mut P,
                       switches: &mut Vec<SwitchEvent>,
                       observed: &mut usize,
                       now: f64,
                       depth: usize| {
        let next = policy.decide(now, depth);
        if next != *observed {
            switches.push(SwitchEvent { at_ms: now, from_idx: *observed, to_idx: next });
            *observed = next;
        }
        next
    };

    let mut i = 0usize; // next arrival index
    let n = arrivals.len();
    let mut next_id = 0u64;

    // Event loop: either the next arrival or the earliest server
    // freeing up.
    while i < n || !queue.is_empty() {
        let next_arrival = if i < n { arrivals[i] * 1000.0 } else { f64::INFINITY };

        // Earliest-free server (ties broken by lowest index).
        let (slot, earliest) = busy
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();

        if !queue.is_empty() && earliest <= next_arrival {
            // Serve the head of the queue at max(server-free, arrival).
            let (id, arr_ms) = queue.pop_front().unwrap();
            let start = earliest.max(arr_ms);
            // Switches apply at dequeue: consult the policy now.
            let idx = observe(policy, &mut switches, &mut observed, start, queue.len());
            let svc = service.sample_ms(idx, &mut rng);
            let finish = start + svc;
            busy[slot] = finish;
            records.push(RequestRecord {
                id,
                arrival_ms: arr_ms,
                start_ms: start,
                finish_ms: finish,
                config_idx: idx,
                accuracy: plan.ladder[idx].accuracy,
                success: None,
            });
            // Departure observation.
            observe(policy, &mut switches, &mut observed, finish, queue.len());
        } else if i < n {
            // Admit the next arrival.
            let arr_ms = arrivals[i] * 1000.0;
            queue.push_back((next_id, arr_ms));
            next_id += 1;
            i += 1;
            // In-flight requests count toward the observed depth.
            let in_flight = busy.iter().filter(|&&b| b > arr_ms).count();
            observe(policy, &mut switches, &mut observed, arr_ms, queue.len() + in_flight);
        } else {
            break;
        }
    }

    records.sort_by(|a, b| a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap());
    SimOutcome { records, switches }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunSummary;
    use crate::planner::{AqmParams, ConfigPolicy};
    use crate::serving::policy::StaticPolicy;
    use crate::serving::ElasticoPolicy;

    fn plan2() -> Plan {
        let rung = |label: &str, acc: f64, mean: f64, p95: f64| ConfigPolicy {
            label: label.into(),
            config: vec![],
            accuracy: acc,
            mean_ms: mean,
            p95_ms: p95,
            queue_slack_ms: 0.0,
            upscale_threshold: 0,
            downscale_threshold: None,
        };
        // Derive real thresholds through the AQM.
        let front = vec![
            crate::planner::ProfiledConfig {
                config: vec![],
                label: "fast".into(),
                accuracy: 0.76,
                latency: crate::planner::LatencyProfile {
                    mean_ms: 20.0,
                    p50_ms: 20.0,
                    p95_ms: 28.0,
                    runs: 10,
                },
            },
            crate::planner::ProfiledConfig {
                config: vec![],
                label: "accurate".into(),
                accuracy: 0.85,
                latency: crate::planner::LatencyProfile {
                    mean_ms: 90.0,
                    p50_ms: 90.0,
                    p95_ms: 120.0,
                    runs: 10,
                },
            },
        ];
        let _ = rung; // silence helper when unused
        crate::planner::derive_plan(&front, AqmParams::for_slo(300.0))
    }

    fn arrivals(qps: f64, dur: f64) -> Vec<f64> {
        crate::workload::generate_arrivals(&crate::workload::WorkloadSpec {
            base_qps: qps,
            duration_s: dur,
            pattern: crate::workload::Pattern::Steady,
            seed: 5,
        })
    }

    #[test]
    fn fifo_and_single_server_invariants() {
        let plan = plan2();
        let arr = arrivals(8.0, 60.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let mut pol = StaticPolicy::new(0, "fast");
        let out = simulate(&arr, &plan, &mut pol, &svc, 1);
        assert_eq!(out.records.len(), arr.len());
        // Single server: service intervals never overlap.
        let mut by_start = out.records.clone();
        by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
        for w in by_start.windows(2) {
            assert!(w[1].start_ms >= w[0].finish_ms - 1e-9);
        }
        // FIFO: start order == arrival order.
        for w in by_start.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        assert!(out.switches.is_empty());
    }

    #[test]
    fn accurate_under_overload_violates_fast_does_not() {
        let plan = plan2();
        // 8 qps: fast (20ms) has utilization 0.16; accurate (90ms) 0.72
        // at base — push 15 qps to overload accurate (1.35 > 1).
        let arr = arrivals(15.0, 60.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let mut fast = StaticPolicy::new(0, "fast");
        let mut acc = StaticPolicy::new(1, "accurate");
        let f = simulate(&arr, &plan, &mut fast, &svc, 2);
        let a = simulate(&arr, &plan, &mut acc, &svc, 2);
        let fs = RunSummary::compute(&f.records, &f.switches, 300.0, 2);
        let as_ = RunSummary::compute(&a.records, &a.switches, 300.0, 2);
        assert!(fs.slo_compliance > 0.95, "fast {}", fs.slo_compliance);
        assert!(as_.slo_compliance < 0.5, "accurate {}", as_.slo_compliance);
    }

    #[test]
    fn elastico_beats_both_static_extremes_under_spike() {
        let plan = plan2();
        let spec = crate::workload::WorkloadSpec {
            base_qps: 6.0,
            duration_s: 120.0,
            pattern: crate::workload::Pattern::paper_spike(),
            seed: 9,
        };
        let arr = crate::workload::generate_arrivals(&spec);
        let svc = LognormalService::from_plan(&plan, 0.25);

        let mut ela = ElasticoPolicy::new(plan.clone());
        let e = simulate(&arr, &plan, &mut ela, &svc, 3);
        let es = RunSummary::compute(&e.records, &e.switches, 300.0, 2);

        let mut acc = StaticPolicy::new(1, "accurate");
        let a = simulate(&arr, &plan, &mut acc, &svc, 3);
        let as_ = RunSummary::compute(&a.records, &a.switches, 300.0, 2);

        let mut fast = StaticPolicy::new(0, "fast");
        let f = simulate(&arr, &plan, &mut fast, &svc, 3);
        let fs = RunSummary::compute(&f.records, &f.switches, 300.0, 2);

        assert!(
            es.slo_compliance > as_.slo_compliance + 0.2,
            "elastico {} vs accurate {}",
            es.slo_compliance,
            as_.slo_compliance
        );
        assert!(
            es.mean_accuracy > fs.mean_accuracy + 0.01,
            "elastico {} vs fast {}",
            es.mean_accuracy,
            fs.mean_accuracy
        );
        assert!(es.switches >= 2, "should adapt during the spike");
    }

    /// Exact record equality (RequestRecord carries f64 times).
    fn records_identical(a: &[RequestRecord], b: &[RequestRecord]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.id == y.id
                    && x.arrival_ms == y.arrival_ms
                    && x.start_ms == y.start_ms
                    && x.finish_ms == y.finish_ms
                    && x.config_idx == y.config_idx
            })
    }

    #[test]
    fn k1_reproduces_single_server_simulate_exactly() {
        // simulate() must stay bit-for-bit the M/G/1 simulator: same
        // seed, same arrivals -> identical records through simulate_k(1).
        let plan = plan2();
        let arr = arrivals(12.0, 90.0);
        let svc = LognormalService::from_plan(&plan, 0.25);

        let mut p1 = ElasticoPolicy::new(plan.clone());
        let a = simulate(&arr, &plan, &mut p1, &svc, 42);
        let mut p2 = ElasticoPolicy::new(plan.clone());
        let b = simulate_k(&arr, &plan, &mut p2, &svc, 42, 1);

        assert!(records_identical(&a.records, &b.records));
        assert_eq!(a.switches.len(), b.switches.len());
    }

    #[test]
    fn k_servers_shrink_the_makespan() {
        // Deterministic overload: 100 arrivals, 40 ms service. One
        // server needs ~4000 ms; four servers ~1000 ms.
        let plan = plan2();
        let arr: Vec<f64> = (0..100).map(|i| i as f64 * 0.001).collect();
        let svc = DeterministicService { means: vec![40.0, 40.0] };

        let makespan = |k: usize| {
            let mut pol = StaticPolicy::new(0, "fast");
            let out = simulate_k(&arr, &plan, &mut pol, &svc, 1, k);
            out.records
                .iter()
                .map(|r| r.finish_ms)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let m1 = makespan(1);
        let m4 = makespan(4);
        assert!(m1 / m4 >= 3.9, "makespan k=1 {m1:.0} vs k=4 {m4:.0}");
    }

    #[test]
    fn never_more_than_k_in_service() {
        let plan = plan2();
        let arr = arrivals(40.0, 30.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        for k in [1usize, 2, 3] {
            let mut pol = StaticPolicy::new(1, "accurate");
            let out = simulate_k(&arr, &plan, &mut pol, &svc, 7, k);
            assert_eq!(out.records.len(), arr.len());
            // Sweep service intervals: concurrency never exceeds k.
            let mut events: Vec<(f64, i32)> = Vec::new();
            for r in &out.records {
                events.push((r.start_ms, 1));
                events.push((r.finish_ms, -1));
            }
            events.sort_by(|a, b| {
                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
            });
            let mut in_service = 0;
            for (_, d) in events {
                in_service += d;
                assert!(in_service <= k as i32, "concurrency {in_service} > k {k}");
            }
        }
    }
}
