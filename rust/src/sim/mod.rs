//! Discrete-event serving simulator — shims over the one topology-driven
//! engine.
//!
//! Replays a workload trace against a service-time model derived from the
//! Planner's latency profiles, driving the *same* [`ScalingPolicy`]
//! implementations as the live server. Used to
//!
//! * validate the AQM thresholds analytically (queued requests stay
//!   within the latency slack — §V),
//! * regenerate the paper's serving figures quickly and deterministically
//!   (180 s x 24 experiment cells replay in milliseconds),
//! * property-test controller invariants over thousands of random loads,
//! * quantify the ordering/latency delta of sharded work stealing and
//!   heterogeneous pool routing against central-FIFO theory before
//!   touching the live pool.
//!
//! ## One engine, many shapes
//!
//! Since the dispatch-plane unification there is a single event loop,
//! [`engine::simulate_topology`], parameterized by a
//! [`crate::serving::topology::Topology`] — the same pure decision core
//! (shard layout, routing, walk order, spill gate, batch arithmetic)
//! the live `ShardedQueue` executes. The historical entry points are
//! thin shims that build the matching topology:
//!
//! * [`simulate`] — one server, one shard (the paper's M/G/1 testbed);
//! * [`simulate_k`] — k servers draining one central FIFO (M/G/k);
//! * [`simulate_disc`] — either [`Discipline`]: `CentralFifo` is the
//!   one-shard shape, `ShardedSteal` runs round-robin routing over
//!   `shards` per-worker FIFOs with front-of-queue steal-half;
//! * [`simulate_pools`] — named heterogeneous pools with rung-aware
//!   routing, within-pool stealing, gated cross-pool spill, per-pool
//!   service-time scaling and per-pool engine rungs.
//!
//! `CentralFifo == ShardedSteal(shards = 1)` and
//! `ShardedSteal(k shards) == simulate_pools(one uniform pool of k)`
//! therefore hold **by construction** — all three are the same loop over
//! the same core — and the parity tests below survive unmodified as
//! regression pins on the shims rather than as the only thing holding
//! five hand-kept copies together. What parity remains *pinned by test*
//! is live-vs-simulated equivalence (`tests/theory_validation.rs`, the
//! worker-pool suite): the DES shares the live runtime's decisions but
//! models its mechanics (real threads, locks, the wall clock).
//!
//! ## Signals and known divergences
//!
//! The policy observes the per-pool queued depth of the current rung's
//! home pool at every arrival, dispatch and departure — on a single
//! pool exactly the total-across-shards signal the live `ShardedQueue`
//! maintains lock-free. Known divergence from the live server
//! (inherited from the seed simulator): the arrival-time observation
//! includes the routed pool's in-service count (≤ k) on top of its
//! queue depth, while the live injector observes queue depth only —
//! kept so k = 1 results stay bit-for-bit with the paper figures. The
//! DES queue is unbounded (no admission rejections), as in the seed.
//!
//! ## Batch model
//!
//! A freeing server drains up to B requests from the chosen shard in
//! one dispatch — a front run of its home shard, or a steal/spill-half
//! (`⌈len/2⌉`, capped at B) from the victim — exactly the live
//! `ShardedQueue::pop_batch` walk, so FIFO-per-shard order is preserved
//! and a batch never spans shards. Batch service time follows
//! `s̄(B) = α + β·B` with `α =` [`crate::planner::Plan::batch_alpha_ms`]:
//! each request's sampled service time is treated as `α + βᵢ`, so a
//! batch of n costs `Σᵢ sᵢ − (n−1)·α` — n marginal costs but one
//! dispatch cost. All n requests share the batch's start/finish (a
//! request completes when its batch does) and the policy is consulted
//! once per batch at dispatch and once at departure, mirroring the live
//! executor. With `B = 1` every expression degenerates to the seed
//! simulator bit-for-bit (same rng consumption, same timestamps).
//!
//! A guided tour of the whole dispatch plane — how this engine and the
//! live `ShardedQueue` share one decision core, and where routing,
//! steal, spill, batch and AQM each live — is in `docs/ARCHITECTURE.md`.
//!
//! Failure injection: [`engine::simulate_topology_faults`] applies a
//! [`crate::workload::FaultPlan`] (pool dark, slowdown window, queue
//! squeeze) to the same event loop; [`SimOutcome::rejected`] counts the
//! arrivals a fault turned away so `served + rejected == arrivals`
//! stays checkable under faults.

pub mod engine;
pub mod service;
pub mod theory;

pub use engine::{
    simulate_topology, simulate_topology_faults, simulate_topology_overload,
    simulate_topology_replan, simulate_topology_resilient,
};
pub use service::{
    DeterministicService, ExponentialService, LognormalService, ParetoService, ServiceModel,
};

// The queue discipline and the decision core are defined next to the
// live queues and shared with the DES so both sides dispatch
// identically.
pub use crate::serving::topology::Topology;
pub use crate::serving::Discipline;

use crate::metrics::{RequestRecord, SwitchEvent};
use crate::planner::Plan;
use crate::serving::policy::ScalingPolicy;
use crate::serving::pool::PoolSpec;

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub records: Vec<RequestRecord>,
    pub switches: Vec<SwitchEvent>,
    /// Dispatches satisfied by stealing from a non-home shard of the
    /// server's own pool (always 0 under [`Discipline::CentralFifo`]).
    pub steals: u64,
    /// Dispatches satisfied by spilling into another pool's shards
    /// (always 0 outside [`simulate_pools`] / a multi-pool
    /// [`simulate_topology`]).
    pub spills: u64,
    /// Arrivals turned away by an injected fault (queue squeeze, or a
    /// dark pool's unreachable backlog). Always 0 without a
    /// [`crate::workload::FaultPlan`]; the extended conservation law
    /// `records.len() + rejected + failed` equals the arrival count
    /// (`failed` is always 0 outside
    /// [`simulate_topology_resilient`] with failures injected).
    pub rejected: usize,
    /// Requests that failed terminally (injected flake or timeout with
    /// no retry admitted).
    pub failed: usize,
    /// Failed requests re-enqueued through health-aware routing.
    pub retries: u64,
    /// Mirrors the live counter; the DES has no panics, so always 0.
    pub panics_recovered: u64,
    /// Completions discarded for exceeding the resilience request
    /// timeout.
    pub timeouts: u64,
    /// Circuit-breaker open transitions across all pools.
    pub breaker_trips: u64,
    /// Requests routed to a non-home pool because the home pool was
    /// dark or breaker-open.
    pub failovers: u64,
    /// Arrivals shed by the overload plane's admission control — a
    /// doomed or over-budget class in deadline-aware mode, the newest
    /// past `shed_depth` in the tail-drop twin. Always 0 outside
    /// [`simulate_topology_overload`]; the fully extended conservation
    /// law is `served + rejected + failed + shed + expired == arrivals`.
    pub shed: usize,
    /// Queued requests skipped at pop time because their class deadline
    /// had already passed (lazy in-queue expiry — stale work never
    /// occupies a server).
    pub expired: usize,
    /// Brownout step-down events: the deadline-pressure EWMA degraded
    /// the effective rung within the policy's no-switch band.
    pub brownout_steps: u64,
    /// Plan swaps installed by the online re-planner (rederived
    /// thresholds the policy adopted via `replace_plan`). Always 0
    /// unless [`simulate_topology_replan`] runs with an enabled
    /// [`crate::serving::ReplanConfig`].
    pub replans: u64,
}

/// Simulate serving `arrivals` (seconds) under `policy` on a single
/// server (the paper's M/G/1 testbed) — see [`simulate_k`].
pub fn simulate<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
) -> SimOutcome {
    simulate_k(arrivals, plan, policy, service, seed, 1)
}

/// Simulate serving `arrivals` (seconds) under `policy` on a pool of
/// `workers` servers draining one central FIFO (M/G/k) — see
/// [`simulate_disc`] for the sharded discipline.
pub fn simulate_k<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    workers: usize,
) -> SimOutcome {
    simulate_disc(
        arrivals,
        plan,
        policy,
        service,
        seed,
        workers,
        Discipline::CentralFifo,
        0,
        1,
    )
}

/// Simulate serving under either homogeneous queue discipline — a shim
/// building the uniform one-pool [`Topology`] for
/// [`simulate_topology`].
///
/// `service` samples per-request service times (ms) given a ladder index;
/// `plan` supplies per-rung expected accuracy (and the per-dispatch
/// fixed cost `α` of the batch model). The policy is consulted on
/// every arrival and once per dispatch/departure (the live monitor's
/// observation points). `shards` is the shard count under
/// [`Discipline::ShardedSteal`] (0 = one per worker) and is ignored under
/// [`Discipline::CentralFifo`]; `batch` is the executor batch bound B
/// (0/1 = unbatched). With `CentralFifo`, `workers == 1` and `batch <= 1`
/// this is bit-for-bit the original M/G/1 simulator.
#[allow(clippy::too_many_arguments)]
pub fn simulate_disc<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    workers: usize,
    discipline: Discipline,
    shards: usize,
    batch: usize,
) -> SimOutcome {
    let workers = workers.max(1);
    let topo = Topology::uniform(workers, discipline.effective_shards(workers, shards));
    simulate_topology(arrivals, plan, policy, service, seed, &topo, batch)
}

/// Simulate serving on a heterogeneous fleet of named worker pools —
/// the DES mirror of [`crate::serving::serve_pools`], a shim building
/// the per-worker-shard pooled [`Topology`] (spill margin 0, the
/// historical spill-when-dry) for [`simulate_topology`].
///
/// Each pool runs `workers` servers over `workers` per-pool shards.
/// Arrivals route to the pool whose rung band contains the current
/// policy rung (per-pool round-robin); a freeing server drains its home
/// shard (front run of up to `batch`), steals half a sibling shard's
/// backlog when dry, and **spills** into other pools' shards only when
/// its whole pool is dry. A pool executes the policy rung clamped into
/// its own band and its sampled service times are scaled by its
/// `speed_factor`; the policy observes the queued depth of the current
/// rung's home pool (the per-pool AQM signal) at every arrival,
/// dispatch and departure.
///
/// A single [`PoolSpec::uniform`] pool *is* [`simulate_disc`] under
/// [`Discipline::ShardedSteal`] (one shard per worker) — the same
/// engine over the same topology — and the record-for-record parity
/// test below pins the shims equal.
pub fn simulate_pools<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    pools: &[PoolSpec],
    batch: usize,
) -> SimOutcome {
    let topo = Topology::from_pools(pools, 0.0).expect("invalid pool topology");
    simulate_topology(arrivals, plan, policy, service, seed, &topo, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunSummary;
    use crate::planner::{AqmParams, ConfigPolicy};
    use crate::serving::policy::StaticPolicy;
    use crate::serving::ElasticoPolicy;

    fn plan2() -> Plan {
        let rung = |label: &str, acc: f64, mean: f64, p95: f64| ConfigPolicy {
            label: label.into(),
            config: vec![],
            accuracy: acc,
            mean_ms: mean,
            p95_ms: p95,
            queue_slack_ms: 0.0,
            upscale_threshold: 0,
            downscale_threshold: None,
        };
        // Derive real thresholds through the AQM.
        let front = vec![
            crate::planner::ProfiledConfig {
                config: vec![],
                label: "fast".into(),
                accuracy: 0.76,
                latency: crate::planner::LatencyProfile {
                    mean_ms: 20.0,
                    p50_ms: 20.0,
                    p95_ms: 28.0,
                    runs: 10,
                },
            },
            crate::planner::ProfiledConfig {
                config: vec![],
                label: "accurate".into(),
                accuracy: 0.85,
                latency: crate::planner::LatencyProfile {
                    mean_ms: 90.0,
                    p50_ms: 90.0,
                    p95_ms: 120.0,
                    runs: 10,
                },
            },
        ];
        let _ = rung; // silence helper when unused
        crate::planner::derive_plan(&front, AqmParams::for_slo(300.0))
    }

    fn arrivals(qps: f64, dur: f64) -> Vec<f64> {
        crate::workload::generate_arrivals(&crate::workload::WorkloadSpec {
            base_qps: qps,
            duration_s: dur,
            pattern: crate::workload::Pattern::Steady,
            seed: 5,
        })
    }

    #[test]
    fn fifo_and_single_server_invariants() {
        let plan = plan2();
        let arr = arrivals(8.0, 60.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let mut pol = StaticPolicy::new(0, "fast");
        let out = simulate(&arr, &plan, &mut pol, &svc, 1);
        assert_eq!(out.records.len(), arr.len());
        // Single server: service intervals never overlap.
        let mut by_start = out.records.clone();
        by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
        for w in by_start.windows(2) {
            assert!(w[1].start_ms >= w[0].finish_ms - 1e-9);
        }
        // FIFO: start order == arrival order.
        for w in by_start.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        assert!(out.switches.is_empty());
        assert_eq!(out.steals, 0);
    }

    #[test]
    fn accurate_under_overload_violates_fast_does_not() {
        let plan = plan2();
        // 8 qps: fast (20ms) has utilization 0.16; accurate (90ms) 0.72
        // at base — push 15 qps to overload accurate (1.35 > 1).
        let arr = arrivals(15.0, 60.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let mut fast = StaticPolicy::new(0, "fast");
        let mut acc = StaticPolicy::new(1, "accurate");
        let f = simulate(&arr, &plan, &mut fast, &svc, 2);
        let a = simulate(&arr, &plan, &mut acc, &svc, 2);
        let fs = RunSummary::compute(&f.records, &f.switches, 300.0, 2);
        let as_ = RunSummary::compute(&a.records, &a.switches, 300.0, 2);
        assert!(fs.slo_compliance > 0.95, "fast {}", fs.slo_compliance);
        assert!(as_.slo_compliance < 0.5, "accurate {}", as_.slo_compliance);
    }

    #[test]
    fn elastico_beats_both_static_extremes_under_spike() {
        let plan = plan2();
        let spec = crate::workload::WorkloadSpec {
            base_qps: 6.0,
            duration_s: 120.0,
            pattern: crate::workload::Pattern::paper_spike(),
            seed: 9,
        };
        let arr = crate::workload::generate_arrivals(&spec);
        let svc = LognormalService::from_plan(&plan, 0.25);

        let mut ela = ElasticoPolicy::new(plan.clone());
        let e = simulate(&arr, &plan, &mut ela, &svc, 3);
        let es = RunSummary::compute(&e.records, &e.switches, 300.0, 2);

        let mut acc = StaticPolicy::new(1, "accurate");
        let a = simulate(&arr, &plan, &mut acc, &svc, 3);
        let as_ = RunSummary::compute(&a.records, &a.switches, 300.0, 2);

        let mut fast = StaticPolicy::new(0, "fast");
        let f = simulate(&arr, &plan, &mut fast, &svc, 3);
        let fs = RunSummary::compute(&f.records, &f.switches, 300.0, 2);

        assert!(
            es.slo_compliance > as_.slo_compliance + 0.2,
            "elastico {} vs accurate {}",
            es.slo_compliance,
            as_.slo_compliance
        );
        assert!(
            es.mean_accuracy > fs.mean_accuracy + 0.01,
            "elastico {} vs fast {}",
            es.mean_accuracy,
            fs.mean_accuracy
        );
        assert!(es.switches >= 2, "should adapt during the spike");
    }

    /// Exact record equality (RequestRecord carries f64 times).
    fn records_identical(a: &[RequestRecord], b: &[RequestRecord]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.id == y.id
                    && x.arrival_ms == y.arrival_ms
                    && x.start_ms == y.start_ms
                    && x.finish_ms == y.finish_ms
                    && x.config_idx == y.config_idx
            })
    }

    #[test]
    fn k1_reproduces_single_server_simulate_exactly() {
        // simulate() must stay bit-for-bit the M/G/1 simulator: same
        // seed, same arrivals -> identical records through simulate_k(1).
        let plan = plan2();
        let arr = arrivals(12.0, 90.0);
        let svc = LognormalService::from_plan(&plan, 0.25);

        let mut p1 = ElasticoPolicy::new(plan.clone());
        let a = simulate(&arr, &plan, &mut p1, &svc, 42);
        let mut p2 = ElasticoPolicy::new(plan.clone());
        let b = simulate_k(&arr, &plan, &mut p2, &svc, 42, 1);

        assert!(records_identical(&a.records, &b.records));
        assert_eq!(a.switches.len(), b.switches.len());
    }

    #[test]
    fn sharded_single_shard_reproduces_central_fifo_exactly() {
        // The acceptance parity: ShardedSteal with one shard must be the
        // central FIFO record-for-record (same policy decisions, same
        // rng consumption, same timestamps) at k = 1.
        let plan = plan2();
        let arr = arrivals(12.0, 90.0);
        let svc = LognormalService::from_plan(&plan, 0.25);

        let mut pc = ElasticoPolicy::new(plan.clone());
        let central = simulate_disc(
            &arr,
            &plan,
            &mut pc,
            &svc,
            42,
            1,
            Discipline::CentralFifo,
            0,
            1,
        );
        let mut ps = ElasticoPolicy::new(plan.clone());
        let sharded = simulate_disc(
            &arr,
            &plan,
            &mut ps,
            &svc,
            42,
            1,
            Discipline::ShardedSteal,
            1,
            1,
        );

        assert!(records_identical(&central.records, &sharded.records));
        assert_eq!(central.switches.len(), sharded.switches.len());
        assert_eq!(sharded.steals, 0, "one shard can never steal");
    }

    #[test]
    fn k_servers_shrink_the_makespan() {
        // Deterministic overload: 100 arrivals, 40 ms service. One
        // server needs ~4000 ms; four servers ~1000 ms.
        let plan = plan2();
        let arr: Vec<f64> = (0..100).map(|i| i as f64 * 0.001).collect();
        let svc = DeterministicService { means: vec![40.0, 40.0] };

        let makespan = |k: usize, d: Discipline| {
            let mut pol = StaticPolicy::new(0, "fast");
            let out = simulate_disc(&arr, &plan, &mut pol, &svc, 1, k, d, 0, 1);
            out.records
                .iter()
                .map(|r| r.finish_ms)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let m1 = makespan(1, Discipline::CentralFifo);
        let m4 = makespan(4, Discipline::CentralFifo);
        assert!(m1 / m4 >= 3.9, "makespan k=1 {m1:.0} vs k=4 {m4:.0}");
        // The sharded discipline keeps the same pool speedup: with equal
        // service times the steal sweep keeps every server busy.
        let s4 = makespan(4, Discipline::ShardedSteal);
        assert!(m1 / s4 >= 3.9, "sharded makespan k=4 {s4:.0} vs k=1 {m1:.0}");
    }

    #[test]
    fn never_more_than_k_in_service() {
        let plan = plan2();
        let arr = arrivals(40.0, 30.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        for disc in [Discipline::CentralFifo, Discipline::ShardedSteal] {
            for k in [1usize, 2, 3] {
                let mut pol = StaticPolicy::new(1, "accurate");
                let out =
                    simulate_disc(&arr, &plan, &mut pol, &svc, 7, k, disc, 0, 1);
                assert_eq!(out.records.len(), arr.len());
                // Sweep service intervals: concurrency never exceeds k.
                let mut events: Vec<(f64, i32)> = Vec::new();
                for r in &out.records {
                    events.push((r.start_ms, 1));
                    events.push((r.finish_ms, -1));
                }
                events.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                });
                let mut in_service = 0;
                for (_, d) in events {
                    in_service += d;
                    assert!(
                        in_service <= k as i32,
                        "concurrency {in_service} > k {k} ({disc:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_conserves_requests_and_steals_under_skew() {
        // More shards than workers: shards beyond the home set are only
        // reachable by stealing, so a full drain forces steals and every
        // request must still be served exactly once.
        let plan = plan2();
        let arr: Vec<f64> = (0..120).map(|i| i as f64 * 0.001).collect();
        let svc = DeterministicService { means: vec![10.0, 10.0] };
        let mut pol = StaticPolicy::new(0, "fast");
        let out = simulate_disc(
            &arr,
            &plan,
            &mut pol,
            &svc,
            3,
            2,
            Discipline::ShardedSteal,
            6,
            1,
        );
        assert_eq!(out.records.len(), arr.len());
        let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..arr.len() as u64).collect::<Vec<u64>>());
        // 4 of 6 shards are steal-only for workers {0, 1}: at least the
        // 80 requests routed there must arrive via steals.
        assert!(out.steals >= 80, "steals {} < 80", out.steals);
    }

    #[test]
    fn sharded_per_shard_order_is_fifo() {
        // Within one shard (id ≡ r mod shards) starts follow arrival
        // order even though global order may interleave.
        let plan = plan2();
        let arr = arrivals(30.0, 30.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let mut pol = StaticPolicy::new(0, "fast");
        let shards = 4usize;
        let out = simulate_disc(
            &arr,
            &plan,
            &mut pol,
            &svc,
            11,
            4,
            Discipline::ShardedSteal,
            shards,
            1,
        );
        for s in 0..shards as u64 {
            let mut rs: Vec<_> = out
                .records
                .iter()
                .filter(|r| r.id % shards as u64 == s)
                .collect();
            rs.sort_by(|a, b| {
                a.start_ms
                    .partial_cmp(&b.start_ms)
                    .unwrap()
                    .then(a.id.cmp(&b.id))
            });
            for w in rs.windows(2) {
                assert!(
                    w[1].id > w[0].id,
                    "shard {s} served {} before {}",
                    w[1].id,
                    w[0].id
                );
            }
        }
    }

    #[test]
    fn batch_one_reproduces_the_seed_simulator_exactly() {
        // B = 1 through the batched dispatch path must be bit-for-bit
        // the unbatched simulator (same rng consumption, same
        // timestamps), in both disciplines, even with α set.
        let mut plan = plan2();
        plan.batch_alpha_ms = 5.0; // must be inert at B = 1
        let arr = arrivals(12.0, 90.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        for disc in [Discipline::CentralFifo, Discipline::ShardedSteal] {
            let mut p1 = ElasticoPolicy::new(plan.clone());
            let a = simulate_disc(&arr, &plan, &mut p1, &svc, 42, 2, disc, 0, 1);
            let mut p2 = ElasticoPolicy::new(plan.clone());
            let b = simulate_disc(&arr, &plan, &mut p2, &svc, 42, 2, disc, 0, 0);
            assert!(records_identical(&a.records, &b.records), "{disc:?}");
            assert_eq!(a.switches.len(), b.switches.len());
        }
    }

    #[test]
    fn batched_dispatch_conserves_and_keeps_fifo_per_shard() {
        let plan = plan2();
        let arr = arrivals(30.0, 30.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let shards = 4usize;
        let mut pol = StaticPolicy::new(0, "fast");
        let out = simulate_disc(
            &arr,
            &plan,
            &mut pol,
            &svc,
            11,
            4,
            Discipline::ShardedSteal,
            shards,
            8,
        );
        // Conservation: every arrival served exactly once.
        assert_eq!(out.records.len(), arr.len());
        let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..arr.len() as u64).collect::<Vec<u64>>());
        // FIFO within each shard (batches are front runs, steals take
        // the victim's front half — order never inverts).
        for s in 0..shards as u64 {
            let mut rs: Vec<_> = out
                .records
                .iter()
                .filter(|r| r.id % shards as u64 == s)
                .collect();
            rs.sort_by(|a, b| {
                a.start_ms
                    .partial_cmp(&b.start_ms)
                    .unwrap()
                    .then(a.id.cmp(&b.id))
            });
            for w in rs.windows(2) {
                assert!(w[1].id > w[0].id, "shard {s} out of order");
            }
        }
        // Batches share their bounds and respect the bound B = 8.
        let mut sizes: std::collections::HashMap<(u64, u64), usize> =
            std::collections::HashMap::new();
        for r in &out.records {
            *sizes
                .entry((r.start_ms.to_bits(), r.finish_ms.to_bits()))
                .or_default() += 1;
        }
        assert!(sizes.values().all(|&n| n <= 8), "batch bound violated");
    }

    #[test]
    fn pooled_single_uniform_pool_reproduces_sharded_des_exactly() {
        // The tentpole parity pin: one homogeneous pool (speed 1, offset
        // 0) must be the existing sharded k-worker path record-for-record
        // — same rng consumption, timestamps, switches and steal counts —
        // at several pool sizes and batch bounds, driving a switching
        // policy so routing reads the live rung.
        let plan = plan2();
        let arr = arrivals(12.0, 90.0);
        let svc = LognormalService::from_plan(&plan, 0.25);
        for k in [1usize, 4] {
            for batch in [1usize, 8] {
                let mut pd = ElasticoPolicy::new(plan.clone());
                let disc = simulate_disc(
                    &arr,
                    &plan,
                    &mut pd,
                    &svc,
                    42,
                    k,
                    Discipline::ShardedSteal,
                    0,
                    batch,
                );
                let mut pp = ElasticoPolicy::new(plan.clone());
                let pooled = simulate_pools(
                    &arr,
                    &plan,
                    &mut pp,
                    &svc,
                    42,
                    &[crate::serving::pool::PoolSpec::uniform(k)],
                    batch,
                );
                assert!(
                    records_identical(&disc.records, &pooled.records),
                    "k={k} B={batch}"
                );
                assert_eq!(disc.switches.len(), pooled.switches.len());
                assert_eq!(disc.steals, pooled.steals, "k={k} B={batch}");
                assert_eq!(pooled.spills, 0, "one pool can never spill");
            }
        }
    }

    #[test]
    fn pooled_heterogeneous_conserves_and_spills_only_off_band() {
        // fast:2 owns rung 0, accurate:2 (2x slower) owns rung 1+. A
        // static rung-0 policy routes everything to the fast pool, so
        // the accurate pool can only work via spill — every request is
        // still served exactly once and spills must appear. Requests
        // spilled into the accurate pool execute at *its* band rung.
        let plan = plan2();
        let pools = crate::serving::pool::parse_pools("fast:2:1.0,accurate:2:2.0").unwrap();
        let arr: Vec<f64> = (0..200).map(|i| i as f64 * 0.001).collect();
        let svc = DeterministicService { means: vec![10.0, 10.0] };
        let mut pol = StaticPolicy::new(0, "fast");
        let out = simulate_pools(&arr, &plan, &mut pol, &svc, 3, &pools, 1);
        assert_eq!(out.records.len(), arr.len());
        let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..arr.len() as u64).collect::<Vec<u64>>());
        assert!(out.spills > 0, "idle accurate pool must spill");
        // Spilled requests ran at the accurate pool's band (rung 1) and
        // routed requests at the policy rung (rung 0) — both appear.
        let accurate = out.records.iter().filter(|r| r.config_idx == 1).count();
        let fast = out.records.iter().filter(|r| r.config_idx == 0).count();
        assert!(accurate > 0 && fast > 0, "fast {fast} accurate {accurate}");
        assert_eq!(accurate as u64, {
            // Every spill dispatch at B=1 takes exactly one request.
            out.spills
        });
    }

    #[test]
    fn pooled_routing_follows_the_policy_rung_across_bands() {
        // Elastico under a spike: when the controller upscales from the
        // accurate band to the fast band, new load must land on the fast
        // pool (and vice versa under low load) — both pools end up
        // serving, and per-shard FIFO holds within every pool.
        let plan = plan2();
        let pools = crate::serving::pool::parse_pools("fast:2:1.0,accurate:2:1.5").unwrap();
        let spec = crate::workload::WorkloadSpec {
            base_qps: 10.0,
            duration_s: 120.0,
            pattern: crate::workload::Pattern::paper_spike(),
            seed: 9,
        };
        let arr = crate::workload::generate_arrivals(&spec);
        let svc = LognormalService::from_plan(&plan, 0.25);
        let mut ela = ElasticoPolicy::new(plan.clone());
        let out = simulate_pools(&arr, &plan, &mut ela, &svc, 3, &pools, 1);
        assert_eq!(out.records.len(), arr.len());
        assert!(out.switches.len() >= 2, "spike should force rung switches");
        let fast = out.records.iter().filter(|r| r.config_idx == 0).count();
        let slow = out.records.iter().filter(|r| r.config_idx >= 1).count();
        assert!(
            fast > 0 && slow > 0,
            "switching must move load between pools (fast {fast}, slow {slow})"
        );
    }

    #[test]
    fn batching_amortizes_dispatch_when_alpha_dominates() {
        // Deterministic 10 ms service of which α = 8 ms is dispatch:
        // a B=8 batch costs 8 + 8·2 = 24 ms for 8 requests vs 80 ms
        // serially, so the makespan of a 160-deep backlog shrinks ~3x.
        let mut plan = plan2();
        plan.batch_alpha_ms = 8.0;
        let arr: Vec<f64> = (0..160).map(|i| i as f64 * 1e-4).collect();
        let svc = DeterministicService { means: vec![10.0, 10.0] };
        let makespan = |batch: usize| {
            let mut pol = StaticPolicy::new(0, "fast");
            let out = simulate_disc(
                &arr,
                &plan,
                &mut pol,
                &svc,
                1,
                1,
                Discipline::CentralFifo,
                0,
                batch,
            );
            assert_eq!(out.records.len(), arr.len());
            out.records
                .iter()
                .map(|r| r.finish_ms)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let m1 = makespan(1);
        let m8 = makespan(8);
        assert!(
            m1 / m8 >= 2.5,
            "B=8 should amortize dispatch: B=1 {m1:.0} ms vs B=8 {m8:.0} ms"
        );
    }

    #[test]
    fn zero_alpha_batching_trades_latency_for_nothing() {
        // With α = 0 a batch of n costs exactly n marginals: throughput
        // (makespan) is unchanged, but early requests now wait for their
        // whole batch — mean latency strictly worse. This is the "when
        // batching hurts" half of the model, validated against theory.
        let plan = plan2(); // batch_alpha_ms = 0 via derive_plan default
        assert_eq!(plan.batch_alpha_ms, 0.0);
        let arr: Vec<f64> = (0..120).map(|i| i as f64 * 1e-4).collect();
        let svc = DeterministicService { means: vec![10.0, 10.0] };
        let run = |batch: usize| {
            let mut pol = StaticPolicy::new(0, "fast");
            simulate_disc(
                &arr,
                &plan,
                &mut pol,
                &svc,
                1,
                1,
                Discipline::CentralFifo,
                0,
                batch,
            )
        };
        let b1 = run(1);
        let b8 = run(8);
        let makespan = |o: &SimOutcome| {
            o.records
                .iter()
                .map(|r| r.finish_ms)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let mean_latency = |o: &SimOutcome| {
            o.records.iter().map(|r| r.latency_ms()).sum::<f64>() / o.records.len() as f64
        };
        assert!(
            (makespan(&b1) - makespan(&b8)).abs() < 1e-6,
            "α=0 batching must not change throughput"
        );
        assert!(
            mean_latency(&b8) > mean_latency(&b1) + 1.0,
            "α=0 batching must inflate mean latency: B=1 {:.1} vs B=8 {:.1}",
            mean_latency(&b1),
            mean_latency(&b8)
        );
    }
}
