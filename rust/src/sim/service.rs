//! Service-time models for the simulator.
//!
//! The Planner's profiles give (mean, p95) per configuration; a lognormal
//! is fitted to both moments — the standard heavy-tail model for LLM
//! serving times (latency varies with input/output length, §III-A).

use crate::planner::Plan;
use crate::util::Rng;

/// Samples per-request service times (ms) for a ladder index.
pub trait ServiceModel {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64;

    /// Mean service time of a rung (for utilization math).
    fn mean_ms(&self, idx: usize) -> f64;
}

/// Lognormal fitted to (mean, p95) per rung.
#[derive(Clone, Debug)]
pub struct LognormalService {
    /// Per-rung (mu, sigma) in log-space.
    params: Vec<(f64, f64)>,
    means: Vec<f64>,
}

/// Solve lognormal (mu, sigma) matching a mean and a p95.
///
/// mean = exp(mu + sigma^2/2), p95 = exp(mu + z95 * sigma) with
/// z95 = 1.6449. Substituting gives a quadratic in sigma; the smaller
/// root is taken (the larger one puts most mass at ~0, which is not a
/// service-time shape). Falls back to near-deterministic when p95 is not
/// meaningfully above the mean.
pub fn fit_lognormal(mean: f64, p95: f64) -> (f64, f64) {
    assert!(mean > 0.0);
    let z = 1.6449;
    let ratio = (p95 / mean).max(1.0 + 1e-9);
    // sigma^2/2 - z*sigma + ln(p95/mean) = 0.
    let disc = z * z - 2.0 * ratio.ln();
    let sigma = if disc <= 0.0 {
        z // cap: extremely heavy tail
    } else {
        (z - disc.sqrt()).max(1e-6)
    };
    let mu = mean.ln() - sigma * sigma / 2.0;
    (mu, sigma)
}

impl LognormalService {
    /// Fit per-rung models from a plan; `min_cv` lower-bounds the
    /// coefficient of variation (keeps M/G/1 behavior realistic even for
    /// rungs profiled with nearly deterministic latency).
    pub fn from_plan(plan: &Plan, min_cv: f64) -> LognormalService {
        let params = plan
            .ladder
            .iter()
            .map(|p| {
                let sigma_floor = (min_cv * min_cv + 1.0_f64).ln().sqrt();
                let (_mu, sigma) = fit_lognormal(p.mean_ms, p.p95_ms);
                let sigma = sigma.max(sigma_floor);
                let mu = p.mean_ms.ln() - sigma * sigma / 2.0;
                (mu, sigma)
            })
            .collect();
        LognormalService {
            params,
            means: plan.ladder.iter().map(|p| p.mean_ms).collect(),
        }
    }
}

impl ServiceModel for LognormalService {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64 {
        let (mu, sigma) = self.params[idx];
        (mu + sigma * rng.normal()).exp()
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

/// Exponential (memoryless) service — the M/M/k reference model the
/// Erlang-C formulas are exact for; used by the DES-vs-theory validation
/// suite (`tests/theory_validation.rs`). Memorylessness also makes the
/// occupancy process insensitive to the dispatch discipline (central,
/// sharded-steal, pooled), which is what lets one theory target validate
/// every queue walk.
#[derive(Clone, Debug)]
pub struct ExponentialService {
    /// Per-rung mean service time (ms).
    pub means: Vec<f64>,
}

impl ServiceModel for ExponentialService {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64 {
        rng.exponential(1.0 / self.means[idx])
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

/// Pareto (power-law) service — the heavy-tailed model for scenario
/// sweeps. With shape `alpha` and per-rung scale `x_m` chosen so the
/// mean matches the plan's profile (`mean = x_m·alpha/(alpha-1)`), the
/// squared coefficient of variation is `1/(alpha·(alpha-2))`, which for
/// `alpha` just above 2 is far heavier than any lognormal fit: a small
/// fraction of requests take many times the mean, stressing tail SLOs.
#[derive(Clone, Debug)]
pub struct ParetoService {
    /// Tail shape; must be > 2 for finite variance.
    alpha: f64,
    /// Per-rung scale (minimum service time, ms).
    x_m: Vec<f64>,
    means: Vec<f64>,
}

impl ParetoService {
    /// Per-rung Pareto with the plan's mean service times. `alpha`
    /// close to 2 (e.g. 2.05) gives a very heavy tail (CV ≈ 3).
    pub fn from_plan(plan: &Plan, alpha: f64) -> ParetoService {
        assert!(alpha > 2.0, "alpha must be > 2 for finite variance");
        ParetoService {
            alpha,
            x_m: plan
                .ladder
                .iter()
                .map(|p| p.mean_ms * (alpha - 1.0) / alpha)
                .collect(),
            means: plan.ladder.iter().map(|p| p.mean_ms).collect(),
        }
    }
}

impl ServiceModel for ParetoService {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64 {
        // Inverse-CDF: x = x_m · u^(-1/alpha), u uniform on (0, 1].
        let u = 1.0 - rng.uniform();
        self.x_m[idx] * u.powf(-1.0 / self.alpha)
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

/// Deterministic service (tests / M/D/1 analyses).
#[derive(Clone, Debug)]
pub struct DeterministicService {
    pub means: Vec<f64>,
}

impl ServiceModel for DeterministicService {
    fn sample_ms(&self, idx: usize, _rng: &mut Rng) -> f64 {
        self.means[idx]
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_matches_moments() {
        let (mu, sigma) = fit_lognormal(100.0, 180.0);
        // Monte-Carlo check of both moments.
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mut xs: Vec<f64> = (0..n)
            .map(|_| (mu + sigma * rng.normal()).exp())
            .collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = xs[(0.95 * n as f64) as usize];
        assert!((mean - 100.0).abs() < 2.0, "mean {mean}");
        assert!((p95 - 180.0).abs() < 5.0, "p95 {p95}");
    }

    #[test]
    fn fit_handles_tight_tail() {
        let (mu, sigma) = fit_lognormal(50.0, 50.0);
        assert!(sigma < 0.01);
        assert!((mu.exp() - 50.0).abs() < 1.0);
    }

    #[test]
    fn deterministic_is_deterministic() {
        let d = DeterministicService { means: vec![10.0, 20.0] };
        let mut rng = Rng::new(0);
        assert_eq!(d.sample_ms(1, &mut rng), 20.0);
        assert_eq!(d.mean_ms(0), 10.0);
    }

    #[test]
    fn pareto_matches_mean_and_is_heavier_than_exponential() {
        let alpha = 2.05;
        let mean = 10.0;
        let p = ParetoService {
            alpha,
            x_m: vec![mean * (alpha - 1.0) / alpha],
            means: vec![mean],
        };
        let e = ExponentialService { means: vec![mean] };
        let mut rng = Rng::new(11);
        let n = 400_000;
        let cv2 = |svc: &dyn ServiceModel, rng: &mut Rng| {
            let (mut sum, mut sq, mut max) = (0.0, 0.0, 0.0_f64);
            for _ in 0..n {
                let s = svc.sample_ms(0, rng);
                assert!(s > 0.0);
                sum += s;
                sq += s * s;
                max = max.max(s);
            }
            let m = sum / n as f64;
            (m, sq / n as f64 / (m * m) - 1.0, max)
        };
        let (p_mean, p_cv2, p_max) = cv2(&p, &mut rng);
        let (_, e_cv2, e_max) = cv2(&e, &mut rng);
        assert!((p_mean - mean).abs() / mean < 0.15, "mean {p_mean}");
        assert_eq!(p.mean_ms(0), mean);
        // Heavy tail: the Pareto run must be burstier than the
        // memoryless reference, with a far larger extreme sample.
        assert!(p_cv2 > e_cv2 + 0.3, "pareto cv² {p_cv2} vs exp {e_cv2}");
        assert!(p_max > 2.0 * e_max, "pareto max {p_max} vs exp {e_max}");
        assert!(p_max > 20.0 * mean, "pareto max {p_max}");
    }

    #[test]
    fn exponential_matches_mean_and_cv() {
        let e = ExponentialService { means: vec![10.0] };
        let mut rng = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let s = e.sample_ms(0, &mut rng);
            assert!(s >= 0.0);
            sum += s;
            sq += s * s;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 10.0).abs() < 0.1, "mean {mean}");
        // Exponential: cv = 1 (variance = mean²).
        assert!((var / (mean * mean) - 1.0).abs() < 0.03, "cv² {}", var / (mean * mean));
        assert_eq!(e.mean_ms(0), 10.0);
    }
}
