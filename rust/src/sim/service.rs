//! Service-time models for the simulator.
//!
//! The Planner's profiles give (mean, p95) per configuration; a lognormal
//! is fitted to both moments — the standard heavy-tail model for LLM
//! serving times (latency varies with input/output length, §III-A).

use crate::planner::Plan;
use crate::util::Rng;

/// Samples per-request service times (ms) for a ladder index.
pub trait ServiceModel {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64;

    /// Mean service time of a rung (for utilization math).
    fn mean_ms(&self, idx: usize) -> f64;
}

/// Lognormal fitted to (mean, p95) per rung.
#[derive(Clone, Debug)]
pub struct LognormalService {
    /// Per-rung (mu, sigma) in log-space.
    params: Vec<(f64, f64)>,
    means: Vec<f64>,
}

/// Solve lognormal (mu, sigma) matching a mean and a p95.
///
/// mean = exp(mu + sigma^2/2), p95 = exp(mu + z95 * sigma) with
/// z95 = 1.6449. Substituting gives a quadratic in sigma; the smaller
/// root is taken (the larger one puts most mass at ~0, which is not a
/// service-time shape). Falls back to near-deterministic when p95 is not
/// meaningfully above the mean.
pub fn fit_lognormal(mean: f64, p95: f64) -> (f64, f64) {
    assert!(mean > 0.0);
    let z = 1.6449;
    let ratio = (p95 / mean).max(1.0 + 1e-9);
    // sigma^2/2 - z*sigma + ln(p95/mean) = 0.
    let disc = z * z - 2.0 * ratio.ln();
    let sigma = if disc <= 0.0 {
        z // cap: extremely heavy tail
    } else {
        (z - disc.sqrt()).max(1e-6)
    };
    let mu = mean.ln() - sigma * sigma / 2.0;
    (mu, sigma)
}

impl LognormalService {
    /// Fit per-rung models from a plan; `min_cv` lower-bounds the
    /// coefficient of variation (keeps M/G/1 behavior realistic even for
    /// rungs profiled with nearly deterministic latency).
    pub fn from_plan(plan: &Plan, min_cv: f64) -> LognormalService {
        let params = plan
            .ladder
            .iter()
            .map(|p| {
                let sigma_floor = (min_cv * min_cv + 1.0_f64).ln().sqrt();
                let (_mu, sigma) = fit_lognormal(p.mean_ms, p.p95_ms);
                let sigma = sigma.max(sigma_floor);
                let mu = p.mean_ms.ln() - sigma * sigma / 2.0;
                (mu, sigma)
            })
            .collect();
        LognormalService {
            params,
            means: plan.ladder.iter().map(|p| p.mean_ms).collect(),
        }
    }
}

impl ServiceModel for LognormalService {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64 {
        let (mu, sigma) = self.params[idx];
        (mu + sigma * rng.normal()).exp()
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

/// Exponential (memoryless) service — the M/M/k reference model the
/// Erlang-C formulas are exact for; used by the DES-vs-theory validation
/// suite (`tests/theory_validation.rs`). Memorylessness also makes the
/// occupancy process insensitive to the dispatch discipline (central,
/// sharded-steal, pooled), which is what lets one theory target validate
/// every queue walk.
#[derive(Clone, Debug)]
pub struct ExponentialService {
    /// Per-rung mean service time (ms).
    pub means: Vec<f64>,
}

impl ServiceModel for ExponentialService {
    fn sample_ms(&self, idx: usize, rng: &mut Rng) -> f64 {
        rng.exponential(1.0 / self.means[idx])
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

/// Deterministic service (tests / M/D/1 analyses).
#[derive(Clone, Debug)]
pub struct DeterministicService {
    pub means: Vec<f64>,
}

impl ServiceModel for DeterministicService {
    fn sample_ms(&self, idx: usize, _rng: &mut Rng) -> f64 {
        self.means[idx]
    }

    fn mean_ms(&self, idx: usize) -> f64 {
        self.means[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_matches_moments() {
        let (mu, sigma) = fit_lognormal(100.0, 180.0);
        // Monte-Carlo check of both moments.
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mut xs: Vec<f64> = (0..n)
            .map(|_| (mu + sigma * rng.normal()).exp())
            .collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = xs[(0.95 * n as f64) as usize];
        assert!((mean - 100.0).abs() < 2.0, "mean {mean}");
        assert!((p95 - 180.0).abs() < 5.0, "p95 {p95}");
    }

    #[test]
    fn fit_handles_tight_tail() {
        let (mu, sigma) = fit_lognormal(50.0, 50.0);
        assert!(sigma < 0.01);
        assert!((mu.exp() - 50.0).abs() < 1.0);
    }

    #[test]
    fn deterministic_is_deterministic() {
        let d = DeterministicService { means: vec![10.0, 20.0] };
        let mut rng = Rng::new(0);
        assert_eq!(d.sample_ms(1, &mut rng), 20.0);
        assert_eq!(d.mean_ms(0), 10.0);
    }

    #[test]
    fn exponential_matches_mean_and_cv() {
        let e = ExponentialService { means: vec![10.0] };
        let mut rng = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let s = e.sample_ms(0, &mut rng);
            assert!(s >= 0.0);
            sum += s;
            sq += s * s;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 10.0).abs() < 0.1, "mean {mean}");
        // Exponential: cv = 1 (variance = mean²).
        assert!((var / (mean * mean) - 1.0).abs() < 0.03, "cv² {}", var / (mean * mean));
        assert_eq!(e.mean_ms(0), 10.0);
    }
}
