//! The one discrete-event serving engine, parameterized by a dispatch
//! [`Topology`].
//!
//! Every public simulator entry point (`simulate`, `simulate_k`,
//! `simulate_disc`, `simulate_pools` in [`crate::sim`]) is a thin shim
//! that builds the matching topology and calls
//! [`simulate_topology`] — so `CentralFifo == ShardedSteal(shards = 1)
//! == simulate_pools(one uniform pool)` holds **by construction**: they
//! are literally the same event loop over the same decision core, not
//! three loops pinned equal by tests. The historical parity tests in
//! `sim::tests` survive unmodified as regression pins on the shims.
//!
//! The engine owns only simulation mechanics — the event clock, the
//! rng, the per-shard `VecDeque`s and the busy-until times. Every
//! *choice* (routing, walk order, spill admission, batch extent,
//! execution rung, service-time scale) is the topology's, shared
//! verbatim with the live [`crate::serving::queue::ShardedQueue`].
//!
//! ## Event loop
//!
//! Arrivals route to the pool whose rung band holds the current policy
//! rung (per-pool round-robin); the earliest-free server dispatches a
//! front run of up to B from its home shard, a steal-half from a pool
//! sibling, or — once its pool is dry and the victim passes the spill
//! gate — a spill-half from another pool. Under a positive spill margin
//! the earliest-free server may be *gated*; the engine then falls back
//! to the next-free server in `(free time, index)` order, and only
//! admits the next arrival when no free server may dispatch (at margin
//! 0 the gate admits any non-empty victim, so the fallback never runs
//! and the loop is event-for-event the historical simulators). The
//! policy observes the per-pool depth of the current rung's home pool
//! at every arrival (plus that pool's in-service count), dispatch and
//! departure — on a single pool exactly the aggregate-depth signal of
//! the seed simulator.
//!
//! Batch service follows `s̄(B) = α + β·B`: a batch of n sampled times
//! costs `Σ sᵢ·speed − (n−1)·α` (α clamped into `[0, s̄(1)·speed]` of
//! the executing pool's rung), all n requests share the batch bounds,
//! and B = 1 degenerates to the seed expressions bit-for-bit.

use crate::metrics::{RequestRecord, SwitchEvent};
use crate::planner::Plan;
use crate::serving::monitor::LoadMonitor;
use crate::serving::overload::{Brownout, OverloadConfig};
use crate::serving::policy::ScalingPolicy;
use crate::serving::replan::{ReplanConfig, ReplanEngine};
use crate::serving::resilience::{HealthView, ResilienceConfig};
use crate::serving::topology::{Dispatch, Topology};
use crate::util::Rng;
use crate::workload::FaultPlan;

use super::{ServiceModel, SimOutcome};

/// One simulated queued request: (id, arrival ms, ready ms, attempt).
/// Fresh arrivals carry `ready == arrival`; a retried request re-enters
/// with `ready = fail time + backoff` so it cannot start before its
/// backoff elapses, while records keep the original arrival.
type Item = (u64, f64, f64, u32);

/// Resilience counters accumulated by one simulated run.
#[derive(Default)]
struct ResCounters {
    failed: usize,
    retries: u64,
    timeouts: u64,
    failovers: u64,
}

/// The DES side of [`retry_or_fail`](crate::serving::server): a failed
/// request re-enqueues through health-aware routing with backoff when
/// the retry policy admits it, else counts terminally failed — the same
/// decisions ([`HealthView::try_retry`], `pool_for_rung_routable`) the
/// live worker takes, driven by the virtual clock.
#[allow(clippy::too_many_arguments)]
fn retry_or_fail_sim(
    topo: &Topology,
    faults: &FaultPlan,
    cfg: &ResilienceConfig,
    hv: &mut HealthView,
    queues: &mut [std::collections::VecDeque<Item>],
    routers: &mut [usize],
    pool_queued: &mut [usize],
    queued_total: &mut usize,
    rung: usize,
    item: Item,
    now_ms: f64,
    counters: &mut ResCounters,
) {
    let (id, arr_ms, _ready, attempt) = item;
    let next = attempt + 1;
    if !(cfg.enabled && hv.try_retry(next, now_ms)) {
        counters.failed += 1;
        return;
    }
    let ready = now_ms + cfg.backoff_ms(next);
    let (pool, moved) = topo.pool_for_rung_routable(rung, |q| hv.routable(q, ready, faults));
    let shard = topo.route(pool, routers[pool]);
    routers[pool] += 1;
    queues[shard].push_back((id, arr_ms, ready, next));
    *queued_total += 1;
    pool_queued[pool] += 1;
    counters.retries += 1;
    if moved {
        counters.failovers += 1;
    }
}

/// The first shard a consumer of `pool` may take from, given the
/// current queue state: the topology's within-pool walk, then the gated
/// cross-pool spill sweep — exactly the live
/// `ShardedQueue::try_pop_batch_pool` order. `margin` is the effective
/// spill margin (the topology's static one, unless the re-planner
/// raised it).
fn choose_shard(
    topo: &Topology,
    queues: &[std::collections::VecDeque<Item>],
    pool_queued: &[usize],
    pool: usize,
    worker: usize,
    margin: f64,
) -> Option<(usize, Dispatch)> {
    for (s, kind) in topo.pool_walk(pool, worker) {
        if !queues[s].is_empty() {
            return Some((s, kind));
        }
    }
    for q in topo.spill_order(pool) {
        if !topo.spill_allowed_with(pool, q, pool_queued[q], margin) {
            continue;
        }
        let (lo, hi) = topo.shard_range(q);
        for s in lo..hi {
            if !queues[s].is_empty() {
                return Some((s, Dispatch::Spill));
            }
        }
    }
    None
}

/// Simulate serving `arrivals` (seconds) under `policy` on the fleet
/// described by `topo`, dispatching up to `batch` requests per engine
/// call — the single event loop behind every `simulate*` entry point.
pub fn simulate_topology<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    topo: &Topology,
    batch: usize,
) -> SimOutcome {
    let faults = FaultPlan::none();
    simulate_topology_faults(arrivals, plan, policy, service, seed, topo, batch, &faults)
}

/// [`simulate_topology`] with a [`FaultPlan`] injected — the DES side of
/// failure injection, mirroring the live executor fault-for-fault:
///
/// * **pool dark** — the pool's server slots retire (busy-until = ∞) at
///   their first dispatch opportunity at or past the dark time; in-
///   flight work completes, and backlog no live server may reach (the
///   spill gate still applies) is counted `rejected`. A *windowed* dark
///   (`until_s` set) pauses the slots until the window ends instead of
///   retiring them;
/// * **slowdown** — the executing pool's service times stretch by the
///   fault factor active at batch start;
/// * **queue squeeze** — arrivals finding `queued_total` at or above
///   the active admission bound are rejected without being observed.
///
/// With the empty plan every guard is inert and the event sequence (and
/// rng stream) is bit-identical to [`simulate_topology`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_topology_faults<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    topo: &Topology,
    batch: usize,
    faults: &FaultPlan,
) -> SimOutcome {
    let resilience = ResilienceConfig::default();
    simulate_topology_resilient(
        arrivals,
        plan,
        policy,
        service,
        seed,
        topo,
        batch,
        faults,
        &resilience,
    )
}

/// [`simulate_topology_faults`] with the resilience plane active — the
/// DES mirror of the live runtime's failure handling, driving the same
/// pure decision machines ([`HealthView`], `Topology::failover_pool`)
/// with the virtual clock:
///
/// * **health-aware routing** — an arrival (or retry) whose rung band's
///   home pool is dark or breaker-open remaps to the nearest surviving
///   pool, and remaps back the instant the pool recovers;
/// * **dark windows** — a windowed dark pool's slots pause
///   (busy-until = window end) instead of retiring; with resilience on,
///   the first slot to notice the window also redistributes the pool's
///   stranded backlog to the failover target (counted `failovers`);
/// * **injected flakes** — each request flips the same deterministic
///   (id, attempt) coin as the live worker *before* service is sampled
///   (a flaked request consumes no engine time), then retries or fails;
/// * **retries** — bounded by the per-request cap and the token-bucket
///   budget, delayed by exponential backoff (`ready = fail + backoff`),
///   re-routed through the health view; **timeouts** discard too-slow
///   batches; per-completion breaker records trip and half-open pools
///   exactly as the live `HealthView` does.
///
/// With the disabled config this is bit-identical to
/// [`simulate_topology_faults`] (which now delegates here) — every
/// resilience branch is gated, so the event sequence and rng stream are
/// unchanged; the parity pins in `tests/resilience.rs` hold it to that.
#[allow(clippy::too_many_arguments)]
pub fn simulate_topology_resilient<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    topo: &Topology,
    batch: usize,
    faults: &FaultPlan,
    resilience: &ResilienceConfig,
) -> SimOutcome {
    let overload = OverloadConfig::default();
    simulate_topology_overload(
        arrivals, plan, policy, service, seed, topo, batch, faults, resilience, &overload,
    )
}

/// [`simulate_topology_resilient`] with the overload plane active — the
/// DES mirror of the live runtime's graceful degradation, driving the
/// same pure decision machines ([`OverloadConfig`], [`Brownout`],
/// `Topology::exec_rung_floor`) with the virtual clock:
///
/// * **SLO classes** — every request id maps deterministically to a
///   class of the configured mix (weight, deadline, rung floor); the
///   arrival stream itself is untouched;
/// * **deadline-aware admission** — an arrival whose class budget the
///   backlog already exceeds is shed at admission (doomed / lowest
///   class first; the tail-drop twin sheds the newest at `shed_depth`).
///   Unlike a squeeze rejection, a shed *consumes the request id*, so
///   DES ids stay aligned with the arrival index — and with the live
///   injector — and class assignment agrees across executors;
/// * **in-queue expiry** — a popped request whose deadline passed
///   before service could start is skipped and counted (lazy expiry:
///   stale work never occupies a server);
/// * **brownout** — the deadline-pressure EWMA over pops steps the
///   effective rung down within `[rung − max_steps, rung]` before
///   shedding bites, and back up on recovery; per-class rung floors are
///   enforced through the same band clamp;
/// * **class-priority service** (`priority=on`, DES-only) — a dispatch
///   takes the highest class queued in its shard, FIFO within a class;
///   off by default so live and DES cells share FIFO semantics.
///
/// Conservation extends to
/// `served + rejected + failed + shed + expired == arrivals`. With the
/// disabled config this is bit-identical to
/// [`simulate_topology_resilient`] (which now delegates here) — every
/// overload branch is gated, so the event sequence and rng stream are
/// unchanged; the parity pins in `tests/overload.rs` hold it to that.
#[allow(clippy::too_many_arguments)]
pub fn simulate_topology_overload<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    topo: &Topology,
    batch: usize,
    faults: &FaultPlan,
    resilience: &ResilienceConfig,
    overload: &OverloadConfig,
) -> SimOutcome {
    let replan = ReplanConfig::default();
    simulate_topology_replan(
        arrivals, plan, policy, service, seed, topo, batch, faults, resilience, overload, &replan,
    )
}

/// [`simulate_topology_overload`] with the online re-planning loop
/// active — the DES mirror of the live adaptation loop
/// ([`crate::serving::replan`]), driving the same pure
/// [`ReplanEngine`] with the virtual clock:
///
/// * a virtual [`LoadMonitor`] ticks at deterministic multiples of the
///   configured cadence (counted over admissions, time-corrected EWMA);
/// * every batch completion feeds `(n, batch_ms)` into the engine's
///   per-(pool, rung) fit windows;
/// * at each evaluation interval the engine re-estimates per-pool
///   speed / α / ρ̂ and may swap a re-derived plan into the policy
///   ([`ScalingPolicy::replace_plan`]), retune the batch bound, and
///   raise the effective spill margin;
/// * a [`crate::workload::fault::Fault::Drift`] window multiplies the
///   executing pool's service times exactly like a slowdown — but
///   persistently, which is the regime change the re-planner adapts to.
///
/// With the disabled config this is bit-identical to
/// [`simulate_topology_overload`] (which now delegates here) — every
/// re-planning branch is gated, so the event sequence and rng stream
/// are unchanged; the parity pins in `tests/replan.rs` hold it to that.
#[allow(clippy::too_many_arguments)]
pub fn simulate_topology_replan<P: ScalingPolicy, S: ServiceModel>(
    arrivals: &[f64],
    plan: &Plan,
    policy: &mut P,
    service: &S,
    seed: u64,
    topo: &Topology,
    batch: usize,
    faults: &FaultPlan,
    resilience: &ResilienceConfig,
    overload: &OverloadConfig,
    replan: &ReplanConfig,
) -> SimOutcome {
    let batch = batch.max(1);
    let alpha = plan.batch_alpha_ms.max(0.0);
    let n_rungs = plan.ladder.len();

    // Server slots in pool order: slot w of pool p has pool-local index
    // `server_local[w]` (its home shard through the topology's walk).
    let mut server_pool: Vec<usize> = Vec::new();
    let mut server_local: Vec<usize> = Vec::new();
    for (p, spec) in topo.pools().iter().enumerate() {
        for local in 0..spec.workers.max(1) {
            server_pool.push(p);
            server_local.push(local);
        }
    }
    let workers = server_pool.len();
    let nsh = topo.n_shards();

    let mut rng = Rng::new(seed);
    let mut records = Vec::with_capacity(arrivals.len());
    let mut switches = Vec::new();
    let mut steals = 0u64;
    let mut spills = 0u64;
    let mut rejected_total = 0usize;
    // Per-pool dark windows (ms); from = ∞ means never dark, until = ∞
    // means the pool never recovers. An open-ended dark retires slots
    // (busy-until = ∞, excluded from every server scan — the historical
    // behavior); a windowed dark just pauses them until the window ends.
    let dark_from: Vec<f64> = (0..topo.n_pools())
        .map(|p| faults.dark_at_ms(p).unwrap_or(f64::INFINITY))
        .collect();
    let dark_to: Vec<f64> = (0..topo.n_pools())
        .map(|p| faults.dark_until_ms(p).unwrap_or(f64::INFINITY))
        .collect();
    let has_flaky = faults.any_flaky();
    let mut hv = HealthView::new(topo.n_pools(), resilience.clone());
    let mut counters = ResCounters::default();
    let mut brown = Brownout::new(overload);
    let mut shed_total = 0usize;
    let mut expired_total = 0usize;
    // Online re-planning state (None/untouched when disabled — the
    // disabled path never ticks a monitor, fits a model, or deviates
    // from the static batch bound and spill margin).
    let mut cur_batch = batch;
    let mut cur_margin = topo.spill_margin();
    let mut replans = 0u64;
    let mut replanner = replan.enabled.then(|| {
        ReplanEngine::new(
            replan.clone(),
            plan.clone(),
            topo.pools().to_vec(),
            batch,
            topo.spill_margin(),
        )
    });
    let lm = replan
        .enabled
        .then(|| LoadMonitor::with_pools_period(0.3, topo.n_pools(), replan.tick_ms));
    let mut next_tick_ms = 0.0f64;

    let mut queues: Vec<std::collections::VecDeque<Item>> =
        (0..nsh).map(|_| std::collections::VecDeque::new()).collect();
    let mut pool_queued = vec![0usize; topo.n_pools()];
    let mut queued_total = 0usize;
    let mut routers = vec![0usize; topo.n_pools()];
    let mut busy: Vec<f64> = vec![f64::NEG_INFINITY; workers];
    let mut observed = policy.current();

    let observe = |policy: &mut P,
                       switches: &mut Vec<SwitchEvent>,
                       observed: &mut usize,
                       now: f64,
                       depth: usize| {
        let next = policy.decide(now, depth);
        if next != *observed {
            switches.push(SwitchEvent { at_ms: now, from_idx: *observed, to_idx: next });
            *observed = next;
        }
        next
    };

    // Advance the virtual monitor/re-planner clock to `now`: tick the
    // rate EWMA at every elapsed cadence boundary (deterministic — the
    // boundaries are fixed multiples of tick_ms, not event times) and
    // apply any evaluation the engine produces. A no-op when the
    // re-planner is disabled.
    let replan_tick = |replanner: &mut Option<ReplanEngine>,
                       policy: &mut P,
                       next_tick_ms: &mut f64,
                       cur_batch: &mut usize,
                       cur_margin: &mut f64,
                       replans: &mut u64,
                       now: f64,
                       depth: usize,
                       rung: usize| {
        let Some(engine) = replanner.as_mut() else { return };
        let lm = lm.as_ref().unwrap();
        while *next_tick_ms <= now {
            let t = *next_tick_ms;
            *next_tick_ms += replan.tick_ms;
            let rate = lm.tick(t);
            if let Some(upd) = engine.step(t, rate, depth, rung) {
                if let Some(new_plan) = upd.plan {
                    if policy.replace_plan(new_plan) {
                        *replans += 1;
                    }
                }
                *cur_batch = upd.batch;
                *cur_margin = upd.spill_margin;
            }
        }
    };

    let mut i = 0usize; // next arrival index
    let n = arrivals.len();
    let mut next_id = 0u64;

    // Event loop: either the next arrival or the earliest server
    // freeing up with work it may take.
    while i < n || queued_total > 0 {
        let next_arrival = if i < n { arrivals[i] * 1000.0 } else { f64::INFINITY };

        // Pick the dispatching server: the earliest-free server (ties
        // broken by lowest index — pool order, reference pools first)
        // when it may take work. Only a positive spill margin can gate
        // it; then try the remaining free servers in (free time, index)
        // order before falling back to the next arrival.
        let mut chosen: Option<(usize, f64, usize, Dispatch)> = None;
        if queued_total > 0 {
            let (slot, earliest) = busy
                .iter()
                .copied()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            if earliest <= next_arrival && earliest < f64::INFINITY {
                let pick = choose_shard(
                    topo,
                    &queues,
                    &pool_queued,
                    server_pool[slot],
                    server_local[slot],
                    cur_margin,
                );
                match pick {
                    Some((shard, kind)) => chosen = Some((slot, earliest, shard, kind)),
                    None => {
                        // Whether a pool's consumer can dispatch is a
                        // property of the *pool* (the walk start varies
                        // per worker, not whether any shard is
                        // non-empty or any victim passes the gate), so
                        // one rejection rules out the whole pool: scan
                        // the remaining free servers in (free time,
                        // index) order, skipping rejected pools.
                        let mut rejected = vec![false; topo.n_pools()];
                        rejected[server_pool[slot]] = true;
                        loop {
                            let mut best: Option<(usize, f64)> = None;
                            for (w, &b) in busy.iter().enumerate() {
                                if rejected[server_pool[w]]
                                    || b > next_arrival
                                    || b == f64::INFINITY
                                {
                                    continue;
                                }
                                let better = match best {
                                    None => true,
                                    Some((_, t)) => b < t,
                                };
                                if better {
                                    best = Some((w, b));
                                }
                            }
                            let (slot2, free2) = match best {
                                Some(x) => x,
                                None => break,
                            };
                            let pick = choose_shard(
                                topo,
                                &queues,
                                &pool_queued,
                                server_pool[slot2],
                                server_local[slot2],
                                cur_margin,
                            );
                            match pick {
                                Some((shard, kind)) => {
                                    chosen = Some((slot2, free2, shard, kind));
                                    break;
                                }
                                None => rejected[server_pool[slot2]] = true,
                            }
                        }
                    }
                }
            }
        }

        if let Some((slot, free_at, shard, kind)) = chosen {
            let p = server_pool[slot];
            replan_tick(
                &mut replanner,
                policy,
                &mut next_tick_ms,
                &mut cur_batch,
                &mut cur_margin,
                &mut replans,
                free_at,
                queued_total,
                observed,
            );
            // A dark pool's slot pauses at its first dispatch
            // opportunity inside the dark window (in-flight work
            // already completed): until the window's end for a windowed
            // dark, forever (retired, excluded from every scan) for the
            // open-ended form — the exact historical behavior.
            let front_arr = queues[shard].front().unwrap().1;
            let would_start = free_at.max(front_arr);
            if would_start >= dark_from[p] && would_start < dark_to[p] {
                if resilience.enabled {
                    // Failover: redistribute the pool's stranded
                    // backlog to the nearest surviving pool (the same
                    // spill-order walk the live dark worker uses)
                    // instead of letting it sit out the window.
                    let (lo, hi) = topo.shard_range(p);
                    for s in lo..hi {
                        while let Some(item) = queues[s].pop_front() {
                            queued_total -= 1;
                            pool_queued[p] -= 1;
                            let target =
                                topo.failover_pool(p, |q| hv.routable(q, would_start, faults));
                            match target {
                                Some(q) => {
                                    let shard2 = topo.route(q, routers[q]);
                                    routers[q] += 1;
                                    queues[shard2].push_back(item);
                                    queued_total += 1;
                                    pool_queued[q] += 1;
                                    counters.failovers += 1;
                                }
                                // No surviving pool: reject, never drop.
                                None => rejected_total += 1,
                            }
                        }
                    }
                }
                busy[slot] = dark_to[p];
                continue;
            }
            // Dispatch to server `slot`: a front run of its home shard,
            // a steal-half from a pool sibling, or a spill-half from
            // the gated victim — one steal/spill operation per batch.
            match kind {
                Dispatch::Home => {}
                Dispatch::Steal => steals += 1,
                Dispatch::Spill => spills += 1,
            }
            let take = Topology::take_count(queues[shard].len(), cur_batch, kind);
            let mut taken: Vec<Item> = Vec::with_capacity(take);
            for _ in 0..take {
                // Class-priority service order (DES-only, off by
                // default): take the highest class still queued in the
                // shard, FIFO within a class.
                let item = if overload.enabled && overload.priority {
                    let mut best = 0usize;
                    for j in 1..queues[shard].len() {
                        if overload.class_of(queues[shard][j].0)
                            < overload.class_of(queues[shard][best].0)
                        {
                            best = j;
                        }
                    }
                    queues[shard].remove(best).unwrap()
                } else {
                    queues[shard].pop_front().unwrap()
                };
                taken.push(item);
            }
            queued_total -= take;
            pool_queued[topo.shard_pool(shard)] -= take;
            // The batch starts once the server is free and every taken
            // request is ready (for fresh arrivals ready == arrival, so
            // FIFO order makes this the last request's arrival — the
            // historical expression; only a retried request's backoff
            // can push it later).
            let ready_max = taken.iter().map(|it| it.2).fold(f64::NEG_INFINITY, f64::max);
            let mut start = free_at.max(ready_max);
            // Lazy in-queue expiry: popped requests whose deadline
            // already passed are skipped and counted — stale work never
            // occupies a server. Dropping them can only lower the
            // batch's ready_max, so `start` is recomputed over the
            // survivors. An expired pop is maximal deadline pressure
            // for the brownout signal.
            let taken = if overload.enabled {
                let (dead, alive): (Vec<Item>, Vec<Item>) = taken
                    .into_iter()
                    .partition(|&(id, arr, _, _)| overload.expired(id, arr, start));
                if !dead.is_empty() {
                    expired_total += dead.len();
                    for _ in &dead {
                        brown.observe_pop(true);
                    }
                    let ready_max =
                        alive.iter().map(|it| it.2).fold(f64::NEG_INFINITY, f64::max);
                    start = free_at.max(ready_max);
                }
                alive
            } else {
                taken
            };
            // Switches apply at dequeue: one policy consultation per
            // batch, against the per-pool depth of the current rung's
            // home pool (the signal the live PolicyHandle feeds).
            let sig = pool_queued[topo.pool_for_rung(observed)];
            let idx = observe(policy, &mut switches, &mut observed, start, sig);
            // The pool executes its own rung — the policy rung clamped
            // into its band — and its hardware scales every sampled
            // service time by the pool's speed factor. Under overload
            // the brownout offset lowers the requested rung within its
            // band and the batch's strictest class floor raises it,
            // both through the same clamp.
            let exec = if overload.enabled {
                let mean_now = plan.ladder[idx].mean_ms;
                let mut floor = 0usize;
                for &(id, arr, _, _) in &taken {
                    brown.observe_pop(overload.at_risk(id, arr, start, mean_now));
                    floor = floor.max(overload.rung_floor(id));
                }
                topo.exec_rung_floor(p, brown.effective_rung(idx), floor, n_rungs)
            } else {
                topo.exec_rung(p, idx, n_rungs)
            };
            // An active slowdown window stretches the pool's hardware
            // speed factor for batches starting inside it; a drift
            // window does the same persistently (the regime change the
            // re-planner adapts to — the *belief* side never touches
            // this arithmetic).
            let speed =
                topo.speed(p) * faults.slowdown_at_ms(p, start) * faults.drift_at_ms(p, start);
            // Injected flakes fail out of the batch before service is
            // sampled (the same deterministic (id, attempt) coin the
            // live worker flips; a flaked request consumes no engine
            // time). Without flaky faults this moves the whole batch.
            let (flaked, live): (Vec<Item>, Vec<Item>) = if has_flaky {
                taken
                    .into_iter()
                    .partition(|&(id, arr, _, att)| faults.flaky_fails(p, id, att, arr))
            } else {
                (Vec::new(), taken)
            };
            // Batch service: each sampled time is α + βᵢ, so n requests
            // in one dispatch cost Σ sᵢ − (n−1)·α (one dispatch cost, n
            // marginals); α is clamped into [0, s̄(1)] of the *executing*
            // pool's rung. At B = 1 this is the sample itself.
            let alpha_k = alpha.clamp(0.0, plan.ladder[exec].mean_ms * speed);
            let svc = if live.is_empty() {
                0.0
            } else {
                (0..live.len())
                    .map(|_| service.sample_ms(exec, &mut rng) * speed)
                    .sum::<f64>()
                    - (live.len() as f64 - 1.0) * alpha_k
            };
            let finish = start + svc.max(0.0);
            busy[slot] = finish;
            // Feed the re-planner's fit buffer: (pool, executed rung,
            // batch size, wall ms) — the same observable the live
            // worker records.
            if let Some(engine) = replanner.as_mut() {
                if !live.is_empty() {
                    engine.on_completion(p, exec, live.len(), finish - start);
                }
            }
            // A too-slow batch fails every request in it (the live
            // timeout gate measures the same start→finish span).
            let batch_timed_out = resilience.timed_out(finish - start);
            if batch_timed_out {
                counters.timeouts += live.len() as u64;
            }
            for item in live {
                if batch_timed_out {
                    hv.record(p, false, finish);
                    retry_or_fail_sim(
                        topo,
                        faults,
                        resilience,
                        &mut hv,
                        &mut queues,
                        &mut routers,
                        &mut pool_queued,
                        &mut queued_total,
                        observed,
                        item,
                        finish,
                        &mut counters,
                    );
                } else {
                    hv.record(p, true, finish);
                    records.push(RequestRecord {
                        id: item.0,
                        arrival_ms: item.1,
                        start_ms: start,
                        finish_ms: finish,
                        config_idx: exec,
                        accuracy: plan.ladder[exec].accuracy,
                        success: None,
                    });
                }
            }
            for item in flaked {
                hv.record(p, false, finish);
                retry_or_fail_sim(
                    topo,
                    faults,
                    resilience,
                    &mut hv,
                    &mut queues,
                    &mut routers,
                    &mut pool_queued,
                    &mut queued_total,
                    observed,
                    item,
                    finish,
                    &mut counters,
                );
            }
            // Departure observation (once per batch).
            let sig = pool_queued[topo.pool_for_rung(observed)];
            observe(policy, &mut switches, &mut observed, finish, sig);
        } else if i < n {
            // Admit the next arrival: rung-aware routing — round-robin
            // over the shards of the current rung's home pool.
            let arr_ms = arrivals[i] * 1000.0;
            // Advance the re-plan clock before counting the arrival so
            // this request lands in the window the tick just opened.
            replan_tick(
                &mut replanner,
                policy,
                &mut next_tick_ms,
                &mut cur_batch,
                &mut cur_margin,
                &mut replans,
                arr_ms,
                queued_total,
                observed,
            );
            // An active queue squeeze tightens the admission bound; a
            // rejected arrival consumes no id and is not observed
            // (mirrors the live injector's pre-push check).
            if let Some(cap) = faults.capacity_at_ms(arr_ms) {
                if queued_total >= cap {
                    rejected_total += 1;
                    i += 1;
                    continue;
                }
            }
            // Deadline-aware admission (overload plane): shed the
            // arrival whose class budget the backlog already exceeds —
            // or, in tail-drop mode, any arrival past `shed_depth`.
            // Unlike a squeeze rejection, a shed consumes the request
            // id, keeping DES ids aligned with the arrival index (and
            // with the live injector) so class assignment agrees
            // across executors.
            if overload.enabled
                && !overload.admit(
                    next_id,
                    queued_total,
                    plan.ladder[observed].mean_ms,
                    topo.n_workers(),
                )
            {
                shed_total += 1;
                next_id += 1;
                i += 1;
                continue;
            }
            // Health-aware routing (resilience only): a rung band whose
            // home pool is dark or breaker-open remaps to the nearest
            // surviving pool, exactly like the live injector.
            // Count the admitted arrival into the rate EWMA at the same
            // point the live injector does (post-squeeze, post-shed).
            if let Some(m) = lm.as_ref() {
                m.on_arrival();
            }
            let rp = if resilience.enabled {
                let (rp, moved) =
                    topo.pool_for_rung_routable(observed, |q| hv.routable(q, arr_ms, faults));
                if moved {
                    counters.failovers += 1;
                }
                rp
            } else {
                topo.pool_for_rung(observed)
            };
            let shard = topo.route(rp, routers[rp]);
            routers[rp] += 1;
            queues[shard].push_back((next_id, arr_ms, arr_ms, 0u32));
            queued_total += 1;
            pool_queued[rp] += 1;
            next_id += 1;
            i += 1;
            // In-flight requests of the routed pool count toward the
            // observed per-pool depth.
            let in_flight = busy
                .iter()
                .enumerate()
                .filter(|&(w, &b)| server_pool[w] == rp && b > arr_ms && b != f64::INFINITY)
                .count();
            observe(
                policy,
                &mut switches,
                &mut observed,
                arr_ms,
                pool_queued[rp] + in_flight,
            );
        } else {
            // Without faults this is unreachable: with no arrivals left
            // every server is a candidate and a pool's own workers are
            // never gated on their own backlog, so queued work always
            // finds a server. With a dark pool, backlog no live server
            // may reach (retired slots, spill-gated victims) is
            // rejected — conservation still holds.
            assert!(faults.any_dark(), "queued_total > 0 but no server may dispatch");
            rejected_total += queued_total;
            break;
        }
    }

    records.sort_by(|a, b| a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap());
    SimOutcome {
        records,
        switches,
        steals,
        spills,
        rejected: rejected_total,
        failed: counters.failed,
        retries: counters.retries,
        panics_recovered: 0,
        timeouts: counters.timeouts,
        breaker_trips: hv.breaker_trips,
        failovers: counters.failovers,
        shed: shed_total,
        expired: expired_total,
        brownout_steps: brown.steps,
        replans,
    }
}
