//! Analytical queueing formulas (M/M/1, M/G/1, Erlang-C, M/G/k) used to
//! validate the discrete-event simulator — the foundation the AQM's
//! guarantees rest on (§V models the server as an M/G/1 queue; the
//! k-worker pool generalizes it to M/G/k via the Allen–Cunneen
//! approximation).

/// M/M/1 mean number in system: `ρ / (1 - ρ)`.
pub fn mm1_mean_in_system(rho: f64) -> f64 {
    assert!((0.0..1.0).contains(&rho));
    rho / (1.0 - rho)
}

/// M/M/1 mean response time: `1 / (μ - λ)`.
pub fn mm1_mean_response(lambda: f64, mu: f64) -> f64 {
    assert!(lambda < mu);
    1.0 / (mu - lambda)
}

/// M/G/1 mean waiting time (Pollaczek–Khinchine):
/// `W = λ E[S²] / (2 (1 - ρ))`.
pub fn mg1_mean_wait(lambda: f64, mean_s: f64, second_moment_s: f64) -> f64 {
    let rho = lambda * mean_s;
    assert!(rho < 1.0, "unstable queue (rho = {rho})");
    lambda * second_moment_s / (2.0 * (1.0 - rho))
}

/// Erlang-C: probability that an arrival must wait in an M/M/k queue
/// with offered load `a = λ/μ` (erlangs). Requires `a < k` (stability).
///
/// Computed through the numerically stable Erlang-B recurrence
/// `B(n) = a·B(n-1) / (n + a·B(n-1))` and the conversion
/// `C = B / (1 - ρ + ρ·B)` — no factorials, no overflow for large k.
pub fn erlang_c(k: usize, a: f64) -> f64 {
    assert!(k >= 1, "need at least one server");
    assert!(
        (0.0..k as f64).contains(&a),
        "unstable queue (a = {a}, k = {k})"
    );
    let mut b = 1.0;
    for n in 1..=k {
        b = a * b / (n as f64 + a * b);
    }
    let rho = a / k as f64;
    b / (1.0 - rho + rho * b)
}

/// M/M/k mean waiting time: `W = C(k, a) / (kμ - λ)`.
pub fn mmk_mean_wait(k: usize, lambda: f64, mu: f64) -> f64 {
    erlang_c(k, lambda / mu) / (k as f64 * mu - lambda)
}

/// M/G/k mean waiting time (Allen–Cunneen / Lee–Longton approximation):
/// the M/M/k wait scaled by `(1 + cv²) / 2` where `cv` is the service
/// coefficient of variation. Exact at k = 1 (it reduces to
/// Pollaczek–Khinchine) and for exponential service at any k.
pub fn mgk_mean_wait(k: usize, lambda: f64, mean_s: f64, second_moment_s: f64) -> f64 {
    let cv2 = (second_moment_s / (mean_s * mean_s) - 1.0).max(0.0);
    mmk_mean_wait(k, lambda, 1.0 / mean_s) * (1.0 + cv2) / 2.0
}

/// Second moment of a lognormal with given mean and sigma (log-space).
pub fn lognormal_second_moment(mean: f64, sigma: f64) -> f64 {
    // E[X²] = exp(2μ + 2σ²) with μ = ln(mean) - σ²/2.
    let mu = mean.ln() - sigma * sigma / 2.0;
    (2.0 * mu + 2.0 * sigma * sigma).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RequestRecord;
    use crate::planner::{ConfigPolicy, Plan};
    use crate::serving::StaticPolicy;
    use crate::sim::{simulate, simulate_k, DeterministicService, LognormalService};
    use crate::workload::{generate_arrivals, Pattern, WorkloadSpec};

    fn plan_one(mean: f64, p95: f64) -> Plan {
        Plan {
            slo_ms: 1e9,
            slack_buffer_ms: 0.0,
            up_cooldown_ms: 0.0,
            down_cooldown_ms: 0.0,
            workers: 1,
            batch: 1,
            batch_alpha_ms: 0.0,
            pools: vec![],
            ladder: vec![ConfigPolicy {
                label: "only".into(),
                config: vec![],
                accuracy: 0.8,
                mean_ms: mean,
                p95_ms: p95,
                queue_slack_ms: 0.0,
                upscale_threshold: u64::MAX,
                downscale_threshold: None,
            }],
        }
    }

    fn mean_wait(records: &[RequestRecord]) -> f64 {
        records.iter().map(|r| r.wait_ms()).sum::<f64>() / records.len() as f64
    }

    #[test]
    fn simulator_matches_md1_wait() {
        // M/D/1: W = ρ s̄ / (2 (1 - ρ)). λ = 0.04/ms, s = 15 ms, ρ = 0.6.
        let plan = plan_one(15.0, 15.0);
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 40.0,
            duration_s: 4000.0,
            pattern: Pattern::Steady,
            seed: 17,
        });
        let svc = DeterministicService { means: vec![15.0] };
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate(&arrivals, &plan, &mut pol, &svc, 17);
        let measured = mean_wait(&out.records);
        let rho: f64 = 0.04 * 15.0;
        let expect = rho * 15.0 / (2.0 * (1.0 - rho));
        assert!(
            (measured - expect).abs() / expect < 0.15,
            "M/D/1 wait: measured {measured:.2} expect {expect:.2}"
        );
    }

    #[test]
    fn simulator_matches_pollaczek_khinchine() {
        // M/G/1 with lognormal service fitted to (mean 20, p95 36).
        let plan = plan_one(20.0, 36.0);
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 30.0, // λ = 0.03/ms, ρ = 0.6
            duration_s: 6000.0,
            pattern: Pattern::Steady,
            seed: 23,
        });
        let svc = LognormalService::from_plan(&plan, 0.0);
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate(&arrivals, &plan, &mut pol, &svc, 23);
        let measured = mean_wait(&out.records);

        let sigma = crate::sim::service::fit_lognormal(20.0, 36.0).1;
        let m2 = lognormal_second_moment(20.0, sigma);
        let expect = mg1_mean_wait(0.03, 20.0, m2);
        assert!(
            (measured - expect).abs() / expect < 0.2,
            "P-K wait: measured {measured:.2} expect {expect:.2}"
        );
    }

    #[test]
    fn erlang_c_matches_tabulated_values() {
        // k = 1 reduces to ρ.
        assert!((erlang_c(1, 0.5) - 0.5).abs() < 1e-12);
        assert!((erlang_c(1, 0.9) - 0.9).abs() < 1e-12);
        // Textbook values: C(2, a=1) = 1/3, C(3, a=2) = 4/9.
        assert!((erlang_c(2, 1.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((erlang_c(3, 2.0) - 4.0 / 9.0).abs() < 1e-12);
        // Heavier pool, moderate load: waiting probability keeps
        // shrinking as servers are added at fixed ρ.
        let c2 = erlang_c(2, 2.0 * 0.7);
        let c8 = erlang_c(8, 8.0 * 0.7);
        assert!(c8 < c2, "C8 {c8} should be < C2 {c2}");
    }

    #[test]
    fn mgk_reduces_to_pollaczek_khinchine_at_k1() {
        let (lambda, mean, m2) = (0.03, 20.0, 520.0);
        let pk = mg1_mean_wait(lambda, mean, m2);
        let ac = mgk_mean_wait(1, lambda, mean, m2);
        assert!((pk - ac).abs() / pk < 1e-12, "PK {pk} vs AC {ac}");
    }

    #[test]
    fn simulator_matches_mdk_wait() {
        // M/D/2 at ρ = 0.75: Allen–Cunneen predicts
        // W ≈ C(2, 1.5)/(2μ - λ) · 1/2 (cv = 0 for deterministic
        // service); the approximation is good to a few percent here.
        let plan = plan_one(15.0, 15.0);
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 100.0, // λ = 0.1/ms, a = 1.5 erlangs over k = 2
            duration_s: 3000.0,
            pattern: Pattern::Steady,
            seed: 29,
        });
        let svc = DeterministicService { means: vec![15.0] };
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate_k(&arrivals, &plan, &mut pol, &svc, 29, 2);
        let measured = mean_wait(&out.records);
        let expect = mgk_mean_wait(2, 0.1, 15.0, 15.0 * 15.0);
        assert!(
            (measured - expect).abs() / expect < 0.15,
            "M/D/2 wait: measured {measured:.2} expect {expect:.2}"
        );
    }

    #[test]
    fn closed_forms_sane() {
        assert!((mm1_mean_in_system(0.5) - 1.0).abs() < 1e-12);
        assert!((mm1_mean_response(0.5, 1.0) - 2.0).abs() < 1e-12);
        // Deterministic service: E[S²] = s̄², W = ρ s̄ / (2(1-ρ)).
        let w = mg1_mean_wait(0.05, 10.0, 100.0);
        assert!((w - 0.05 * 100.0 / (2.0 * 0.5)).abs() < 1e-12);
        // Lognormal second moment at sigma -> 0 approaches mean².
        assert!((lognormal_second_moment(10.0, 1e-9) - 100.0).abs() < 1e-6);
    }
}
