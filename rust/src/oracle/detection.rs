//! Calibrated object-detection cascade landscape (stands in for COCO
//! mAP@0.5 over the YOLOv8 cascade of paper §VI-B).
//!
//! Structure:
//! * per-detector base quality (det-n < det-s < det-m);
//! * the verifier adds its gain on the fraction of inputs forwarded to
//!   it, which rises with the confidence threshold (more predictions fall
//!   below a higher bar and get re-checked);
//! * NMS threshold has a sweet spot at 0.5 with a quadratic penalty on
//!   both sides (too low merges true positives, too high keeps
//!   duplicates) — this makes the landscape non-monotone on one axis,
//!   exercising COMPASS-V's gradient navigation rather than pure
//!   monotone expansion.

use super::{Landscape, LandscapeEvaluator};
use crate::configspace::{Config, ConfigSpace};
use crate::workflows::detection::{DETECTOR_NAMES, VERIFIER_NAMES};

/// Base mAP of each detector (det-n, det-s, det-m).
pub const DETECTOR_BASE: [f64; 3] = [0.565, 0.625, 0.680];
/// Additive gain of each verifier at full coverage (none, m, l, x).
pub const VERIFIER_GAIN: [f64; 4] = [0.0, 0.075, 0.105, 0.130];
/// NMS penalty curvature.
pub const NMS_PENALTY: f64 = 0.08;

/// Fraction of predictions forwarded to the verifier at threshold `t`.
pub fn forwarded_fraction(conf_thr: f64) -> f64 {
    (0.25 + 1.5 * conf_thr).min(1.0)
}

/// The detection-cascade landscape.
#[derive(Clone, Debug, Default)]
pub struct DetectionLandscape;

impl Landscape for DetectionLandscape {
    fn true_accuracy(&self, space: &ConfigSpace, cfg: &Config) -> f64 {
        let det = space.named_value(cfg, "detector").as_str().unwrap().to_string();
        let ver = space.named_value(cfg, "verifier").as_str().unwrap().to_string();
        let conf = space.named_value(cfg, "conf_thr").as_f64().unwrap();
        let nms = space.named_value(cfg, "nms_thr").as_f64().unwrap();

        let di = DETECTOR_NAMES.iter().position(|n| *n == det).expect("detector");
        let vi = VERIFIER_NAMES.iter().position(|n| *n == ver).expect("verifier");

        let coverage = forwarded_fraction(conf);
        let nms_pen = NMS_PENALTY * ((nms - 0.5) / 0.2).powi(2);
        (DETECTOR_BASE[di] + VERIFIER_GAIN[vi] * coverage - nms_pen).clamp(0.0, 1.0)
    }
}

/// The detection oracle: landscape + deterministic Bernoulli observation.
pub type DetectionOracle = LandscapeEvaluator<DetectionLandscape>;

impl DetectionOracle {
    pub fn new_detection(seed: u64) -> DetectionOracle {
        LandscapeEvaluator::new(DetectionLandscape, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::detection_space;

    /// Paper §VI-B: eight detection thresholds (0.55 … 0.80).
    pub const TAUS: [f64; 8] = [0.55, 0.59, 0.62, 0.66, 0.70, 0.73, 0.76, 0.80];

    #[test]
    fn feasible_fractions_span_paper_range() {
        let space = detection_space();
        let l = DetectionLandscape;
        let all = space.enumerate_valid();
        let frac = |tau: f64| {
            all.iter()
                .filter(|c| l.true_accuracy(&space, c) >= tau)
                .count() as f64
                / all.len() as f64
        };
        let fracs: Vec<f64> = TAUS.iter().map(|&t| frac(t)).collect();
        for w in fracs.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(fracs[0] > 0.85, "tau=0.55 fraction {}", fracs[0]);
        assert!(
            fracs[7] > 0.0 && fracs[7] < 0.05,
            "tau=0.80 fraction {}",
            fracs[7]
        );
    }

    #[test]
    fn nms_sweet_spot_at_half() {
        let space = detection_space();
        let l = DetectionLandscape;
        let nms_axis = space.param_index("nms_thr").unwrap();
        // For a fixed otherwise-best config, nms=0.5 must maximize.
        let mut cfg = space.enumerate_valid()[0].clone();
        cfg[space.param_index("detector").unwrap()] = 2;
        cfg[space.param_index("verifier").unwrap()] = 3;
        cfg[space.param_index("conf_thr").unwrap()] = 6;
        let accs: Vec<f64> = (0..5)
            .map(|i| {
                let mut c = cfg.clone();
                c[nms_axis] = i;
                l.true_accuracy(&space, &c)
            })
            .collect();
        let best = accs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 2); // index 2 = 0.5
    }

    #[test]
    fn verifier_gain_requires_coverage() {
        let space = detection_space();
        let l = DetectionLandscape;
        // With verifier=x, higher confidence threshold -> more coverage ->
        // higher mAP.
        let conf_axis = space.param_index("conf_thr").unwrap();
        let mut cfg = vec![0; space.dims()];
        cfg[space.param_index("verifier").unwrap()] = 3;
        cfg[space.param_index("nms_thr").unwrap()] = 2;
        let lo = l.true_accuracy(&space, &cfg);
        cfg[conf_axis] = 6;
        let hi = l.true_accuracy(&space, &cfg);
        assert!(hi > lo);
    }
}
