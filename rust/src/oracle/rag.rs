//! Calibrated RAG accuracy landscape (stands in for SQuAD 2.0 F1).
//!
//! Structure (all monotonicities match the paper's RAG pipeline):
//!
//! * retrieval recall rises with retriever-k with diminishing returns:
//!   `r(k) = 1 - 0.55 * exp(-k / 7)`;
//! * the reranker keeps the relevant document with probability rising in
//!   rerank-k and reranker quality: `s = 1 - miss_rr * exp(-rk / 4)`;
//! * the generator converts a grounded context into a correct answer with
//!   per-size quality `q_gen`, and salvages a fraction `BACKGROUND` of
//!   ungrounded queries (parametric knowledge);
//! * `F1(c) = q_gen * (hit + BACKGROUND * (1 - hit))`, `hit = r * s`.
//!
//! Calibration targets the paper's eight RAG thresholds (0.30 … 0.85)
//! spanning feasible fractions ≈99% → ≈2% (checked by tests below).

use super::{Landscape, LandscapeEvaluator};
use crate::configspace::{Config, ConfigSpace};
use crate::workflows::rag::{GENERATOR_NAMES, RERANKER_NAMES};

/// Per-generator answer quality (gen-64 … gen-288 ladder).
pub const GEN_QUALITY: [f64; 6] = [0.70, 0.76, 0.82, 0.86, 0.89, 0.91];
/// Per-reranker miss mass (rr-48 … rr-160 ladder).
pub const RERANK_MISS: [f64; 3] = [0.35, 0.22, 0.12];
/// Probability an ungrounded query is still answered correctly.
pub const BACKGROUND: f64 = 0.25;

/// The RAG landscape (see module docs).
#[derive(Clone, Debug, Default)]
pub struct RagLandscape;

/// Retrieval recall@k of the planted relevant document.
pub fn retrieval_recall(k: f64) -> f64 {
    1.0 - 0.55 * (-k / 7.0).exp()
}

/// Probability the reranker keeps the relevant doc in its top rerank-k.
pub fn rerank_keep(miss: f64, rerank_k: f64) -> f64 {
    1.0 - miss * (-rerank_k / 4.0).exp()
}

impl Landscape for RagLandscape {
    fn true_accuracy(&self, space: &ConfigSpace, cfg: &Config) -> f64 {
        let gen = space.named_value(cfg, "generator").as_str().unwrap().to_string();
        let rr = space.named_value(cfg, "reranker").as_str().unwrap().to_string();
        let k = space.named_value(cfg, "retriever_k").as_f64().unwrap();
        let rk = space.named_value(cfg, "rerank_k").as_f64().unwrap();

        let gi = GENERATOR_NAMES.iter().position(|n| *n == gen).expect("generator");
        let ri = RERANKER_NAMES.iter().position(|n| *n == rr).expect("reranker");

        let hit = retrieval_recall(k) * rerank_keep(RERANK_MISS[ri], rk);
        (GEN_QUALITY[gi] * (hit + BACKGROUND * (1.0 - hit))).clamp(0.0, 1.0)
    }
}

/// The RAG oracle: landscape + deterministic Bernoulli observation.
pub type RagOracle = LandscapeEvaluator<RagLandscape>;

impl RagOracle {
    pub fn new_rag(seed: u64) -> RagOracle {
        LandscapeEvaluator::new(RagLandscape, seed)
    }
}

// Ergonomic alias used across examples/experiments.
impl RagLandscape {
    pub fn oracle(seed: u64) -> RagOracle {
        RagOracle::new_rag(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::rag_space;

    /// Paper §VI-B: eight RAG thresholds.
    pub const TAUS: [f64; 8] = [0.30, 0.40, 0.50, 0.60, 0.70, 0.75, 0.80, 0.85];

    #[test]
    fn monotone_in_each_dimension() {
        let space = rag_space();
        let l = RagLandscape;
        for cfg in space.enumerate_valid() {
            let base = l.true_accuracy(&space, &cfg);
            for n in space.neighbors_step(&cfg) {
                let other = l.true_accuracy(&space, &n);
                // Find the axis that moved; all axes are quality-monotone
                // (larger index = better) in this space.
                let axis = (0..cfg.len()).find(|&i| n[i] != cfg[i]).unwrap();
                if n[axis] > cfg[axis] {
                    assert!(
                        other >= base - 1e-12,
                        "axis {axis} up should not hurt: {base} -> {other}"
                    );
                }
            }
        }
    }

    #[test]
    fn feasible_fractions_span_paper_range() {
        let space = rag_space();
        let l = RagLandscape;
        let all = space.enumerate_valid();
        let frac = |tau: f64| {
            all.iter()
                .filter(|c| l.true_accuracy(&space, c) >= tau)
                .count() as f64
                / all.len() as f64
        };
        let fracs: Vec<f64> = TAUS.iter().map(|&t| frac(t)).collect();
        // Decreasing in tau, spanning wide -> narrow.
        for w in fracs.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(fracs[0] > 0.9, "tau=0.30 fraction {}", fracs[0]);
        assert!(fracs[7] > 0.0 && fracs[7] < 0.08, "tau=0.85 fraction {}", fracs[7]);
        // A moderate threshold sits in the paper's "hard" band.
        assert!(fracs[4] > 0.2 && fracs[4] < 0.8, "tau=0.70 fraction {}", fracs[4]);
    }

    #[test]
    fn accuracy_range_sane() {
        let space = rag_space();
        let l = RagLandscape;
        for cfg in space.enumerate_valid() {
            let a = l.true_accuracy(&space, &cfg);
            assert!((0.2..=0.95).contains(&a), "{a}");
        }
    }

    #[test]
    fn best_config_is_biggest_everything() {
        let space = rag_space();
        let l = RagLandscape;
        let best = space
            .enumerate_valid()
            .into_iter()
            .max_by(|a, b| {
                l.true_accuracy(&space, a)
                    .partial_cmp(&l.true_accuracy(&space, b))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(
            space.named_value(&best, "generator").as_str(),
            Some("gen-288")
        );
        assert_eq!(
            space.named_value(&best, "reranker").as_str(),
            Some("rr-160")
        );
    }
}
