//! Surrogate accuracy oracles — the ground-truth landscapes for search.
//!
//! The paper evaluates COMPASS-V against exhaustive grid search over real
//! SQuAD-F1 / COCO-mAP evaluations. Those datasets and models are not
//! available offline, so the search experiments run against *calibrated
//! synthetic landscapes* with the same structure (DESIGN.md §2):
//!
//! * monotone in each semantic direction (bigger generator / reranker ↑,
//!   larger k ↑ with diminishing returns, NMS sweet-spot at 0.5, …);
//! * feasible fractions spanning ≈99% → ≈2% across the paper's eight
//!   thresholds per workflow;
//! * observed through per-sample Bernoulli draws — exactly the view
//!   COMPASS-V has of a real dataset evaluation (success/failure per
//!   dataset item), which is all that Wilson-CI budgeting consumes.
//!
//! Both oracles are deterministic: draw `i` for configuration `c` is a
//! pure function of `(oracle seed, flat config id, i)`, so COMPASS-V and
//! grid search observe identical sample streams.

pub mod detection;
pub mod rag;

pub use detection::DetectionOracle;
pub use rag::RagOracle;

use crate::configspace::{Config, ConfigSpace};
use crate::search::Evaluator;
use crate::util::Rng;
use std::collections::HashMap;

/// Common machinery: a true-accuracy landscape observed through
/// deterministic Bernoulli sampling.
pub trait Landscape {
    /// The latent true accuracy of a configuration.
    fn true_accuracy(&self, space: &ConfigSpace, cfg: &Config) -> f64;
}

/// Wraps a [`Landscape`] into a deterministic [`Evaluator`].
pub struct LandscapeEvaluator<L: Landscape> {
    pub landscape: L,
    seed: u64,
    counters: HashMap<usize, u64>,
}

impl<L: Landscape> LandscapeEvaluator<L> {
    pub fn new(landscape: L, seed: u64) -> Self {
        LandscapeEvaluator { landscape, seed, counters: HashMap::new() }
    }

    pub fn true_accuracy(&self, space: &ConfigSpace, cfg: &Config) -> f64 {
        self.landscape.true_accuracy(space, cfg)
    }

    /// Reset draw counters (fresh evaluation pass with identical draws).
    pub fn reset(&mut self) {
        self.counters.clear();
    }
}

impl<L: Landscape> Evaluator for LandscapeEvaluator<L> {
    fn sample(&mut self, space: &ConfigSpace, cfg: &Config, n: u32) -> u32 {
        let id = space.flat_id(cfg);
        let p = self.landscape.true_accuracy(space, cfg);
        let counter = self.counters.entry(id).or_insert(0);
        let mut successes = 0;
        for i in 0..n as u64 {
            // Counter-based stream: one cheap RNG per draw keeps draw k of
            // config c identical regardless of batching.
            let draw = *counter + i;
            let mut r = Rng::new(
                self.seed
                    ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ draw.wrapping_mul(0xD1B54A32D192ED03),
            );
            if r.bernoulli(p) {
                successes += 1;
            }
        }
        *counter += n as u64;
        successes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configspace::{ConfigSpace, ParamDef};

    struct Flat(f64);

    impl Landscape for Flat {
        fn true_accuracy(&self, _s: &ConfigSpace, _c: &Config) -> f64 {
            self.0
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new("t", vec![ParamDef::discrete("x", vec![0, 1])], vec![])
    }

    #[test]
    fn batching_invariant_draws() {
        let s = space();
        let cfg = vec![0];
        let mut a = LandscapeEvaluator::new(Flat(0.5), 9);
        let mut b = LandscapeEvaluator::new(Flat(0.5), 9);
        let batched = a.sample(&s, &cfg, 100);
        let split = b.sample(&s, &cfg, 30) + b.sample(&s, &cfg, 70);
        assert_eq!(batched, split);
    }

    #[test]
    fn matches_latent_probability() {
        let s = space();
        let mut e = LandscapeEvaluator::new(Flat(0.73), 3);
        let succ = e.sample(&s, &vec![1], 20_000);
        let rate = succ as f64 / 20_000.0;
        assert!((rate - 0.73).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn different_configs_decorrelated() {
        let s = ConfigSpace::new(
            "t2",
            vec![ParamDef::discrete("x", vec![0, 1, 2, 3])],
            vec![],
        );
        let mut e = LandscapeEvaluator::new(Flat(0.5), 3);
        let a = e.sample(&s, &vec![0], 1000);
        let b = e.sample(&s, &vec![1], 1000);
        assert_ne!(a, b); // overwhelmingly likely under decorrelation
    }
}
