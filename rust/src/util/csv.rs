//! Tiny CSV writer for experiment outputs (`results/*.csv`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with RFC-4180 quoting.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        write_row(&mut w, header)?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one data row (must match the header width).
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.cols, "csv row width mismatch");
        let refs: Vec<&str> = fields.iter().map(|s| s.as_str()).collect();
        write_row(&mut self.w, &refs)
    }

    /// Convenience: format any Display values as a row.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> std::io::Result<()> {
        let owned: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&owned)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

fn write_row<W: Write>(w: &mut W, fields: &[&str]) -> std::io::Result<()> {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            write!(w, ",")?;
        }
        if f.contains([',', '"', '\n']) {
            write!(w, "\"{}\"", f.replace('"', "\"\""))?;
        } else {
            write!(w, "{f}")?;
        }
    }
    writeln!(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let dir = std::env::temp_dir().join("compass_csv_test");
        let path = dir.join("t.csv");
        {
            let mut c = CsvWriter::create(&path, &["a", "b"]).unwrap();
            c.row(&["1".into(), "x,y".into()]).unwrap();
            c.rowd(&[&2.5, &"q\"uote"]).unwrap();
            c.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2.5,\"q\"\"uote\"\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_wrong_width() {
        let dir = std::env::temp_dir().join("compass_csv_test2");
        let path = dir.join("t.csv");
        let mut c = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = c.row(&["only-one".into()]);
    }
}
