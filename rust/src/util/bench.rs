//! Mini benchmark harness (no `criterion` offline — DESIGN.md §6).
//!
//! `cargo bench` targets set `harness = false` and drive this: warmup,
//! timed iterations, mean / p50 / p95 reporting, and an optional
//! `COMPASS_BENCH_FAST=1` mode so CI can smoke the benches quickly.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary_us: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary_us;
        format!(
            "{:<44} {:>7} iters  mean {:>10.1} µs  p50 {:>10.1} µs  p95 {:>10.1} µs",
            self.name, self.iters, s.mean, s.p50, s.p95
        )
    }
}

/// Whether benches should run in abbreviated mode.
pub fn fast_mode() -> bool {
    std::env::var("COMPASS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let (warmup, iters) = if fast_mode() {
        (warmup.min(1), iters.clamp(1, 5))
    } else {
        (warmup, iters.max(1))
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        summary_us: Summary::of(&samples),
    };
    println!("{}", r.report());
    r
}

/// Group banner for bench output.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop+sum", 1, 10, || {
            let s: u64 = (0..1000).sum();
            std::hint::black_box(s);
        });
        assert_eq!(r.iters, if fast_mode() { 5 } else { 10 });
        assert!(r.summary_us.mean >= 0.0);
    }
}
