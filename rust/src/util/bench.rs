//! Mini benchmark harness (no `criterion` offline — DESIGN.md §6).
//!
//! `cargo bench` targets set `harness = false` and drive this: warmup,
//! timed iterations, mean / p50 / p95 reporting, and an optional
//! `COMPASS_BENCH_FAST=1` mode so CI can smoke the benches quickly.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary_us: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary_us;
        format!(
            "{:<44} {:>7} iters  mean {:>10.1} µs  p50 {:>10.1} µs  p95 {:>10.1} µs",
            self.name, self.iters, s.mean, s.p50, s.p95
        )
    }
}

/// Whether benches should run in abbreviated mode.
pub fn fast_mode() -> bool {
    std::env::var("COMPASS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let (warmup, iters) = if fast_mode() {
        (warmup.min(1), iters.clamp(1, 5))
    } else {
        (warmup, iters.max(1))
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        summary_us: Summary::of(&samples),
    };
    println!("{}", r.report());
    r
}

/// Group banner for bench output.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

/// Write bench results as a machine-readable JSON map `name ->
/// nanoseconds per iteration` (mean), so the perf trajectory can be
/// diffed across PRs (`BENCH_<target>.json` at the invocation cwd).
/// Hand-rolled serialization — no serde offline (DESIGN.md §6).
pub fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    for (i, r) in results.iter().enumerate() {
        let name: String = r
            .name
            .chars()
            .map(|c| if c == '"' || c == '\\' { '_' } else { c })
            .collect();
        out.push_str(&format!(
            "  \"{}\": {:.1}{}\n",
            name,
            r.summary_us.mean * 1e3,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    std::fs::write(path, &out)?;
    println!("-> {path} ({} entries)", results.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop+sum", 1, 10, || {
            let s: u64 = (0..1000).sum();
            std::hint::black_box(s);
        });
        assert_eq!(r.iters, if fast_mode() { 5 } else { 10 });
        assert!(r.summary_us.mean >= 0.0);
    }

    #[test]
    fn json_output_is_a_flat_name_to_ns_map() {
        let r1 = bench("alpha x1", 0, 3, || {
            std::hint::black_box((0..100u64).sum::<u64>());
        });
        let r2 = bench("beta \"quoted\"", 0, 3, || {
            std::hint::black_box((0..100u64).sum::<u64>());
        });
        let dir = std::env::temp_dir().join("compass_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        write_json(path.to_str().unwrap(), &[r1, r2]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with('{') && body.trim_end().ends_with('}'));
        assert!(body.contains("\"alpha x1\":"));
        // Quotes in names are sanitized, keeping the JSON well-formed.
        assert!(body.contains("\"beta _quoted_\":"));
        assert_eq!(body.matches(':').count(), 2);
    }
}
