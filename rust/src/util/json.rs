//! Minimal JSON parser + writer (no `serde` offline).
//!
//! Covers the full JSON grammar; used for `artifacts/manifest.json`, plan
//! files and experiment outputs. Numbers are `f64` (the manifest never
//! needs 64-bit integer precision beyond 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null-ish None when missing.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------- construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.i + 2..self.i + 6],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 5;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                self.i += 4;
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn string_escaping_roundtrip() {
        let j = Json::Str("line\n\"quote\"\tta\\b".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
