//! Statistics substrates: online moments, percentiles, latency summaries.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Finite values of a sample, sorted ascending. NaN / ±∞ entries are
/// dropped rather than poisoning the order: one bad latency record must
/// never panic a live summary.
fn sorted_finite(xs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    v.sort_by(|a, b| a.total_cmp(b));
    v
}

/// Linear-interpolated percentile of an **unsorted** sample (q in [0,1]).
/// Non-finite samples are ignored; NaN only when nothing finite remains.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    percentile_sorted(&sorted_finite(xs), q)
}

/// Percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Latency summary over a sample (all values in the sample's unit).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a sample, ignoring non-finite values (a summary over
    /// nothing finite is the empty default).
    pub fn of(xs: &[f64]) -> Summary {
        let v = sorted_finite(xs);
        if v.is_empty() {
            return Summary::default();
        }
        Summary {
            count: v.len(),
            mean: v.iter().sum::<f64>() / v.len() as f64,
            p50: percentile_sorted(&v, 0.50),
            p90: percentile_sorted(&v, 0.90),
            p95: percentile_sorted(&v, 0.95),
            p99: percentile_sorted(&v, 0.99),
            max: *v.last().unwrap(),
        }
    }
}

/// Exponentially-weighted moving average (load-monitor substrate).
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0,1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let alpha = self.alpha;
        self.push_weighted(x, alpha)
    }

    /// Push with an explicit weight for this observation (the
    /// time-corrected EWMA substrate: a caller covering `dt` of nominal
    /// period `τ` passes `1 − (1 − α)^(dt/τ)`, which is exactly `α`
    /// when `dt == τ` — so regular callers are bit-identical to
    /// [`push`](Ewma::push)). `weight` is clamped to [0, 1]; the first
    /// observation seeds the average regardless of weight.
    pub fn push_weighted(&mut self, x: f64, weight: f64) -> f64 {
        let w = weight.clamp(0.0, 1.0);
        let v = match self.value {
            None => x,
            Some(prev) => prev + w * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Empirical CDF points `(value, fraction <= value)` for plotting (Fig. 6).
/// Non-finite samples are ignored.
pub fn cdf_points(xs: &[f64], n_points: usize) -> Vec<(f64, f64)> {
    let v = sorted_finite(xs);
    if v.is_empty() {
        return vec![];
    }
    let n = v.len();
    let step = (n.max(2) - 1) as f64 / (n_points.max(2) - 1) as f64;
    (0..n_points.max(2))
        .map(|i| {
            let idx = ((i as f64 * step).round() as usize).min(n - 1);
            (v[idx], (idx + 1) as f64 / n as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_ordered() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 1000);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.max, 999.0);
    }

    #[test]
    fn summary_of_empty() {
        assert_eq!(Summary::of(&[]).count, 0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn non_finite_samples_are_ignored_not_fatal() {
        // One NaN used to panic the sort; now it is dropped.
        let xs = [1.0, f64::NAN, 2.0, f64::INFINITY, 3.0, f64::NEG_INFINITY, 4.0];
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
        let s = Summary::of(&xs);
        assert_eq!(s.count, 4);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.5).abs() < 1e-12);
        let pts = cdf_points(&xs, 4);
        assert!(pts.iter().all(|(v, f)| v.is_finite() && f.is_finite()));
        // Nothing finite left: empty-sample behavior, never a panic.
        assert!(percentile(&[f64::NAN], 0.5).is_nan());
        assert_eq!(Summary::of(&[f64::NAN, f64::INFINITY]).count, 0);
        assert!(cdf_points(&[f64::NAN], 5).is_empty());
    }

    #[test]
    fn weighted_push_matches_push_at_full_alpha_weight() {
        let mut a = Ewma::new(0.3);
        let mut b = Ewma::new(0.3);
        for i in 0..20 {
            let x = (i * 7 % 13) as f64;
            let va = a.push(x);
            let vb = b.push_weighted(x, 0.3);
            assert_eq!(va, vb, "weight == alpha must be bit-identical to push");
        }
    }

    #[test]
    fn weighted_push_interpolates_by_weight() {
        let mut e = Ewma::new(0.5);
        e.push_weighted(10.0, 1.0); // seed
        // Zero weight: the estimate must not move.
        assert_eq!(e.push_weighted(100.0, 0.0), 10.0);
        // Full weight: jumps to the observation.
        assert_eq!(e.push_weighted(100.0, 1.0), 100.0);
        // Out-of-range weights clamp instead of extrapolating.
        assert_eq!(e.push_weighted(0.0, 2.0), 0.0);
        assert_eq!(e.push_weighted(50.0, -1.0), 0.0);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let pts = cdf_points(&xs, 10);
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
    }
}
