//! Cache-line padding for hot atomics.
//!
//! Adjacent atomics that different cores hammer (per-shard ring heads
//! and tails, the depth/steal/spill counters of the sharded queue) end
//! up on the same 64-byte cache line when laid out naively — every
//! update then invalidates the *other* counters' line too ("false
//! sharing"), and the coherence traffic serializes cores that never
//! touch the same data. [`CachePadded`] aligns its contents to a
//! 64-byte boundary and rounds its size up to a multiple of it, so two
//! padded values can never share a line.
//!
//! 64 bytes is the line size of every x86-64 part and most aarch64
//! server parts; some Apple/ARM designs prefetch 128-byte pairs, which
//! this deliberately does not chase — the queue's counters are already
//! separated by at least one full line, which removes the measurable
//! effect (vendored-`crossbeam`'s `CachePadded` makes the same
//! trade-off configurable per arch; we keep the std-only build simple).

/// Pads and aligns `T` to a 64-byte cache line.
///
/// Transparent to use: `Deref`/`DerefMut` expose the inner value, so an
/// `CachePadded<AtomicUsize>` is called exactly like the bare atomic.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` in its own cache line.
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    /// Unwrap the padded value.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn padded_values_never_share_a_line() {
        // Size and alignment are both rounded to the full line, so
        // consecutive array/struct members land on distinct lines.
        assert_eq!(std::mem::align_of::<CachePadded<AtomicUsize>>(), 64);
        assert_eq!(std::mem::size_of::<CachePadded<AtomicUsize>>(), 64);
        let pair: [CachePadded<AtomicUsize>; 2] =
            [CachePadded::new(AtomicUsize::new(0)), CachePadded::new(AtomicUsize::new(0))];
        let a = &pair[0] as *const _ as usize;
        let b = &pair[1] as *const _ as usize;
        assert!(b - a >= 64, "adjacent padded atomics {a:#x} / {b:#x} share a line");
    }

    #[test]
    fn deref_is_transparent() {
        let c = CachePadded::new(AtomicUsize::new(7));
        c.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 8);
        assert_eq!(c.into_inner().into_inner(), 8);
    }
}
