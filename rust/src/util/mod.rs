//! Std-only substrates: deterministic RNG, JSON, CSV, statistics, timing.
//!
//! The offline build environment provides no `rand`, `serde` or `criterion`
//! (DESIGN.md §6), so the pieces this crate needs are implemented here with
//! an emphasis on determinism — every stochastic component in Compass is
//! seeded, which makes search traces, simulations and serving experiments
//! reproducible bit-for-bit.

pub mod bench;
pub mod cache;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;

pub use cache::CachePadded;
pub use rng::Rng;
pub use stats::{percentile, OnlineStats, Summary};

use std::time::{SystemTime, UNIX_EPOCH};

/// Wall-clock seconds since the Unix epoch (coarse; for run stamping only).
pub fn unix_time() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Create the results directory used by experiments, returning its path.
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from(
        std::env::var("COMPASS_RESULTS_DIR").unwrap_or_else(|_| "results".into()),
    );
    let _ = std::fs::create_dir_all(&dir);
    dir
}
