//! Deterministic pseudo-random number generation (no `rand` offline).
//!
//! `xoshiro256**` seeded through SplitMix64 — the standard pairing: the
//! SplitMix64 stream decorrelates low-entropy seeds before they reach the
//! xoshiro state. Includes the distribution samplers Compass needs:
//! uniform, normal (Box–Muller), exponential (inversion) and Poisson
//! (Knuth multiplication for the small means used by arrival generation).

/// xoshiro256** PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-config / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Bernoulli trial with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln is finite.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson with mean `lambda` (Knuth; fine for the small means used by
    /// per-tick arrival generation).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            // Normal approximation for large means (error < 2% there).
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly choose an element index of a non-empty slice.
    pub fn choice_index(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean_matches() {
        let mut r = Rng::new(4);
        for lambda in [0.3, 2.0, 8.0, 50.0] {
            let n = 20_000;
            let total: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.06,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn exponential_mean_matches() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let total: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        assert!((total / n as f64 - 0.25).abs() < 0.01);
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
