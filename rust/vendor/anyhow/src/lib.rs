//! Minimal offline drop-in for the `anyhow` error crate.
//!
//! The build environment provides no registry access (DESIGN.md §6), so
//! the subset of `anyhow` this workspace uses — [`Result`], [`Error`],
//! [`Context`], `anyhow!`, `bail!` — is implemented here. An [`Error`]
//! carries a human-readable context chain instead of a live trait
//! object; formatting matches `anyhow` (`{}` prints the outermost
//! message, `{:#}` the full chain), which is all the callers rely on.
//! Swapping back to the registry crate is a one-line change in the
//! workspace `Cargo.toml`.

use std::fmt;

/// `Result` with a defaulted error type, as in `anyhow`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value: a message plus a chain of context frames, outermost
/// first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// As in `anyhow`: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` (and the
// `?` operator on any std error) coexist with `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Adds `context`/`with_context` to `Result`, as in `anyhow`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner"]);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("boom {}", 7));
        let e = r.with_context(|| "while testing").unwrap_err();
        assert_eq!(format!("{e:#}"), "while testing: boom 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "nope");
    }
}
