//! Construction-level parity of the unified dispatch plane.
//!
//! Every historical simulator entry point (`simulate`, `simulate_k`,
//! `simulate_disc`, `simulate_pools`) is a shim building a
//! [`Topology`] for the one engine (`sim::simulate_topology`). These
//! tests pin that construction:
//!
//! * a property sweep over k × B × shards × {uniform, heterogeneous}
//!   asserting each shim returns records/switches/steals/spills
//!   identical to the direct engine call on the matching topology;
//! * a golden pin of the seed shape (k = 1, B = 1, central FIFO)
//!   against a hand-computed M/D/1 timeline — exact f64 equality, so
//!   the seed figures can never drift silently;
//! * the cost-aware spill gate: a slow pool stops poaching work the
//!   fast pool would finish sooner once `--spill-margin` is positive.

use compass::metrics::RequestRecord;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, Plan, ProfiledConfig};
use compass::serving::pool::{parse_pools, PoolSpec};
use compass::serving::{ElasticoPolicy, StaticPolicy, Topology};
use compass::sim::{
    simulate, simulate_disc, simulate_k, simulate_pools, simulate_topology,
    DeterministicService, Discipline, LognormalService, SimOutcome,
};
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn plan2() -> Plan {
    let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
    };
    derive_plan(
        &[mk("fast", 0.76, 20.0, 28.0), mk("accurate", 0.85, 90.0, 120.0)],
        AqmParams::for_slo(300.0),
    )
}

fn arrivals(qps: f64, dur: f64) -> Vec<f64> {
    generate_arrivals(&WorkloadSpec {
        base_qps: qps,
        duration_s: dur,
        pattern: Pattern::Steady,
        seed: 5,
    })
}

/// Exact record equality (RequestRecord carries f64 times).
fn records_identical(a: &[RequestRecord], b: &[RequestRecord]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.id == y.id
                && x.arrival_ms == y.arrival_ms
                && x.start_ms == y.start_ms
                && x.finish_ms == y.finish_ms
                && x.config_idx == y.config_idx
        })
}

fn assert_outcomes_equal(shim: &SimOutcome, engine: &SimOutcome, what: &str) {
    assert!(records_identical(&shim.records, &engine.records), "records: {what}");
    assert_eq!(shim.switches.len(), engine.switches.len(), "switches: {what}");
    assert_eq!(shim.steals, engine.steals, "steals: {what}");
    assert_eq!(shim.spills, engine.spills, "spills: {what}");
}

#[test]
fn shims_equal_the_direct_engine_across_the_sweep() {
    // k ∈ {1,2,4} × B ∈ {1,4,8} × shards ∈ {1,k} × {uniform pool,
    // fast+accurate pools}: every shim entry point must return exactly
    // what the direct Topology engine call returns — same records, same
    // switch count, same steal/spill counts — under a switching policy
    // so routing reads the live rung.
    let plan = plan2();
    let arr = arrivals(12.0, 60.0);
    let svc = LognormalService::from_plan(&plan, 0.25);
    for k in [1usize, 2, 4] {
        for batch in [1usize, 4, 8] {
            let mut shard_set = vec![1usize];
            if k > 1 {
                shard_set.push(k);
            }
            for &shards in &shard_set {
                let mut ps = ElasticoPolicy::new(plan.clone());
                let shim = simulate_disc(
                    &arr,
                    &plan,
                    &mut ps,
                    &svc,
                    42,
                    k,
                    Discipline::ShardedSteal,
                    shards,
                    batch,
                );
                let topo = Topology::uniform(k, shards);
                let mut pe = ElasticoPolicy::new(plan.clone());
                let eng = simulate_topology(&arr, &plan, &mut pe, &svc, 42, &topo, batch);
                let what = format!("sharded k={k} shards={shards} B={batch}");
                assert_outcomes_equal(&shim, &eng, &what);
            }

            // Central FIFO: the 1-shard / k-worker shape.
            let mut ps = ElasticoPolicy::new(plan.clone());
            let shim = simulate_disc(
                &arr,
                &plan,
                &mut ps,
                &svc,
                42,
                k,
                Discipline::CentralFifo,
                0,
                batch,
            );
            let topo = Topology::uniform(k, 1);
            let mut pe = ElasticoPolicy::new(plan.clone());
            let eng = simulate_topology(&arr, &plan, &mut pe, &svc, 42, &topo, batch);
            assert_outcomes_equal(&shim, &eng, &format!("central k={k} B={batch}"));

            // One uniform pool through the pooled shim.
            let uniform = [PoolSpec::uniform(k)];
            let mut ps = ElasticoPolicy::new(plan.clone());
            let shim = simulate_pools(&arr, &plan, &mut ps, &svc, 42, &uniform, batch);
            let topo = Topology::from_pools(&uniform, 0.0).unwrap();
            let mut pe = ElasticoPolicy::new(plan.clone());
            let eng = simulate_topology(&arr, &plan, &mut pe, &svc, 42, &topo, batch);
            assert_outcomes_equal(&shim, &eng, &format!("uniform pool k={k} B={batch}"));

            // Heterogeneous fast+accurate pools.
            let pools = parse_pools(&format!("fast:{k}:1.0,accurate:{k}:2.5")).unwrap();
            let mut ps = ElasticoPolicy::new(plan.clone());
            let shim = simulate_pools(&arr, &plan, &mut ps, &svc, 42, &pools, batch);
            let topo = Topology::from_pools(&pools, 0.0).unwrap();
            let mut pe = ElasticoPolicy::new(plan.clone());
            let eng = simulate_topology(&arr, &plan, &mut pe, &svc, 42, &topo, batch);
            assert_outcomes_equal(&shim, &eng, &format!("het pools k={k} B={batch}"));
        }
    }
}

#[test]
fn seed_shape_golden_pin_is_bit_for_bit() {
    // k = 1, B = 1, central FIFO, deterministic 40 ms service under a
    // static policy: the M/D/1 timeline is computable by hand
    // (start_i = max(arrival_i, finish_{i-1})) and every entry point
    // must reproduce it exactly. All values are integer milliseconds,
    // so f64 equality is exact — the seed figures cannot drift.
    let plan = plan2();
    let arr = [0.0, 0.01, 0.02, 0.03, 0.1];
    let svc = DeterministicService { means: vec![40.0, 40.0] };
    let golden: [(u64, f64, f64, f64); 5] = [
        (0, 0.0, 0.0, 40.0),
        (1, 10.0, 40.0, 80.0),
        (2, 20.0, 80.0, 120.0),
        (3, 30.0, 120.0, 160.0),
        (4, 100.0, 160.0, 200.0),
    ];
    let check = |out: &SimOutcome, what: &str| {
        assert_eq!(out.records.len(), golden.len(), "{what}");
        for (r, g) in out.records.iter().zip(&golden) {
            assert_eq!(r.id, g.0, "{what}");
            assert_eq!(r.arrival_ms, g.1, "{what} id={}", r.id);
            assert_eq!(r.start_ms, g.2, "{what} id={}", r.id);
            assert_eq!(r.finish_ms, g.3, "{what} id={}", r.id);
            assert_eq!(r.config_idx, 0, "{what}");
        }
        assert!(out.switches.is_empty(), "{what}");
        assert_eq!(out.steals, 0, "{what}");
        assert_eq!(out.spills, 0, "{what}");
    };
    let mut p = StaticPolicy::new(0, "fast");
    check(&simulate(&arr, &plan, &mut p, &svc, 7), "simulate");
    let mut p = StaticPolicy::new(0, "fast");
    check(&simulate_k(&arr, &plan, &mut p, &svc, 7, 1), "simulate_k");
    let mut p = StaticPolicy::new(0, "fast");
    let disc = simulate_disc(&arr, &plan, &mut p, &svc, 7, 1, Discipline::CentralFifo, 0, 1);
    check(&disc, "simulate_disc");
    let mut p = StaticPolicy::new(0, "fast");
    let topo = Topology::uniform(1, 1);
    check(&simulate_topology(&arr, &plan, &mut p, &svc, 7, &topo, 1), "engine");
}

#[test]
fn spill_margin_keeps_work_the_fast_pool_finishes_sooner() {
    // fast:2 @1x owns rung 0; slow:2 @2.5x owns rung 1+. A static
    // rung-0 policy routes all three arrivals to the fast pool. With
    // margin 0 the idle slow pool immediately poaches the third request
    // and runs it 2.5x slower (finish at 27 ms); with margin 1 the gate
    // holds (backlog 1 ≤ 1 · 2.5 · 2 = 5) and a fast worker picks it up
    // at 10 ms, finishing at 20 ms — strictly sooner.
    let plan = plan2();
    let pools = parse_pools("fast:2:1.0,slow:2:2.5").unwrap();
    let arr = [0.0, 0.001, 0.002];
    let svc = DeterministicService { means: vec![10.0, 10.0] };
    let run = |margin: f64| {
        let topo = Topology::from_pools(&pools, margin).unwrap();
        let mut pol = StaticPolicy::new(0, "fast");
        simulate_topology(&arr, &plan, &mut pol, &svc, 3, &topo, 1)
    };
    let poached = run(0.0);
    assert_eq!(poached.records.len(), 3);
    assert!(poached.spills > 0, "margin 0 must keep spill-when-dry");
    let gated = run(1.0);
    assert_eq!(gated.records.len(), 3, "gated work must still be served");
    assert_eq!(gated.spills, 0, "the margin must block the shallow poach");
    assert!(gated.records.iter().all(|r| r.config_idx == 0), "fast pool only");
    let makespan = |o: &SimOutcome| {
        o.records.iter().map(|r| r.finish_ms).fold(f64::NEG_INFINITY, f64::max)
    };
    assert!(
        makespan(&gated) < makespan(&poached),
        "gated fleet must finish sooner: {} vs {}",
        makespan(&gated),
        makespan(&poached)
    );
}
