//! Integration: COMPASS-V recall/savings at paper scale — all 16
//! (workflow, τ) cells of Fig. 4, asserting the reproduction's headline
//! properties: 100% recall on the noise-free ground truth and positive
//! savings everywhere.

use compass::configspace::{detection_space, rag_space, ConfigSpace};
use compass::oracle::{DetectionOracle, Landscape, LandscapeEvaluator, RagOracle};
use compass::search::{grid_search, BudgetSchedule, CompassV, CompassVParams};

fn check<L: Landscape>(
    space: &ConfigSpace,
    taus: &[f64],
    schedule: BudgetSchedule,
    make: impl Fn(u64) -> LandscapeEvaluator<L>,
) {
    let n = space.enumerate_valid().len();
    let b_max = schedule.b_max();
    for &tau in taus {
        let mut gt_oracle = make(7);
        let grid = grid_search(space, b_max, &mut gt_oracle);
        // Noise-free ground truth: measured AND latent accuracy >= tau.
        let gt: Vec<usize> = grid
            .feasible(tau)
            .iter()
            .filter(|(c, _)| gt_oracle.true_accuracy(space, c) >= tau)
            .map(|(c, _)| space.flat_id(c))
            .collect();

        let mut oracle = make(7);
        let r = CompassV::new(CompassVParams {
            seed: 7,
            schedule: schedule.clone(),
            ..Default::default()
        })
        .run(space, tau, &mut oracle);
        let found: std::collections::HashSet<usize> =
            r.feasible.iter().map(|(c, _)| space.flat_id(c)).collect();

        let missed: Vec<&usize> = gt.iter().filter(|id| !found.contains(id)).collect();
        assert!(
            missed.is_empty(),
            "tau={tau}: missed {} of {} noise-free feasible configs",
            missed.len(),
            gt.len()
        );
        assert!(
            r.samples_used < (n as u64) * (b_max as u64),
            "tau={tau}: no savings over exhaustive"
        );
    }
}

#[test]
fn rag_all_thresholds_full_recall_with_savings() {
    check(
        &rag_space(),
        &[0.30, 0.40, 0.50, 0.60, 0.70, 0.75, 0.80, 0.85],
        BudgetSchedule::rag(),
        RagOracle::new_rag,
    );
}

#[test]
fn detection_all_thresholds_full_recall_with_savings() {
    check(
        &detection_space(),
        &[0.55, 0.59, 0.62, 0.66, 0.70, 0.73, 0.76, 0.80],
        BudgetSchedule::detection(),
        DetectionOracle::new_detection,
    );
}

#[test]
fn tight_threshold_savings_exceed_half() {
    // The paper's marquee regime: at tight thresholds most of the space
    // is never visited.
    let space = rag_space();
    let n = space.enumerate_valid().len();
    let mut oracle = RagOracle::new_rag(7);
    let r = CompassV::new(CompassVParams { seed: 7, ..Default::default() })
        .run(&space, 0.85, &mut oracle);
    let savings = r.savings_vs_exhaustive(n, 100);
    assert!(savings > 0.5, "savings {savings}");
}

#[test]
fn different_seeds_agree_on_clear_configs() {
    // Reproducibility envelope: configurations far from the boundary are
    // classified identically across search seeds.
    let space = rag_space();
    let collect = |seed: u64| {
        let mut oracle = RagOracle::new_rag(99); // same draws
        let r = CompassV::new(CompassVParams { seed, ..Default::default() })
            .run(&space, 0.60, &mut oracle);
        r.feasible
            .iter()
            .map(|(c, _)| space.flat_id(c))
            .collect::<std::collections::HashSet<_>>()
    };
    let a = collect(1);
    let b = collect(2);
    let landscape = compass::oracle::rag::RagLandscape;
    for cfg in space.enumerate_valid() {
        let acc = landscape.true_accuracy(&space, &cfg);
        if acc > 0.72 {
            let id = space.flat_id(&cfg);
            assert!(a.contains(&id) && b.contains(&id), "clear config missed");
        }
    }
}
