//! Integration: the online re-planning loop — live ρ̂/speed/α estimation
//! feeding threshold re-derivation, adaptive batch and spill margin — in
//! BOTH executors.
//!
//! Two pins anchor the PR:
//!
//! 1. **Disabled parity** — `ReplanConfig::default()` (off) reproduces
//!    the plain DES engine bit for bit, and the live server with the
//!    loop off reports zero re-plans.
//! 2. **Re-planning beats the static plan under drift** — the same
//!    mid-run persistent service drift, same arrivals, same seed and
//!    same base plan: the run with the adaptation loop closed converges
//!    (≥ 1 adopted re-plan) and holds strictly higher SLO compliance
//!    than the run serving the stale plan, in both the DES and the live
//!    runtime.

use compass::metrics::RunSummary;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, Plan, ProfiledConfig};
use compass::serving::executor::MockEngine;
use compass::serving::{
    serve, ElasticoPolicy, OverloadConfig, ReplanConfig, ResilienceConfig, ServeOptions, Topology,
};
use compass::sim::{simulate_topology, simulate_topology_replan, LognormalService, SimOutcome};
use compass::workload::{Fault, FaultPlan};

/// Synthetic two-rung plan (fast 20 ms, accurate 90 ms) derived for a
/// 2-worker fleet — the idiom of the resilience/overload suites.
fn plan2() -> Plan {
    let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
    };
    derive_plan(
        &[mk("fast", 0.76, 20.0, 28.0), mk("accurate", 0.85, 90.0, 120.0)],
        AqmParams::for_slo_workers(300.0, 2),
    )
}

fn steady_arrivals(qps: f64, dur: f64) -> Vec<f64> {
    let n = (qps * dur) as usize;
    (0..n).map(|i| i as f64 / qps).collect()
}

/// ×4 persistent fleet-wide service drift 20 s into a 90 s run
/// (`Topology::uniform` is a single pool): the accurate rung
/// (90 → 360 ms) then blows the 300 ms SLO on service time alone, so
/// every post-drift request served at that rung is a miss — the stale
/// plan keeps re-entering it on every downscale window, the re-planner
/// learns the drifted speed and blocks it.
fn drift_plan() -> FaultPlan {
    FaultPlan::none().with(Fault::Drift { pool: 0, factor: 4.0, from_s: 20.0, to_s: None })
}

fn des_drift_run(replan: &ReplanConfig) -> (SimOutcome, Vec<f64>, Plan) {
    let plan = plan2();
    let arr = steady_arrivals(8.0, 90.0);
    let svc = LognormalService::from_plan(&plan, 0.10);
    let topo = Topology::uniform(2, 2);
    let mut p = ElasticoPolicy::new(plan.clone());
    let out = simulate_topology_replan(
        &arr,
        &plan,
        &mut p,
        &svc,
        42,
        &topo,
        1,
        &drift_plan(),
        &ResilienceConfig::default(),
        &OverloadConfig::default(),
        replan,
    );
    (out, arr, plan)
}

fn compliance(records: &[compass::metrics::RequestRecord], slo_ms: f64) -> f64 {
    if records.is_empty() {
        return 0.0;
    }
    let ok = records.iter().filter(|r| r.finish_ms - r.arrival_ms <= slo_ms).count();
    ok as f64 / records.len() as f64
}

// ---------------------------------------------------------------------
// Pin 1: the loop off is invisible in both executors
// ---------------------------------------------------------------------

#[test]
fn des_disabled_replan_is_bit_identical_to_the_plain_engine() {
    let plan = plan2();
    let arr = steady_arrivals(12.0, 60.0);
    let svc = LognormalService::from_plan(&plan, 0.25);
    let topo = Topology::uniform(2, 2);
    let mut p1 = ElasticoPolicy::new(plan.clone());
    let base = simulate_topology(&arr, &plan, &mut p1, &svc, 42, &topo, 1);
    let mut p2 = ElasticoPolicy::new(plan.clone());
    let out = simulate_topology_replan(
        &arr,
        &plan,
        &mut p2,
        &svc,
        42,
        &topo,
        1,
        &FaultPlan::none(),
        &ResilienceConfig::default(),
        &OverloadConfig::default(),
        &ReplanConfig::default(),
    );
    assert_eq!(base.records.len(), out.records.len());
    for (x, y) in base.records.iter().zip(&out.records) {
        assert_eq!(x, y, "disabled re-planning must not perturb the DES");
    }
    assert_eq!(base.switches.len(), out.switches.len());
    assert_eq!(out.replans, 0);
}

#[test]
fn live_replan_off_reports_zero_replans() {
    let arrivals: Vec<f64> = (0..40).map(|i| i as f64 * 0.005).collect();
    let out = serve(
        move || {
            Ok(MockEngine {
                service_ms: vec![2.0, 8.0],
                accuracy: vec![0.76, 0.85],
                dispatch_ms: 0.0,
            })
        },
        Box::new(compass::serving::StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .unwrap();
    assert_eq!(out.replans, 0);
    assert_eq!(out.records.len() + out.rejected + out.failed, 40);
}

#[test]
fn live_replan_enabled_requires_a_base_plan() {
    // An enabled loop with no plan attached cannot re-derive anything —
    // the run must refuse to start rather than silently not adapt.
    let arrivals = vec![0.0, 0.01];
    let err = serve(
        move || {
            Ok(MockEngine {
                service_ms: vec![2.0],
                accuracy: vec![0.8],
                dispatch_ms: 0.0,
            })
        },
        Box::new(compass::serving::StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            replan: ReplanConfig { enabled: true, ..ReplanConfig::default() },
            ..ServeOptions::default()
        },
    );
    assert!(err.is_err(), "replan on without a base plan must be a configuration error");
}

// ---------------------------------------------------------------------
// Pin 2: re-planning converges and beats the stale plan under drift
// ---------------------------------------------------------------------

#[test]
fn des_replanning_beats_the_static_plan_under_drift() {
    let on = ReplanConfig { enabled: true, min_samples: 8, ..ReplanConfig::default() };
    let (adaptive, arr, plan) = des_drift_run(&on);
    let (stale, _, _) = des_drift_run(&ReplanConfig::default());

    // Conservation in both runs (no overload plane: three buckets).
    assert_eq!(adaptive.records.len() + adaptive.rejected + adaptive.failed, arr.len());
    assert_eq!(stale.records.len() + stale.rejected + stale.failed, arr.len());

    // The loop converged: the drifted speed crossed the min-change
    // hysteresis and the policy adopted at least one re-derived plan.
    assert!(adaptive.replans >= 1, "the re-planner must adopt a plan under ×4 drift");
    assert_eq!(stale.replans, 0);

    let c_on = compliance(&adaptive.records, plan.slo_ms);
    let c_off = compliance(&stale.records, plan.slo_ms);
    assert!(
        c_on > c_off,
        "re-planning must strictly beat the stale plan on SLO compliance in the DES: \
         replan {c_on:.3} vs static {c_off:.3}"
    );
    // And not vacuously: the stale plan keeps re-entering the drifted
    // 360 ms rung, so it must actually miss the SLO a meaningful part
    // of the time while the adapted run holds it.
    assert!(
        c_off < 0.92,
        "the drift must hurt the stale plan or the comparison is vacuous (got {c_off:.3})"
    );
    assert!(c_on > 0.8, "the adapted run must hold the SLO (got {c_on:.3})");
}

#[test]
fn des_replay_is_deterministic_with_replanning() {
    let on = ReplanConfig { enabled: true, min_samples: 8, ..ReplanConfig::default() };
    let (a, _, _) = des_drift_run(&on);
    let (b, _, _) = des_drift_run(&on);
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x, y, "the re-planning DES must replay bit-identically");
    }
    assert_eq!(a.replans, b.replans);
    assert_eq!(a.switches.len(), b.switches.len());
}

#[test]
fn live_replanning_beats_the_static_plan_under_drift() {
    // Fast 3 ms / accurate 15 ms on 2 workers, SLO 60 ms; pool 0 drifts
    // ×8 at t = 2.5 s and never recovers — the accurate rung (120 ms)
    // then blows the SLO by itself. 30 qps over 8 s.
    let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
    };
    let plan = derive_plan(
        &[mk("fast", 0.76, 3.0, 4.2), mk("accurate", 0.85, 15.0, 20.0)],
        AqmParams::for_slo_workers(60.0, 2),
    );
    let arrivals = steady_arrivals(30.0, 8.0);
    let faults =
        FaultPlan::none().with(Fault::Drift { pool: 0, factor: 8.0, from_s: 2.5, to_s: None });
    let run = |replan: ReplanConfig| {
        let plan = plan.clone();
        let out = serve(
            move || {
                Ok(MockEngine {
                    service_ms: vec![3.0, 15.0],
                    accuracy: vec![0.76, 0.85],
                    dispatch_ms: 0.0,
                })
            },
            Box::new(ElasticoPolicy::new(plan)),
            &arrivals,
            &ServeOptions {
                workers: 2,
                faults: faults.clone(),
                replan,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        assert_eq!(out.records.len() + out.rejected + out.failed, arrivals.len());
        out
    };
    let on = ReplanConfig {
        enabled: true,
        interval_ms: 1000.0,
        min_samples: 8,
        window: 32,
        ..ReplanConfig::default()
    }
    .with_plan(plan.clone());
    let adaptive = run(on);
    let stale = run(ReplanConfig::default());

    assert!(adaptive.replans >= 1, "the live re-planner must adopt a plan under ×8 drift");
    assert_eq!(stale.replans, 0);

    let sum_on = RunSummary::compute(&adaptive.records, &adaptive.switches, 60.0, 2);
    let sum_off = RunSummary::compute(&stale.records, &stale.switches, 60.0, 2);
    assert!(
        sum_on.slo_compliance > sum_off.slo_compliance,
        "re-planning must strictly beat the stale plan on SLO compliance live: \
         replan {:.3} vs static {:.3}",
        sum_on.slo_compliance,
        sum_off.slo_compliance
    );
}
