//! Acceptance pin: the batched hot path performs **zero per-batch heap
//! allocations** at steady state, for both shard-storage backends.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! warm-up pass (shard storage grown, scratch buffers at capacity) the
//! allocation counter must not move across push + batched-pop cycles or
//! across `execute_batch_into` dispatches. Everything lives in ONE test
//! function: the counter is process-global, so a second concurrently
//! running test would pollute the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use compass::serving::executor::{MockEngine, RequestEngine};
use compass::serving::{Popped, QueueBackend, ShardedQueue};
use compass::workflows::ExecOutcome;

/// System allocator with an allocation counter (frees are not counted —
/// the pin is about *new* heap traffic on the hot path).
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

/// Drain exactly `n` items through the batched pop path into `buf`.
fn drain_n<T>(q: &ShardedQueue<T>, n: usize, buf: &mut Vec<T>) {
    let mut total = 0usize;
    while total < n {
        match q.pop_batch_pool_into(0, 0, 32, Duration::from_millis(100), buf) {
            Popped::Item(got) => total += got,
            other => panic!("queue ran dry at {total}/{n}: {other:?}"),
        }
    }
    assert_eq!(total, n, "over-drained");
}

#[test]
fn steady_state_batch_dispatch_performs_no_heap_allocation() {
    type Job = (u64, f64, u32);

    for backend in [QueueBackend::Mutex, QueueBackend::Ring] {
        let q: ShardedQueue<Job> = ShardedQueue::new_backend(1024, 4, backend);
        let mut buf: Vec<Job> = Vec::with_capacity(64);

        // Warm-up: grow the mutex shards' VecDeques (the ring is
        // preallocated) and size the scratch buffer once.
        for i in 0..512u64 {
            q.push((i, 0.0, 0)).unwrap();
        }
        drain_n(&q, 512, &mut buf);

        // Steady state: 50 cycles of 32 pushes + batched drain must not
        // touch the allocator.
        let before = allocs();
        for cycle in 0..50u64 {
            for i in 0..32u64 {
                q.push((cycle * 32 + i, 0.0, 0)).unwrap();
            }
            drain_n(&q, 32, &mut buf);
        }
        let grew = allocs() - before;
        assert_eq!(
            grew, 0,
            "{backend:?} batched hot path allocated {grew} times at steady state"
        );
    }

    // Engine side: `execute_batch_into` refills the caller's outcome
    // buffer without allocating.
    let mut engine = MockEngine {
        service_ms: vec![0.0],
        accuracy: vec![0.8],
        dispatch_ms: 0.0,
    };
    let mut outs: Vec<ExecOutcome> = Vec::with_capacity(8);
    engine.execute_batch_into(0, 8, &mut outs).unwrap();

    let before = allocs();
    for _ in 0..50 {
        engine.execute_batch_into(0, 8, &mut outs).unwrap();
        assert_eq!(outs.len(), 8);
    }
    let grew = allocs() - before;
    assert_eq!(grew, 0, "execute_batch_into allocated {grew} times at steady state");
}
