//! DES-vs-theory validation (the `test` archetype's arming suite):
//! the pooled discrete-event simulator against closed-form queueing
//! theory.
//!
//! * **Homogeneous pools vs M/M/k**: a single uniform pool of k
//!   exponential servers is an M/M/k queue — work stealing keeps the
//!   servers non-idling and exponential service makes the occupancy
//!   process insensitive to which shard a job sits in, so the mean wait
//!   must match `mmk_mean_wait` and the waiting fraction must match
//!   Erlang-C `C(k, a)` (PASTA). Checked at utilizations 0.3 / 0.7 /
//!   0.9 within 5% — this is the bound the Erlang-C threshold mode
//!   (`planner::ThresholdMode::ErlangC`) rests on.
//! * **Heterogeneous bracketing**: a fast+slow fleet must sit strictly
//!   between its all-fast and all-slow homogeneous bounds in mean
//!   latency — the sanity envelope for every routing/spill decision the
//!   pooled runtime makes.
//! * **Two-class priority sandwich**: with the overload plane's
//!   DES-only class-priority service order, a two-class M/M/k at
//!   ρ = 0.7 must reproduce the non-preemptive priority waits (Cobham)
//!   and sit strictly around the FIFO wait:
//!   W_gold ≤ W_fifo ≤ W_bronze.

use compass::planner::{ConfigPolicy, Plan};
use compass::serving::pool::{parse_pools, PoolSpec};
use compass::serving::StaticPolicy;
use compass::sim::theory::{erlang_c, mmk_mean_wait};
use compass::sim::{simulate_pools, ExponentialService};
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

/// A one-rung plan with an effectively-unbounded SLO (theory runs are
/// about the queue, not the controller).
fn plan_one(mean_ms: f64) -> Plan {
    Plan {
        slo_ms: 1e9,
        slack_buffer_ms: 0.0,
        up_cooldown_ms: 0.0,
        down_cooldown_ms: 0.0,
        workers: 1,
        batch: 1,
        batch_alpha_ms: 0.0,
        pools: vec![],
        ladder: vec![ConfigPolicy {
            label: "only".into(),
            config: vec![],
            accuracy: 0.8,
            mean_ms,
            p95_ms: mean_ms,
            queue_slack_ms: 0.0,
            upscale_threshold: u64::MAX,
            downscale_threshold: None,
        }],
    }
}

fn poisson_arrivals(qps: f64, duration_s: f64, seed: u64) -> Vec<f64> {
    generate_arrivals(&WorkloadSpec {
        base_qps: qps,
        duration_s,
        pattern: Pattern::Steady,
        seed,
    })
}

fn mean_wait_ms(records: &[compass::metrics::RequestRecord]) -> f64 {
    records.iter().map(|r| r.wait_ms()).sum::<f64>() / records.len() as f64
}

fn waiting_fraction(records: &[compass::metrics::RequestRecord]) -> f64 {
    records.iter().filter(|r| r.wait_ms() > 1e-9).count() as f64 / records.len() as f64
}

#[test]
fn homogeneous_pool_matches_mmk_wait_and_erlang_c_across_utilizations() {
    // k = 2 exponential servers, mean service 10 ms (μ = 0.1/ms). For
    // each target ρ the run is long enough that the DES estimator's
    // error sits well inside the 5% acceptance band (heavier traffic
    // mixes slower, so ρ = 0.9 gets the longest run).
    let k = 2usize;
    let mean_ms = 10.0;
    let mu_per_ms = 1.0 / mean_ms;
    let plan = plan_one(mean_ms);
    let svc = ExponentialService { means: vec![mean_ms] };

    for (rho, duration_s, seed) in
        [(0.3, 6000.0, 11u64), (0.7, 6000.0, 13), (0.9, 9000.0, 17)]
    {
        let qps = rho * k as f64 * 100.0; // λ = ρ·k·μ, μ = 100 qps/server
        let arrivals = poisson_arrivals(qps, duration_s, seed);
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate_pools(
            &arrivals,
            &plan,
            &mut pol,
            &svc,
            seed,
            &[PoolSpec::uniform(k)],
            1,
        );
        assert_eq!(out.records.len(), arrivals.len());

        // Mean wait vs M/M/k.
        let lambda_per_ms = qps / 1000.0;
        let expect_wait = mmk_mean_wait(k, lambda_per_ms, mu_per_ms);
        let measured_wait = mean_wait_ms(&out.records);
        assert!(
            (measured_wait - expect_wait).abs() / expect_wait < 0.05,
            "ρ={rho}: mean wait {measured_wait:.3} ms vs M/M/{k} {expect_wait:.3} ms"
        );

        // Waiting probability vs Erlang-C (PASTA: an arrival waits iff
        // all k servers are busy).
        let expect_c = erlang_c(k, k as f64 * rho);
        let measured_c = waiting_fraction(&out.records);
        assert!(
            (measured_c - expect_c).abs() / expect_c < 0.05,
            "ρ={rho}: P(wait) {measured_c:.4} vs C({k}, {:.1}) = {expect_c:.4}",
            k as f64 * rho
        );
    }
}

#[test]
fn heterogeneous_wait_is_bracketed_by_the_homogeneous_bounds() {
    // 4 workers at λ = 140 qps, exponential service, mean 10 ms on
    // reference hardware. Three fleets over the same arrival trace:
    // all-fast (4 @ 1x), heterogeneous (2 @ 1x + 2 @ 2x), all-slow
    // (4 @ 2x; ρ = 0.7 — the tightest of the three, still stable). The
    // pooled fleet's mean latency must land strictly between the two
    // homogeneous bounds: replacing fast workers with slower ones can
    // only hurt, but not as much as slowing the whole fleet.
    let plan = plan_one(10.0);
    let svc = ExponentialService { means: vec![10.0] };
    let arrivals = poisson_arrivals(140.0, 2000.0, 23);

    let mean_latency = |pools: &[PoolSpec]| {
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate_pools(&arrivals, &plan, &mut pol, &svc, 23, pools, 1);
        assert_eq!(out.records.len(), arrivals.len(), "conservation");
        out.records.iter().map(|r| r.latency_ms()).sum::<f64>()
            / out.records.len() as f64
    };

    let all_fast = mean_latency(&[PoolSpec::uniform(4)]);
    let het = mean_latency(&parse_pools("fast:2:1.0,slow:2:2.0").unwrap());
    let all_slow = mean_latency(&[PoolSpec::new("slow", 4, 0, 2.0)]);

    assert!(
        all_fast < het && het < all_slow,
        "bracketing violated: all-fast {all_fast:.2} ms, het {het:.2} ms, \
         all-slow {all_slow:.2} ms"
    );
    // The bounds are not degenerate: the envelope is clearly open.
    assert!(all_slow > all_fast * 1.2, "bounds too tight to be meaningful");
}

#[test]
fn erlang_thresholds_agree_with_the_des_measured_waiting_probability() {
    // Close the loop between the Erlang-C threshold derivation and the
    // simulator: the planner's thresholds assume the waiting
    // probability C(k, k·ρ̂) — re-derive the depth budget from the
    // waiting probability the pooled DES actually *measures* at ρ̂ and
    // it must land on the plan's N↑ within 5%. This fails if either the
    // analytic C drifts from the simulated system or the derivation
    // stops using it as documented (N↑ = ⌊k·Δ/(s̄·C)⌋).
    use compass::planner::{
        derive_plan, AqmParams, LatencyProfile, ProfiledConfig, ThresholdMode,
    };
    let mean_ms = 10.0;
    let front = vec![ProfiledConfig {
        config: vec![],
        label: "fast".into(),
        accuracy: 0.8,
        latency: LatencyProfile {
            mean_ms,
            p50_ms: mean_ms,
            p95_ms: 14.0,
            runs: 10,
        },
    }];
    let plan_sim = plan_one(mean_ms);
    let svc = ExponentialService { means: vec![mean_ms] };
    let rho_hat = 0.45; // AqmParams::target_rho default
    for (k, duration_s, seed) in [(2usize, 4000.0, 29u64), (4, 3000.0, 31)] {
        // Measure P(wait) in the pooled DES at the assumed operating
        // point ρ̂ — the quantity the Erlang-C mode plugs in.
        let qps = rho_hat * k as f64 * 100.0;
        let arrivals = poisson_arrivals(qps, duration_s, seed);
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate_pools(
            &arrivals,
            &plan_sim,
            &mut pol,
            &svc,
            seed,
            &[PoolSpec::uniform(k)],
            1,
        );
        let c_measured = waiting_fraction(&out.records);

        let params = AqmParams::for_slo_workers(300.0, k)
            .with_thresholds(ThresholdMode::ErlangC);
        let plan = derive_plan(&front, params);
        let n_up = plan.ladder[0].upscale_threshold as f64;
        let slack = plan.ladder[0].queue_slack_ms;
        // Depth budget recomputed from the *measured* C.
        let budget_measured = k as f64 * slack / (mean_ms * c_measured);
        assert!(
            (budget_measured - n_up).abs() / n_up < 0.05,
            "k={k}: N↑ {n_up} vs DES-measured budget {budget_measured:.1} \
             (measured C {c_measured:.4}, analytic C {:.4})",
            erlang_c(k, k as f64 * rho_hat)
        );
        // And the legacy bound is genuinely deepened (C < 1).
        let legacy = derive_plan(&front, AqmParams::for_slo_workers(300.0, k));
        assert!(plan.ladder[0].upscale_threshold > legacy.ladder[0].upscale_threshold);
    }
}

#[test]
fn two_class_priority_waits_sandwich_fifo_and_match_mmk_priority_theory() {
    // Non-preemptive two-class M/M/k priority at ρ = 0.7 (k = 2, mean
    // 10 ms exponential service, equal class split, no deadlines so the
    // overload plane's shed/expiry machinery stays inert). Cobham's
    // waits are
    //   W_j = C(k, a)/(kμ) / ((1 − σ_{j−1})(1 − σ_j)),  σ_j = Σ_{i≤j} λ_i/(kμ)
    // and blind FIFO is W = C(k, a)/(kμ(1 − ρ)). The class-priority DES
    // (`priority=on`, an overload-plane knob) must reproduce the
    // priority waits, the FIFO run the blind wait, and the sandwich
    // W_gold < W_fifo < W_bronze must be strict.
    use compass::serving::{parse_classes, OverloadConfig, ResilienceConfig, Topology};
    use compass::sim::simulate_topology_overload;
    use compass::workload::FaultPlan;

    let k = 2usize;
    let mean_ms = 10.0;
    let rho = 0.7;
    let plan = plan_one(mean_ms);
    let svc = ExponentialService { means: vec![mean_ms] };
    let qps = rho * k as f64 * 100.0;
    let arrivals = poisson_arrivals(qps, 6000.0, 37);
    // One shard = the central FIFO: the priority scan sees the whole
    // backlog, so the service order is exactly the theory's.
    let topo = Topology::uniform(k, 1);
    let classes = parse_classes("gold:0.5:0,bronze:0.5:0").unwrap();

    let run = |priority: bool| {
        let cfg =
            OverloadConfig { priority, ..OverloadConfig::enabled() }.with_classes(classes.clone());
        let mut pol = StaticPolicy::new(0, "only");
        let out = simulate_topology_overload(
            &arrivals,
            &plan,
            &mut pol,
            &svc,
            37,
            &topo,
            1,
            &FaultPlan::none(),
            &ResilienceConfig::default(),
            &cfg,
        );
        assert_eq!(out.records.len(), arrivals.len(), "nothing sheds or expires at ρ = 0.7");
        (out, cfg)
    };
    let (fifo, cfg) = run(false);
    let (prio, _) = run(true);
    let class_mean = |records: &[compass::metrics::RequestRecord], class: usize| {
        let waits: Vec<f64> = records
            .iter()
            .filter(|r| cfg.class_of(r.id) == class)
            .map(|r| r.wait_ms())
            .collect();
        waits.iter().sum::<f64>() / waits.len() as f64
    };
    let w_fifo = mean_wait_ms(&fifo.records);
    let w_gold = class_mean(&prio.records, 0);
    let w_bronze = class_mean(&prio.records, 1);
    assert!(
        w_gold < w_fifo && w_fifo < w_bronze,
        "sandwich violated: gold {w_gold:.2} ms, fifo {w_fifo:.2} ms, bronze {w_bronze:.2} ms"
    );

    let kmu = k as f64 / mean_ms; // kμ per ms
    let c = erlang_c(k, k as f64 * rho);
    let sigma_gold = 0.5 * rho; // the gold half of the offered load
    let expect_fifo = c / (kmu * (1.0 - rho));
    let expect_gold = c / (kmu * (1.0 - sigma_gold));
    let expect_bronze = c / (kmu * (1.0 - sigma_gold) * (1.0 - rho));
    for (label, got, want) in [
        ("fifo", w_fifo, expect_fifo),
        ("gold", w_gold, expect_gold),
        ("bronze", w_bronze, expect_bronze),
    ] {
        assert!(
            (got - want).abs() / want < 0.10,
            "{label}: measured {got:.3} ms vs theory {want:.3} ms"
        );
    }
}
