//! Integration: the composed offline phase (search -> candidates ->
//! Pareto -> AQM) and its interaction with the simulator — the
//! "plan quality" contract that the online phase relies on.

use compass::experiments::common::{
    base_qps, make_policy, modeled_latency_ms, offline_phase, simulate_boxed,
};
use compass::metrics::RunSummary;
use compass::sim::LognormalService;
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

#[test]
fn offline_phase_produces_usable_ladder() {
    let (space, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
    assert!(plan.ladder.len() >= 2);
    for w in plan.ladder.windows(2) {
        assert!(w[0].mean_ms < w[1].mean_ms);
        assert!(w[0].accuracy < w[1].accuracy);
    }
    // Modeled latencies coherent with the cost model.
    for p in &plan.ladder {
        let m = modeled_latency_ms(&space, &p.config);
        assert!((m - p.mean_ms).abs() < 1e-6);
    }
}

#[test]
fn aqm_thresholds_keep_slo_in_simulation() {
    // The AQM contract (§V): under steady load at the design utilization,
    // Elastico holds P95 within the SLO.
    let (_s, full) = offline_phase(0.75, 1e9, 7, false).unwrap();
    let slo = 2.2 * full.ladder.last().unwrap().mean_ms;
    let (_s2, plan) = offline_phase(0.75, slo, 7, false).unwrap();
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: base_qps(&full),
        duration_s: 300.0,
        pattern: Pattern::Steady,
        seed: 11,
    });
    let svc = LognormalService::from_plan(&plan, 0.10);
    let mut policy = make_policy(&plan, "Elastico");
    let out = simulate_boxed(&arrivals, &plan, &mut policy, &svc, 11);
    let summary = RunSummary::compute(&out.records, &out.switches, slo, plan.ladder.len());
    assert!(
        summary.slo_compliance > 0.95,
        "steady-state compliance {}",
        summary.slo_compliance
    );
    // Under steady feasible load the controller should converge toward
    // accurate rungs, not sit at the fastest.
    assert!(
        summary.mean_accuracy > plan.ladder[0].accuracy + 0.005,
        "never recovered accuracy: {}",
        summary.mean_accuracy
    );
}

#[test]
fn plan_json_roundtrip_through_disk() {
    let (_s, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
    let path = std::env::temp_dir().join("compass_plan_test.json");
    std::fs::write(&path, plan.to_json().to_string()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed =
        compass::planner::Plan::from_json(&compass::util::json::Json::parse(&text).unwrap())
            .unwrap();
    assert_eq!(parsed, plan);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tighter_slo_prunes_ladder() {
    let (_s, full) = offline_phase(0.75, 1e9, 7, false).unwrap();
    let slowest_p95 = full.ladder.last().unwrap().p95_ms;
    let (_s2, tight) = offline_phase(0.75, slowest_p95 * 0.8, 7, false).unwrap();
    assert!(tight.ladder.len() < full.ladder.len());
    // The excluded rungs are exactly those whose p95 exceeds the SLO.
    for p in &tight.ladder {
        assert!(p.p95_ms < slowest_p95 * 0.8);
    }
}
